"""Minimal pure-pytree optimizers (no optax dependency).

The paper's local optimizer is mini-batch SGD with momentum 0.9; FedOpt
needs a server-side Adam.  LR schedules mirror the paper's step decay.
"""
from __future__ import annotations
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class SGDState(NamedTuple):
    momentum: Params


def sgd_init(params: Params) -> SGDState:
    return SGDState(jax.tree.map(jnp.zeros_like, params))


def sgd_update(params: Params, grads: Params, state: SGDState, *,
               lr: float, momentum: float = 0.9,
               weight_decay: float = 0.0) -> tuple[Params, SGDState]:
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    new_m = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
    new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
    return new_p, SGDState(new_m)


class AdamState(NamedTuple):
    m: Params
    v: Params
    count: jax.Array


def adam_init(params: Params) -> AdamState:
    return AdamState(jax.tree.map(jnp.zeros_like, params),
                     jax.tree.map(jnp.zeros_like, params),
                     jnp.zeros((), jnp.int32))


def adam_update(params: Params, grads: Params, state: AdamState, *,
                lr: float, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8) -> tuple[Params, AdamState]:
    count = state.count + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g), state.v, grads)
    c = count.astype(jnp.float32)
    mh = 1.0 / (1 - b1 ** c)
    vh = 1.0 / (1 - b2 ** c)
    new_p = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mh) / (jnp.sqrt(v_ * vh) + eps),
        params, m, v)
    return new_p, AdamState(m, v, count)


def step_decay(base_lr: float, round_idx, decay_rounds, factor: float = 0.1):
    """Paper-style step decay (decay at the listed rounds)."""
    mult = 1.0
    for r in decay_rounds:
        mult = jnp.where(round_idx >= r, mult * factor, mult)
    return base_lr * mult
