"""repro.fleet — vectorized fleet-scale fedbuff simulation.

Struct-of-arrays client populations (``state.py``), jitted event waves
with a shard_map'd cohort sampler (``waves.py``), and the wave-loop
engine (``engine.py``) that replays ``sim.run_sim``'s fedbuff semantics
at N ~ 10^5..10^6 clients.  See ``run_fleet`` and the engine module
docstring for the host/device split and the documented non-goals.
"""
from repro.fleet.engine import run_fleet  # noqa: F401
from repro.fleet.state import FleetState  # noqa: F401
from repro.fleet.waves import (INELIGIBLE, make_wave_scorer,  # noqa: F401
                               make_wave_trainer, wave_top_k)
