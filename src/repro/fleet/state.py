"""Struct-of-arrays client population state for the fleet engine.

One dataclass of (N,)-shaped arrays replaces the sim engine's per-client
Python dicts (``jobs``, the sorted ``idle`` list, per-event heap
entries) — the representation change that moves the population axis
from Python objects to array programs.  Everything time- or byte-valued
is host numpy float64 (the same precision argument as the byte ledgers:
f32 silently loses integer byte counts past ~16M and collapses
virtual-clock ties); the device side of the split (selection scoring,
training, the merge) lives in ``fleet/waves.py``.

The population state the policies own stays in THEIR arrays —
availability phase is implicit (2*pi*i/N in ``VAvailDiurnal``), battery
and busy-until live in ``VEnergy``, bandwidth class in
``ResourceArrays`` — so this dataclass carries only the engine's view:
who is in flight, from which version, and what their round trip costs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class FleetState:
    """Engine-side per-client arrays (all shape (N,))."""

    arrival_time: np.ndarray    # f64 virtual arrival instant; +inf = idle
    in_flight: np.ndarray       # bool: a dispatch is outstanding
    is_dropout: np.ndarray      # bool: the outstanding dispatch will vanish
                                #   (decided at dispatch, like the sim's
                                #   DROPOUT-vs-ARRIVAL event choice)
    dl_version: np.ndarray      # int64 server version the client downloaded
    job_up_bytes: np.ndarray    # f64 nominal uplink payload of the job
    job_down_bytes: np.ndarray  # f64 broadcast-leg bytes of the job
    part_count: np.ndarray      # int64 dispatches per client
    drop_count: np.ndarray      # int64 mid-round deaths per client

    @classmethod
    def init(cls, n_clients: int) -> "FleetState":
        return cls(
            arrival_time=np.full(n_clients, np.inf, np.float64),
            in_flight=np.zeros(n_clients, bool),
            is_dropout=np.zeros(n_clients, bool),
            dl_version=np.full(n_clients, -1, np.int64),
            job_up_bytes=np.zeros(n_clients, np.float64),
            job_down_bytes=np.zeros(n_clients, np.float64),
            part_count=np.zeros(n_clients, np.int64),
            drop_count=np.zeros(n_clients, np.int64),
        )

    @property
    def n_inflight(self) -> int:
        return int(self.in_flight.sum())

    def free(self, ids: np.ndarray) -> None:
        """Mark a popped wave's clients idle again."""
        self.in_flight[ids] = False
        self.arrival_time[ids] = np.inf
