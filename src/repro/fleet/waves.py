"""The jitted device kernels of the fleet engine.

Three kernels cover everything the wave loop runs on device, each O(N)
over the population or O(K * model) over a wave:

  * ``make_wave_scorer(mesh)`` — the population-wide redispatch sampler:
    one Gumbel score per client where eligible (-inf elsewhere), so a
    global top-k draws a uniform-without-replacement cohort from the
    eligible set (the Gumbel-max trick).  The score array is sharded
    over the mesh's data axes with ``shard_map`` — the population is
    split across devices and each shard folds its own axis index into
    the key so shards draw independent streams.
  * ``wave_top_k(scores, k)`` — the global cohort draw over the gathered
    scores (k is static; the engine sees a handful of distinct k's).
  * ``make_wave_trainer(loss_fn, client_cfg)`` — K clients' local
    updates as ONE vmapped+jitted call over stacked start params and
    stacked batch trees (the sim engine trains per arrival; a wave
    trains its whole buffer in one dispatch).

Everything here is pure array code: the f64 virtual clock, byte
ledgers, and ring ledgers stay on the host (see ``fleet/engine.py`` for
the split).  ``repro.analyze`` roots the shard_map body for the
jit-purity rule, so host calls cannot creep into the wave kernels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.fl.client import local_update
from repro.launch.mesh import data_axes

# below any real Gumbel draw in f32; ineligible clients score here so a
# top-k can recognize them (the engine drops hits at/below the sentinel)
INELIGIBLE = -3.0e38


def _gumbel_score_body(axis_names: tuple[str, ...], key, eligible):
    """Per-shard scores: Gumbel(0,1) where eligible, sentinel elsewhere.

    ``key`` is replicated; folding the shard's position on every data
    axis into it gives each shard its own stream (without the fold all
    shards would draw IDENTICAL noise and the "uniform" cohort would be
    striped by shard boundary)."""
    for ax in axis_names:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    u = jax.random.uniform(key, eligible.shape, jnp.float32,
                           minval=1e-7, maxval=1.0)
    scores = -jnp.log(-jnp.log(u))
    return jnp.where(eligible, scores, INELIGIBLE)


def make_wave_scorer(mesh):
    """Jitted sharded scorer: (key, eligible bool (N,)) -> scores (N,).

    N must be a multiple of the mesh's data-axes extent — the engine
    pads the eligibility mask with False (padding scores at the
    sentinel, so it can never be drawn)."""
    axes = data_axes(mesh)
    spec = P(axes)
    fn = shard_map(partial(_gumbel_score_body, axes), mesh=mesh,
                   in_specs=(P(), spec), out_specs=spec, check_rep=False)
    return jax.jit(fn)


@partial(jax.jit, static_argnames="k")
def wave_top_k(scores, k: int):
    """Top-k scores over the (gathered) population: the cohort draw."""
    return jax.lax.top_k(scores, k)


def make_wave_trainer(loss_fn, client_cfg):
    """One wave's local training: vmap ``local_update`` over stacked
    start params (each arrival trains from the broadcast of ITS dispatch
    version) and stacked batch trees, jitted as a single call."""
    def _train_one(p, b):
        return local_update(loss_fn, p, b, client_cfg)
    return jax.jit(jax.vmap(_train_one))
