"""repro.fleet — the vectorized fleet-scale fedbuff engine.

``sim/engine.py`` is an event-driven simulator: one heap event per
client round trip, one Python callback per arrival.  That is the right
tool at N ~ 10^2..10^3 and asymptotically the wrong one at N ~ 10^5..10^6
— the heap, the per-event policy callbacks, and the per-client Python
dicts all scale with *events*, and events scale with N.  This engine
re-expresses the SAME fedbuff semantics as batched array programs over a
struct-of-arrays population (``fleet/state.py``):

  wave loop      pop the next ``buffer_size - len(buffer)`` earliest
                 arrivals AT ONCE (np.argpartition over the f64 arrival
                 column instead of heap pops), train them as one
                 vmapped+jitted call, merge, refill every freed slot in
                 one dispatch wave.
  cost model     ``core/comm.py``'s ``*_vec`` counterparts price a whole
                 wave per call (host f64, elementwise the scalar math).
  participation  ``participate/vectorized.py`` answers eligibility for
                 the whole population per wave; cohort selection is a
                 jitted Gumbel top-k sharded over the mesh's data axes
                 (``fleet/waves.py``).

Host/device split: the virtual clock, byte ledgers, ring ledgers, and
eligibility masks stay host numpy float64 (integer byte counts and
clock ties are exact in f64 and silently wrong in device f32); training,
selection scoring, and the buffered LUAR merge (the SAME jitted
``make_buffer_agg_fn`` body the sim and ``repro.serve`` run) are device
code.

Semantics vs the sim engine (pinned in ``tests/test_fleet.py``): under a
uniform scenario + uniform policy + no codecs the two engines produce
IDENTICAL dispatch/upload/merge counts, byte ledgers, comm ratios, and
virtual finish time; accuracy matches within a documented tolerance only
(the engines draw client batches in different orders, so the learning
trajectories are statistically — not bitwise — the same run).

Deliberate non-goals (each raises ``NotImplementedError`` rather than
silently degrading):

  * downlink codec pipelines — the sim's ``broadcast_for_dispatch``
    advances SERVER-side encoder state once per dispatch, an inherently
    sequential O(events) host loop; the fleet keeps one broadcast
    snapshot per version (the ``param_ring``) instead.
  * stateful uplink codecs (EF error feedback) — per-client codec state
    is O(N * model) memory at fleet scale.
  * weighted participation policies — rejected by
    ``make_vector_policy`` (their bias correction needs per-client
    feedback the wave loop does not thread yet).

One accounting approximation, documented because it is the only place
the fleet's ledgers are not exactly the sim's: a ledger-miss rejection
charges its wasted bytes to units PROPORTIONALLY to unit size (the
per-dispatch per-unit price array is not stored per client; the mask
needed to recompute it is exactly what the miss lost).  Misses are
impossible when ``ledger_capacity`` exceeds the worst-case version lag —
the regime every equivalence test and benchmark runs in — so the
approximation touches ``wasted_per_unit`` attribution only, never the
scalar totals.
"""
from __future__ import annotations

import math
from typing import Any
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import Direction
from repro.configs.base import get_scenario
from repro.core import luar_init
from repro.core.comm import (ResourceArrays, compute_time_vec,
                             download_time_vec, round_trip_time_vec)
from repro.fl.rounds import FLConfig, build_codec_pipeline
from repro.fl.server import broadcast_point, server_init
from repro.fleet.state import FleetState
from repro.fleet.waves import (INELIGIBLE, make_wave_scorer,
                               make_wave_trainer, wave_top_k)
from repro.launch.mesh import data_axes, make_host_mesh
from repro.obs import (AGGREGATE, DISPATCH, EVICT, M_INFLIGHT_END,
                       M_STRANDED_END, RUN_END, RUN_START, Telemetry,
                       UPLOAD, WAKE as TRACE_WAKE)
from repro.participate import make_vector_policy
from repro.sim.engine import (MaskLedger, SimConfig, SimResult,
                              VersionLedger, _Instruments, _schedule_alpha,
                              _staleness_quantiles, make_buffer_agg_fn)
from repro.sim.profiles import bandwidth_multiplier, sample_resource_arrays

Params = Any


def run_fleet(loss_fn: Callable[[Params, dict], jax.Array],
              init_params: Params,
              data: dict[str, np.ndarray],
              parts: list[np.ndarray] | np.ndarray,
              cfg: FLConfig,
              sim: SimConfig,
              eval_fn: Callable[[Params], dict[str, float]] | None = None,
              telemetry: Telemetry | None = None,
              mesh=None) -> SimResult:
    """Fleet-scale fedbuff over ``cfg.n_clients`` clients.

    Same config objects and same ``SimResult`` as ``sim.run_sim`` (the
    equivalence tests literally hand both engines the same arguments).
    ``parts`` may be the sim's per-client index list OR one shared index
    array — at N ~ 10^5 there is no per-client partition to speak of, so
    fleet benchmarks hand every client the same proxy pool and let the
    batch RNG do the partitioning.  ``SimResult.resources`` is ``None``
    (a million-row ``ClientResources`` list is exactly the per-client
    Python object layer this engine exists to avoid).
    """
    if sim.mode != "fedbuff":
        raise ValueError(
            f"the fleet engine is the fedbuff wave loop; got "
            f"sim.mode={sim.mode!r} (sync cohorts have no population-scale "
            f"event problem — use sim.run_sim)")
    if not sim.mask_ledger:
        raise NotImplementedError(
            "the fleet engine always merges against the versioned mask "
            "ledger; the PR-1 mask_ledger=False semantics exist only in "
            "sim.run_sim")
    pipeline = build_codec_pipeline(cfg)
    down_pipe = build_codec_pipeline(cfg, Direction.DOWN)
    sync_only = pipeline.sync_only_specs() + down_pipe.sync_only_specs()
    if sync_only:
        raise NotImplementedError(
            f"codec stage(s) {list(sync_only)} are anchored to a "
            "synchronous server view no async engine holds (same "
            "restriction as the fedbuff sim)")
    if down_pipe:
        raise NotImplementedError(
            f"downlink codec stage(s) {list(down_pipe.specs())}: per-"
            "dispatch broadcast encoding is a sequential host loop over "
            "events; the fleet engine broadcasts one per-version snapshot "
            "(run sim.run_sim for priced downlink pipelines)")
    if pipeline.stateful:
        raise NotImplementedError(
            f"stateful uplink codec in {list(pipeline.specs())}: per-"
            "client codec state is O(n_clients * model) at fleet scale")

    scenario = get_scenario(sim.scenario)
    res_arr = sample_resource_arrays(scenario, cfg.n_clients, sim.sys_seed)
    tele = telemetry if telemetry is not None else Telemetry()
    n = cfg.n_clients

    # the sim's RNG stream split: learning draws (batches) from cfg.seed,
    # systems draws (dropout) from sys_seed, and a dedicated selection
    # key for the Gumbel cohort draw (the sim burns host RNG per select;
    # the fleet draws on device)
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)
    sys_rng = np.random.default_rng(
        np.random.SeedSequence([sim.sys_seed, 0xE7]))
    sel_key = jax.random.PRNGKey(np.uint32(cfg.seed ^ 0xF1EE7))

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    sizes = np.asarray(um.unit_bytes, np.float64)
    total_bytes = sizes.sum()
    n_units = len(um.names)
    alpha = sim.staleness_alpha
    fedasync = sim.buffer_size == 1

    vec_policy = make_vector_policy(cfg.participation, n, cfg.seed)
    state = FleetState.init(n)

    mesh = mesh if mesh is not None else make_host_mesh()
    shards = math.prod(mesh.shape[a] for a in data_axes(mesh))
    pad = (-n) % shards
    scorer = make_wave_scorer(mesh)
    trainer = make_wave_trainer(loss_fn, cfg.client)
    codec_template = pipeline.init_state(params, um)

    def _enc_one(d, k):
        enc, _, aux = pipeline.encode(codec_template, d, k)
        return enc, aux
    encode_wave = jax.jit(jax.vmap(_enc_one))

    agg_fn = make_buffer_agg_fn(cfg, um, fedasync)

    now = 0.0
    version = 0
    ins = _Instruments(tele)
    tr = tele.trace

    def _evict_hook(which: str):
        child = ins.evictions.labels(ledger=which)

        def hook(v: int) -> None:
            child.inc()
            if tr:
                tr.emit(EVICT, now, ledger=which, version=v)
        return hook

    ledger = MaskLedger(sim.ledger_capacity, on_evict=_evict_hook("mask"))
    # the per-version broadcast snapshots every in-flight client trains
    # from — O(capacity * model) server memory, the fleet's replacement
    # for the sim's per-job ``start`` tree.  Recorded idempotently at
    # dispatch alongside the mask, same capacity: a mask hit IS a
    # snapshot hit.
    param_ring = VersionLedger(sim.ledger_capacity,
                               on_evict=_evict_hook("params"))
    res = SimResult(wasted_per_unit=np.zeros(n_units, np.float64))
    observed: list[float] = ins.staleness.samples
    buffer: list[tuple] = []
    no_mask_row = np.zeros((1, n_units), bool)

    if tr:
        tr.emit(RUN_START, 0.0, engine="fleet", mode="fedbuff",
                n_clients=n, rounds=cfg.rounds,
                buffer_size=sim.buffer_size, n_units=n_units,
                units=list(um.names))

    def draw_cohort(eligible: np.ndarray, want: int) -> np.ndarray:
        """Uniform-without-replacement cohort over the eligible mask via
        the sharded Gumbel top-k (k is capped at the eligible count so
        the sentinel filter is a no-op except under float ties)."""
        nonlocal sel_key
        k = min(int(want), int(eligible.sum()))
        if k <= 0:
            return np.empty(0, np.int64)
        sel_key, sub = jax.random.split(sel_key)
        elig = (np.concatenate([eligible, np.zeros(pad, bool)])
                if pad else eligible)
        vals, idx = wave_top_k(scorer(sub, jnp.asarray(elig)), k)
        idx = np.asarray(idx)[np.asarray(vals) > INELIGIBLE / 2]
        return idx.astype(np.int64)

    def dispatch_wave(ids: np.ndarray, t: float) -> None:
        """Serve ``ids`` the current version: record ledgers once, price
        the whole wave with the vectorized cost model, decide dropout
        fates, and write the arrival column."""
        k = len(ids)
        state.part_count[ids] += 1
        mask_now = np.asarray(luar_state.mask)
        ledger.record(version, mask_now)
        param_ring.record(version,
                          broadcast_point(params, server_state, cfg.server))
        with tele.span("pricing"):
            per_unit = pipeline.price_per_unit(sizes, mask_now)
            up_nominal = float(per_unit.sum())
            down_b = float(total_bytes)     # no down pipeline (validated)
        ins.down.add(down_b * k)
        ins.dispatches.add(k)
        ins.full_dl.add(k)
        if tr:
            tr.emit(DISPATCH, t, client=-1, n=k, version=version,
                    down_bytes=down_b, delta=False, first=False)
        m_bw = bandwidth_multiplier(scenario, t)
        res_w = ResourceArrays(res_arr.step_time[ids],
                               res_arr.up_bw[ids] * m_bw,
                               res_arr.down_bw[ids] * m_bw,
                               res_arr.dropout[ids])
        p_dead = vec_policy.survival_prob(ids, res_arr.dropout[ids])
        dead = (sys_rng.random(k) < p_dead if np.any(p_dead > 0.0)
                else np.zeros(k, bool))
        t_dead = (download_time_vec(um, res_w, down_b)
                  + compute_time_vec(cfg.tau, res_w))
        t_alive = round_trip_time_vec(um, no_mask_row, res_w, cfg.tau,
                                      payload_bytes=up_nominal,
                                      download_bytes=down_b)
        busy = np.where(dead, t_dead, t_alive)
        state.arrival_time[ids] = t + busy
        state.in_flight[ids] = True
        state.is_dropout[ids] = dead
        state.dl_version[ids] = version
        state.job_up_bytes[ids] = up_nominal
        state.job_down_bytes[ids] = down_b
        vec_policy.observe_dispatch(ids, t, busy)

    def redispatch(t: float) -> None:
        nonlocal starved, wake_backoff
        if starved <= 0:
            return
        elig = vec_policy.eligible(t, scenario.bw_period) & ~state.in_flight
        ids = draw_cohort(elig, starved)
        if len(ids):
            dispatch_wave(ids, t)
            starved -= len(ids)
            wake_backoff = 1.0

    concurrency = min(sim.concurrency or cfg.n_active, n)
    first = draw_cohort(
        vec_policy.eligible(0.0, scenario.bw_period) & ~state.in_flight,
        concurrency)
    if len(first):
        dispatch_wave(first, 0.0)
    starved = concurrency - len(first)
    # same starved-server idle step as the sim's WAKE events: one
    # population-mean full round trip, exponential backoff
    wake_wait = float(np.mean(round_trip_time_vec(
        um, no_mask_row, res_arr, cfg.tau, payload_bytes=total_bytes)))
    wake_backoff = 1.0

    max_waves = 100 * (cfg.rounds * sim.buffer_size + concurrency)
    waves = 0
    while version < cfg.rounds and waves < max_waves:
        waves += 1
        if state.n_inflight == 0:
            # nothing will move the clock: either done starving or idle
            # the server one WAKE step and retry eligibility
            if starved <= 0:
                break
            now += wake_wait * wake_backoff
            wake_backoff = min(wake_backoff * 2.0, 2.0 ** 20)
            if now >= sim.max_sim_time:
                now = min(now, sim.max_sim_time)
                break
            if tr:
                tr.emit(TRACE_WAKE, now)
            redispatch(now)
            continue

        # pop the earliest arrivals that can complete the buffer — the
        # heap pop, batched.  A wave is TIME-HOMOGENEOUS: only arrivals
        # tied at the earliest f64 instant pop together, so every freed
        # slot redispatches at exactly the virtual time the sim would
        # have redispatched it (batching across distinct arrival times
        # would delay early slots to the wave boundary and drift the
        # clock).  Identical-resource populations (uniform, diurnal,
        # measured link classes) tie in whole dispatch generations, which
        # is where the batching wins; continuous per-client resource
        # draws (lognormal, bimodal) degenerate to per-arrival waves —
        # the regime the heap engine already handles.
        need = sim.buffer_size - len(buffer)
        k = min(need, state.n_inflight)
        t_col = np.where(state.in_flight, state.arrival_time, np.inf)
        idx = np.argpartition(t_col, k - 1)[:k]
        idx = idx[np.argsort(t_col[idx], kind="stable")]
        wave_t = float(t_col[idx[0]])
        idx = idx[t_col[idx] == wave_t]
        if wave_t > sim.max_sim_time:
            now = sim.max_sim_time
            break
        now = wave_t

        popped = idx.astype(np.int64)
        dead = state.is_dropout[popped]
        dlv = state.dl_version[popped].copy()
        job_up = state.job_up_bytes[popped].copy()
        job_down = state.job_down_bytes[popped].copy()
        state.free(popped)

        drop_ids = popped[dead]
        if len(drop_ids):
            # downloaded, computed, vanished before upload: downlink waste
            ins.dropouts.add(len(drop_ids))
            state.drop_count[drop_ids] += 1
            ins.wasted_down.add(float(job_down[dead].sum()))
            if tr:
                tr.emit(UPLOAD, now, client=-1, n=int(len(drop_ids)),
                        version=int(dlv[dead][0]), bytes=0.0,
                        status="dropout")

        arr_ids = popped[~dead]
        arr_dlv = dlv[~dead]
        arr_up = job_up[~dead]
        arr_down = job_down[~dead]
        masks_v = [ledger.get(int(v)) for v in arr_dlv]
        miss = np.asarray([m is None for m in masks_v], bool)
        if miss.any():
            # dispatch mask evicted: reject outright, charge the spent
            # uplink at its nominal price (attributed per unit
            # proportionally to size — see module docstring) and the
            # fruitless broadcast leg
            n_miss = int(miss.sum())
            ins.misses.add(n_miss)
            ins.uplinks.add(n_miss)
            up_b = float(arr_up[miss].sum())
            ins.up.add(up_b)
            ins.wasted_up.add(up_b)
            res.wasted_per_unit += sizes * (up_b / total_bytes)
            ins.wasted_down.add(float(arr_down[miss].sum()))
            if tr:
                tr.emit(UPLOAD, now, client=-1, n=n_miss, bytes=up_b,
                        status="rejected")
            keep = ~miss
            arr_ids, arr_dlv = arr_ids[keep], arr_dlv[keep]
            arr_up, arr_down = arr_up[keep], arr_down[keep]
            masks_v = [m for m in masks_v if m is not None]

        if len(arr_ids):
            a = len(arr_ids)
            # one vmapped train + encode call for the whole wave; each
            # arrival starts from the snapshot of ITS downloaded version
            starts = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[param_ring.get(int(v)) for v in arr_dlv])
            sel = np.stack([
                rng.choice(parts if isinstance(parts, np.ndarray)
                           else parts[int(c)],
                           size=(cfg.tau, cfg.batch_size), replace=True)
                for c in arr_ids])
            batches = {kk: jnp.asarray(arr[sel]) for kk, arr in data.items()}
            key, sub = jax.random.split(key)
            with tele.span("client_step", jitted=True):
                raw = trainer(starts, batches)
                enc, aux = encode_wave(raw, jax.random.split(sub, a))
            ins.uplinks.add(a)
            ins.accepted.add(a)
            up_wave = 0.0
            for j in range(a):
                mask_j = masks_v[j]
                aux_j = tuple(None if x is None else np.asarray(x)[j]
                              for x in aux)
                with tele.span("pricing"):
                    per_unit = pipeline.price_per_unit(sizes, mask_j, aux_j)
                up_wave += float(per_unit.sum())
                stal = version - int(arr_dlv[j])
                ins.staleness.observe(stal)
                delta_j = jax.tree.map(lambda x, j=j: x[j], enc)
                buffer.append((delta_j, stal, ~mask_j, per_unit,
                               float(arr_down[j]), 1.0))
            ins.up.add(up_wave)
            if tr:
                tr.emit(UPLOAD, now, client=-1, n=a, bytes=up_wave,
                        status="accepted")

            if len(buffer) >= sim.buffer_size:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *[b[0] for b in buffer])
                stal_arr = jnp.asarray([b[1] for b in buffer], jnp.int32)
                valid_np = np.stack([b[2] for b in buffer])
                valid_arr = jnp.asarray(valid_np)
                alpha_t = (_schedule_alpha(alpha, observed,
                                           sim.staleness_window)
                           if sim.adaptive_alpha else alpha)
                res.alphas.append(alpha_t)
                with tele.span("aggregate", jitted=True):
                    params, luar_state, server_state = agg_fn(
                        params, luar_state, server_state, stacked,
                        stal_arr, valid_arr, jnp.float32(alpha_t))
                n_merged = len(buffer)
                buffer.clear()
                version += 1
                ins.rounds.inc()
                if tr:
                    tr.emit(AGGREGATE, now, version=version, n=n_merged,
                            alpha=float(alpha_t),
                            recycled=[int(i) for i in
                                      np.flatnonzero(~np.any(valid_np,
                                                             axis=0))])
                if eval_fn is not None and (version % cfg.eval_every == 0
                                            or version == cfg.rounds):
                    with tele.span("eval"):
                        metrics = dict(eval_fn(params))
                    metrics.update(
                        round=version, t_sim=now,
                        up_mb=ins.up.value / 1e6,
                        comm_ratio=ins.up.value / max(
                            total_bytes * ins.uplinks.value, 1.0),
                        down_ratio=ins.down.value / max(
                            total_bytes * ins.dispatches.value, 1.0))
                    res.history.append(metrics)

        starved += len(popped)
        redispatch(now)

    # truncated-run accounting, exactly the sim's: stranded buffer
    # entries charge their unmerged payload + broadcast leg; in-flight
    # dispatches charge their broadcast leg
    res.n_stranded_end = len(buffer)
    for _, _, _, uncharged, down_b, _ in buffer:
        res.wasted_per_unit += uncharged
        ins.wasted_up.add(float(uncharged.sum()))
        ins.wasted_down.add(down_b)
    res.n_inflight_end = state.n_inflight
    ins.wasted_down.add(float(state.job_down_bytes[state.in_flight].sum()))
    m = tele.metrics
    m.gauge(M_STRANDED_END, "accepted uploads stranded in a partial "
            "buffer at finish").set(res.n_stranded_end)
    m.gauge(M_INFLIGHT_END, "dispatches still in flight at finish").set(
        res.n_inflight_end)
    ins.finalize(m, res, total_bytes, now, state.part_count,
                 state.drop_count)
    res.staleness_observed = np.asarray(observed, np.int32)
    res.staleness_q = _staleness_quantiles(observed)
    res.params = params
    res.luar_state = luar_state
    if tr:
        tr.emit(RUN_END, now, version=version, uploaded=ins.up.value,
                downloaded=ins.down.value, comm_ratio=res.comm_ratio,
                n_events=waves)
    return res
