"""FEMNIST-class CNN (the paper's FL workload: 2 conv + 2 FC = 4 LUAR
layer-units, matching Table 11's delta in {0..3} out of 4) plus a small
MLP for fast unit tests.  Pure JAX, channels-last."""
from __future__ import annotations
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as nn

Params = dict[str, Any]


def cnn_init(key, n_classes: int = 62, in_ch: int = 1, width: int = 32) -> Params:
    ks = nn.split_keys(key, 4)
    f32 = jnp.float32
    return {
        "conv1": {"w": nn.dense_init(ks[0], (5, 5, in_ch, width), f32, 0.1),
                  "b": jnp.zeros((width,), f32)},
        "conv2": {"w": nn.dense_init(ks[1], (5, 5, width, 2 * width), f32, 0.1),
                  "b": jnp.zeros((2 * width,), f32)},
        "fc1": {"w": nn.dense_init(ks[2], (7 * 7 * 2 * width, 128), f32, 0.05),
                "b": jnp.zeros((128,), f32)},
        "fc2": {"w": nn.dense_init(ks[3], (128, n_classes), f32, 0.05),
                "b": jnp.zeros((n_classes,), f32)},
    }


def _conv(x, p):
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(out + p["b"])


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def cnn_apply(params: Params, images: jax.Array) -> jax.Array:
    """images (B, 28, 28, C) -> logits (B, n_classes)."""
    x = _pool(_conv(images, params["conv1"]))
    x = _pool(_conv(x, params["conv2"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def mlp_init(key, n_features: int = 64, n_classes: int = 10, width: int = 64) -> Params:
    ks = nn.split_keys(key, 3)
    f32 = jnp.float32
    return {
        "fc1": {"w": nn.dense_init(ks[0], (n_features, width), f32, 0.1),
                "b": jnp.zeros((width,), f32)},
        "fc2": {"w": nn.dense_init(ks[1], (width, width), f32, 0.1),
                "b": jnp.zeros((width,), f32)},
        "fc3": {"w": nn.dense_init(ks[2], (width, n_classes), f32, 0.1),
                "b": jnp.zeros((n_classes,), f32)},
    }


def mlp_apply(params: Params, x: jax.Array) -> jax.Array:
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)
