"""Mixture-of-Experts decoders: Mixtral-8x7B (GQA + SWA, 8e top-2) and
DeepSeek-V2-Lite (MLA compressed KV + 2 shared + 64 routed top-6, first
layer dense).

Routing uses TPU-idiomatic capacity-based einsum dispatch (tokens beyond
an expert's capacity are dropped) — the MaxText approach — rather than a
ragged gather.  The expert dimension shards on the mesh 'model' axis when
E divides it (DeepSeek: EP-16); otherwise expert weights are TP-sharded
on the ffn dim (Mixtral).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import policy as _policy
from repro.models import layers as nn

Params = dict[str, Any]


def capacity(cfg: ModelConfig, S: int) -> int:
    c = int(math.ceil(cfg.top_k * S * cfg.moe_capacity_factor / cfg.n_experts))
    return max(1, min(c, S * cfg.top_k))


# ---------------------------------------------------------------------------
# routed expert layer
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig) -> Params:
    d, E, ffe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = nn.split_keys(key, 5)
    p = {
        "router": nn.dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": nn.dense_init(ks[1], (E, d, ffe), cfg.dtype),
        "w_up": nn.dense_init(ks[2], (E, d, ffe), cfg.dtype),
        "w_down": nn.dense_init(ks[3], (E, ffe, d), cfg.dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = nn.mlp_init(ks[4], d, cfg.n_shared_experts * ffe, cfg.dtype)
    return p


def router_probs(p: Params, x: jax.Array, cfg: ModelConfig):
    """Top-k routing.  Returns (gates (B,S,k) f32 renormalised, idx (B,S,k))
    plus the aux load-balance loss."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.clip(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (mean prob * mean assignment rate)
    E = cfg.n_experts
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E), axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch.  x: (B,S,d) -> (y, aux_loss).

    Under a distribution policy with a sequence axis the shard_map
    *group-wise* path runs instead: routing/capacity are computed per
    sequence shard (capacity C scales with the shard length, not the full
    S, so the dispatch tensors shrink by the axis size) and the expert
    compute is exchanged with an all-to-all (EP, DeepSeek) or combined
    with a psum (ffn-TP, Mixtral).  See EXPERIMENTS.md §Perf H2/H3."""
    from repro.launch import policy as _pol
    pol = _pol.active()
    if pol is not None and pol.seq_axis is not None and pol.ep_axis:
        n = pol.axis_size(pol.ep_axis)
        if n > 1 and x.shape[1] % n == 0:
            return _moe_shardmap(p, cfg, x, pol)
    return _moe_dense(p, cfg, x)


def _dispatch_combine(cfg: ModelConfig, gates, idx, S: int, C: int, dtype):
    """Build (B,S,E,C) dispatch/combine one-hot tensors."""
    B = gates.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    # position bookkeeping in f32 (cumsum), but the big (B,S*k,E,C)
    # one-hots are built directly in the compute dtype — halves the HBM
    # traffic of the dispatch path (EXPERIMENTS.md §Perf H3 iteration 2)
    mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (B,S,k,E)
    mask_f = mask.reshape(B, S * k, E)
    pos = jnp.cumsum(mask_f, axis=1) - mask_f                     # slot within expert
    keep = (mask_f * (pos < C)).astype(dtype)
    disp = jax.nn.one_hot(pos, C, dtype=dtype) * keep[..., None]  # (B,S*k,E,C)
    comb = disp * gates.reshape(B, S * k)[..., None, None].astype(dtype)
    disp = disp.reshape(B, S, k, E, C).sum(axis=2)                # (B,S,E,C)
    comb = comb.reshape(B, S, k, E, C).sum(axis=2)
    return disp, comb


def _expert_ffn(xe, w_gate, w_up, w_down):
    h = nn.silu(jnp.einsum("becd,edf->becf", xe, w_gate))
    h = h * jnp.einsum("becd,edf->becf", xe, w_up)
    return jnp.einsum("becf,efd->becd", h, w_down)


def _moe_dense(p: Params, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference (single-host / no-policy) path: global routing."""
    B, S, d = x.shape
    C = capacity(cfg, S)
    gates, idx, aux = router_probs(p, x, cfg)
    disp, comb = _dispatch_combine(cfg, gates, idx, S, C, x.dtype)
    xe = jnp.einsum("bsec,bsd->becd", disp, x)                    # (B,E,C,d)
    ye = _expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])
    y = jnp.einsum("becd,bsec->bsd", ye, comb)
    if "shared" in p:
        y = y + nn.mlp_apply(p["shared"], x)
    return y, aux


def _moe_shardmap(p: Params, cfg: ModelConfig, x: jax.Array, pol) -> tuple[jax.Array, jax.Array]:
    """Group-wise routed MoE under shard_map (tokens sequence-sharded)."""
    import jax.experimental.shard_map as _shmap
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E = cfg.n_experts
    axis = pol.ep_axis
    n = pol.axis_size(axis)
    ep = E % n == 0

    bsz = 1
    for a in pol.batch_axes:
        bsz *= pol.axis_size(a)
    bspec = pol.batch_axes if (bsz > 1 and B % bsz == 0 and B >= bsz) else None
    xspec = P(bspec, pol.seq_axis, None)
    if ep:
        wspec = {"w_gate": P(axis, None, None), "w_up": P(axis, None, None),
                 "w_down": P(axis, None, None)}
    else:
        # E does not divide the axis (Mixtral 8e on 16-way 'model'):
        # ffe-BLOCK parallelism — every rank holds a ffe/n slice of every
        # expert (matches the stored layout, no weight movement), the
        # dispatched slots are all-gathered across the sequence shards and
        # the partial outputs psum_scatter back.  NB (i) a plain ffn-TP
        # psum would be UNSOUND (model-axis peers hold different
        # sequence-sharded tokens; caught by tests/test_distributed.py);
        # (ii) re-virtualising experts to expert-major EP makes GSPMD
        # fully rematerialise the weights (refuted — EXPERIMENTS.md §Perf
        # H2 iteration 2).
        wspec = {"w_gate": P(None, None, axis), "w_up": P(None, None, axis),
                 "w_down": P(None, axis, None)}
    shared = p.get("shared", {})
    shared_spec = jax.tree.map(lambda a: P(*([None] * a.ndim)), shared)

    def local_fn(x_l, router, w_gate, w_up, w_down, shared_l):
        Bl, Sl, _ = x_l.shape
        C = capacity(cfg, Sl)
        gates, idx, aux = router_probs({"router": router}, x_l, cfg)
        aux = jax.lax.pmean(aux, axis)
        disp, comb = _dispatch_combine(cfg, gates, idx, Sl, C, x_l.dtype)
        xe = jnp.einsum("bsec,bsd->becd", disp, x_l)              # (B,E,C,d)
        if ep:
            # EP: exchange token groups so each shard holds its experts'
            # tokens from every sequence shard
            xe = jax.lax.all_to_all(xe, axis, split_axis=1, concat_axis=2,
                                    tiled=True)                    # (B,E/n,C*n,d)
            ye = _expert_ffn(xe, w_gate, w_up, w_down)
            ye = jax.lax.all_to_all(ye, axis, split_axis=2, concat_axis=1,
                                    tiled=True)                    # (B,E,C,d)
        else:
            # ffe-block parallel: gather every shard's slots, compute the
            # local ffe-slice for all of them, psum_scatter the partials
            xe = jax.lax.all_gather(xe, axis, axis=2, tiled=True)  # (B,E,C*n,d)
            ye = _expert_ffn(xe, w_gate, w_up, w_down)
            ye = jax.lax.psum_scatter(ye, axis, scatter_dimension=2,
                                      tiled=True)                  # (B,E,C,d)
        y = jnp.einsum("becd,bsec->bsd", ye, comb)
        if shared:
            # branch on the closed-over params dict (static structure),
            # not the traced shard_map parameter `shared_l`
            y = y + nn.mlp_apply(shared_l, x_l)
        return y, aux

    fn = _shmap.shard_map(
        local_fn, mesh=pol.mesh,
        in_specs=(xspec, P(None, None), wspec["w_gate"], wspec["w_up"],
                  wspec["w_down"], shared_spec),
        out_specs=(xspec, P()),
        check_rep=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rp = cfg.kv_lora_rank, cfg.rope_head_dim
    ks = nn.split_keys(key, 6)
    return {
        "wq": nn.dense_init(ks[0], (d, H * (hd + rp)), cfg.dtype),
        "w_dkv": nn.dense_init(ks[1], (d, r), cfg.dtype),
        "w_kpe": nn.dense_init(ks[2], (d, rp), cfg.dtype),
        "w_uk": nn.dense_init(ks[3], (r, H * hd), cfg.dtype),
        "w_uv": nn.dense_init(ks[4], (r, H * hd), cfg.dtype),
        "wo": nn.dense_init(ks[5], (H * hd, d), cfg.dtype),
    }


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    H, hd, rp = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd + rp)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = nn.rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_ckv(p, cfg, x, positions):
    c_kv = x @ p["w_dkv"]                                          # (B,S,r)
    k_pe = (x @ p["w_kpe"])[:, :, None, :]                         # (B,S,1,rp)
    k_pe = nn.rope(k_pe, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe


def mla_apply(p: Params, cfg: ModelConfig, x: jax.Array):
    """Train/prefill path: expand the compressed KV per token (each token
    pays the up-projection once).  Returns (out, c_kv, k_pe) so prefill can
    cache the *compressed* KV."""
    B, S, _ = x.shape
    H, hd, rp = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    pos = jnp.arange(S)
    q_nope, q_pe = _mla_q(p, cfg, x, pos)
    c_kv, k_pe = _mla_ckv(p, cfg, x, pos)
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, hd)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, hd)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, S, H, rp))], axis=-1)
    o = nn.attention(q, k, v)
    out = o.reshape(B, S, H * hd) @ p["wo"]
    return out, c_kv, k_pe


def mla_decode(p: Params, cfg: ModelConfig, x: jax.Array,
               ckv_cache: jax.Array, kpe_cache: jax.Array, pos: jax.Array):
    """Absorbed decode: score against the compressed cache directly —
    O(S·r) per head instead of re-expanding the 32k cache each step."""
    B = x.shape[0]
    H, hd, rp, r = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora_rank
    q_nope, q_pe = _mla_q(p, cfg, x, pos[None])
    c_kv, k_pe = _mla_ckv(p, cfg, x, pos[None])
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_kv.astype(ckv_cache.dtype), pos, axis=1)
    kpe_cache = jax.lax.dynamic_update_slice_in_dim(
        kpe_cache, k_pe.astype(kpe_cache.dtype), pos, axis=1)

    w_uk = p["w_uk"].reshape(r, H, hd)
    q_c = jnp.einsum("bqhd,rhd->bhqr", q_nope, w_uk)              # absorb W_uk
    s = jnp.einsum("bhqr,bsr->bhqs", q_c.astype(jnp.float32),
                   ckv_cache.astype(jnp.float32))
    s = s + jnp.einsum("bqhp,bsp->bhqs", q_pe.astype(jnp.float32),
                       kpe_cache.astype(jnp.float32))
    s = s / math.sqrt(hd + rp)
    k_pos = jnp.arange(ckv_cache.shape[1])
    s = jnp.where((k_pos <= pos)[None, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", pr.astype(ckv_cache.dtype), ckv_cache)
    w_uv = p["w_uv"].reshape(r, H, hd)
    o = jnp.einsum("bhqr,rhd->bqhd", ctx, w_uv).reshape(B, 1, H * hd)
    return o @ p["wo"], ckv_cache, kpe_cache


# ---------------------------------------------------------------------------
# blocks / model
# ---------------------------------------------------------------------------




def _gather_block(p: Params) -> Params:
    """ZeRO-3 gather for a MoE block: attention/norm/shared weights are
    gathered at use; routed expert stacks stay sharded (EP handles them —
    gathering 64 experts would defeat expert parallelism)."""
    if _policy.active() is None:
        return p
    out = dict(p)
    for k in ("attn", "norm1", "norm2", "mlp"):
        if k in out:
            out[k] = _policy.gather_params(out[k])
    if "moe" in out:
        moe_p = dict(out["moe"])
        for k in ("router", "shared"):
            if k in moe_p:
                moe_p[k] = _policy.gather_params(moe_p[k])
        out["moe"] = moe_p
    return out


def _attn_init(key, cfg):
    return mla_init(key, cfg) if cfg.kv_lora_rank else nn.attn_init(key, cfg)


def block_init(key, cfg: ModelConfig, dense_ffn: bool = False) -> Params:
    ks = nn.split_keys(key, 2)
    p = {
        "attn": _attn_init(ks[0], cfg),
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if dense_ffn:
        p["mlp"] = nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype, cfg.gated_mlp)
    else:
        p["moe"] = moe_init(ks[1], cfg)
    return p


def init(key, cfg: ModelConfig) -> Params:
    n_dense = 1 if cfg.first_layer_dense else 0
    ks = nn.split_keys(key, cfg.n_layers + 2)
    p: Params = {"embed": nn.embed_init(ks[-1], cfg),
                 "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype)}
    if n_dense:
        p["layer0"] = block_init(ks[0], cfg, dense_ffn=True)
    blocks = [block_init(k, cfg) for k in ks[n_dense: cfg.n_layers]]
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return p


def _ffn(p: Params, cfg: ModelConfig, x: jax.Array):
    if "mlp" in p:
        return nn.mlp_apply(p["mlp"], x), jnp.zeros((), jnp.float32)
    return moe_apply(p["moe"], cfg, x)


def _block(cfg: ModelConfig, p: Params, x: jax.Array, aux: jax.Array):
    p = _gather_block(p)
    h = nn.rms_norm(x, p["norm1"])
    if cfg.kv_lora_rank:
        o, _, _ = mla_apply(p["attn"], cfg, h)
    else:
        o = nn.attn_apply(p["attn"], cfg, h, window=cfg.window)
    x = x + o
    h = nn.rms_norm(x, p["norm2"])
    y, a = _ffn(p, cfg, h)
    return x + y, aux + a


def train_loss(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array],
               aux_weight: float = 0.01) -> jax.Array:
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    aux = jnp.zeros((), jnp.float32)
    if "layer0" in params:
        x, aux = _block(cfg, params["layer0"], x, aux)

    blk = jax.checkpoint(partial(_block, cfg))

    def body(carry, p):
        x, aux = carry
        x, aux = blk(p, x, aux)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, aux), params["blocks"])
    h = nn.rms_norm(x, params["final_norm"])
    ce = nn.cross_entropy(_policy.gather_params(params["embed"]), h, batch["labels"])
    return ce + aux_weight * aux / cfg.n_layers


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _prefill_block(cfg, p, x):
    """Returns (x, cache_entries) for one block."""
    p = _gather_block(p)
    B, S, _ = x.shape
    h = nn.rms_norm(x, p["norm1"])
    if cfg.kv_lora_rank:
        o, c_kv, k_pe = mla_apply(p["attn"], cfg, h)
        entries = (c_kv, k_pe)
    else:
        q, k, v = nn.attn_qkv(p["attn"], cfg, h, jnp.arange(S))
        o_ = nn.attention(q, k, v, window=cfg.window)
        o = o_.reshape(B, S, -1) @ p["attn"]["wo"]
        entries = (k, v)
    x = x + o
    h = nn.rms_norm(x, p["norm2"])
    y, _ = _ffn(p, cfg, h)
    return x + y, entries


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    params = {**params, "embed": _policy.gather_params(params["embed"])}
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    first = []
    if "layer0" in params:
        x, e0 = _prefill_block(cfg, params["layer0"], x)
        first = [jax.tree.map(lambda a: a[None], e0)]

    def body(carry, p):
        x = carry
        x, entries = _prefill_block(cfg, p, x)
        return x, entries

    x, stacked = jax.lax.scan(jax.checkpoint(partial(body)), x, params["blocks"])
    entries = jax.tree.map(lambda f, s: jnp.concatenate([f, s], axis=0),
                           first[0], stacked) if first else stacked
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h[:, -1:])[:, 0]
    if cfg.kv_lora_rank:
        cache = {"c_kv": entries[0], "k_pe": entries[1]}
    else:
        cache = {"k": entries[0], "v": entries[1]}
    return logits, cache


def _decode_block(cfg, p, x, cache_entries, pos):
    h = nn.rms_norm(x, p["norm1"])
    if cfg.kv_lora_rank:
        o, c1, c2 = mla_decode(p["attn"], cfg, h, cache_entries[0], cache_entries[1], pos)
    else:
        o, c1, c2 = nn.attn_decode(p["attn"], cfg, h, cache_entries[0], cache_entries[1],
                                   pos, window=cfg.window)
    x = x + o
    h = nn.rms_norm(x, p["norm2"])
    y, _ = _ffn(p, cfg, h)
    return x + y, (c1, c2)


def decode_step(params: Params, cfg: ModelConfig, cache: dict[str, jax.Array],
                batch: dict[str, jax.Array]):
    token, pos = batch["token"], batch["pos"]
    names = ("c_kv", "k_pe") if cfg.kv_lora_rank else ("k", "v")
    c1, c2 = cache[names[0]], cache[names[1]]
    x = nn.embed_lookup(params["embed"], token)
    off = 0
    firsts = None
    if "layer0" in params:
        x, e0 = _decode_block(cfg, params["layer0"], x, (c1[0], c2[0]), pos)
        firsts = jax.tree.map(lambda a: a[None], e0)
        off = 1

    def body(carry, xs):
        p, e1, e2 = xs
        x = carry
        x, entries = _decode_block(cfg, p, x, (e1, e2), pos)
        return x, entries

    x, stacked = jax.lax.scan(body, x, (params["blocks"], c1[off:], c2[off:]))
    if firsts is not None:
        stacked = jax.tree.map(lambda f, s: jnp.concatenate([f, s], axis=0), firsts, stacked)
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h)[:, 0]
    return logits, {names[0]: stacked[0], names[1]: stacked[1]}
