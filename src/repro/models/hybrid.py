"""Zamba2-style hybrid: a Mamba2 backbone with ONE weight-shared
attention+MLP block applied after every ``attn_every`` mamba blocks
(arXiv:2411.15242).  The shared block is weight-tied across all of its
applications (the per-application LoRA of the paper is omitted — see
DESIGN.md §7), which makes it a single LUAR recycling unit whose update
aggregates gradients from all application sites.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import policy as _policy
from repro.models import layers as nn
from repro.models import ssm
from repro.models.transformer import _tree_slice, block_init as attn_block_init

Params = dict[str, Any]


def attn_sites(cfg: ModelConfig) -> list[int]:
    """Mamba-layer indices after which the shared block is applied."""
    return [i for i in range(cfg.n_layers) if (i + 1) % cfg.attn_every == 0]


def init(key, cfg: ModelConfig) -> Params:
    ks = nn.split_keys(key, cfg.n_layers + 2)
    blocks = [ssm.block_init(k, cfg) for k in ks[: cfg.n_layers]]
    return {
        "embed": nn.embed_init(ks[-1], cfg),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "shared_attn": attn_block_init(ks[-2], cfg),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def _shared_block(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    p = _policy.gather_params(p)
    h = nn.rms_norm(x, p["norm1"])
    x = x + nn.attn_apply(p["attn"], cfg, h)
    h = nn.rms_norm(x, p["norm2"])
    return x + nn.mlp_apply(p["mlp"], h)


def _segments(cfg: ModelConfig) -> list[tuple[int, int, bool]]:
    """[(start, length, attn_after)] — static segmentation of the stack."""
    out, start = [], 0
    for site in attn_sites(cfg):
        out.append((start, site + 1 - start, True))
        start = site + 1
    if start < cfg.n_layers:
        out.append((start, cfg.n_layers - start, False))
    return out


def forward(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    blk = jax.checkpoint(partial(ssm.block_apply, cfg=cfg))
    for start, length, attn_after in _segments(cfg):
        def body(carry, p):
            out, _ = blk(p, x=carry)
            return out, None
        x, _ = jax.lax.scan(body, x, _tree_slice(params["blocks"], start, length))
        if attn_after:
            x = jax.checkpoint(partial(_shared_block, cfg=cfg))(params["shared_attn"], x=x)
    return nn.rms_norm(x, params["final_norm"])


def train_loss(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    h = forward(params, cfg, x)
    return nn.cross_entropy(_policy.gather_params(params["embed"]), h, batch["labels"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    B, S, _ = x.shape
    W = cfg.conv_width
    ssm_states, conv_tails, ks, vs = [], [], [], []
    for start, length, attn_after in _segments(cfg):
        def body(carry, p):
            x = carry
            h = nn.rms_norm(x, p["norm_in"])
            _, xbc, _ = ssm._split_proj(cfg, h @ p["in_proj"])
            tail = xbc[:, -(W - 1):, :]
            out, state = ssm.block_apply(p, cfg, x)
            return out, (state, tail)
        x, (st, tl) = jax.lax.scan(jax.checkpoint(body), x,
                                   _tree_slice(params["blocks"], start, length))
        ssm_states.append(st)
        conv_tails.append(tl)
        if attn_after:
            sp = params["shared_attn"]
            h = nn.rms_norm(x, sp["norm1"])
            q, k, v = nn.attn_qkv(sp["attn"], cfg, h, jnp.arange(S))
            o = nn.attention(q, k, v)
            x = x + o.reshape(B, S, -1) @ sp["attn"]["wo"]
            h = nn.rms_norm(x, sp["norm2"])
            x = x + nn.mlp_apply(sp["mlp"], h)
            ks.append(k)
            vs.append(v)
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h[:, -1:])[:, 0]
    return logits, {
        "ssm": jnp.concatenate(ssm_states, axis=0),
        "conv": jnp.concatenate(conv_tails, axis=0),
        "k": jnp.stack(ks), "v": jnp.stack(vs),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: dict[str, jax.Array],
                batch: dict[str, jax.Array]):
    token, pos = batch["token"], batch["pos"]
    x = nn.embed_lookup(params["embed"], token)
    convs, ssms, new_k, new_v = [], [], [], []
    app = 0
    for start, length, attn_after in _segments(cfg):
        def body(carry, xs):
            p, conv, st = xs
            x = carry
            x, conv, st = ssm.block_decode(p, cfg, x, conv, st)
            return x, (conv, st)
        xs = (_tree_slice(params["blocks"], start, length),
              jax.lax.slice_in_dim(cache["conv"], start, start + length, axis=0),
              jax.lax.slice_in_dim(cache["ssm"], start, start + length, axis=0))
        x, (conv, st) = jax.lax.scan(body, x, xs)
        convs.append(conv)
        ssms.append(st)
        if attn_after:
            sp = params["shared_attn"]
            h = nn.rms_norm(x, sp["norm1"])
            o, kc, vc = nn.attn_decode(sp["attn"], cfg, h,
                                       cache["k"][app], cache["v"][app], pos)
            x = x + o
            h = nn.rms_norm(x, sp["norm2"])
            x = x + nn.mlp_apply(sp["mlp"], h)
            new_k.append(kc)
            new_v.append(vc)
            app += 1
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h)[:, 0]
    return logits, {
        "ssm": jnp.concatenate(ssms, axis=0),
        "conv": jnp.concatenate(convs, axis=0),
        "k": jnp.stack(new_k), "v": jnp.stack(new_v),
    }
