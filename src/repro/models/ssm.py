"""Mamba2 (SSD — state-space duality, arXiv:2405.21060), attention-free.

The chunked SSD algorithm: within a chunk the quadratic dual form runs on
the MXU; across chunks a (cheap) recurrence carries the (nh, P, N) state.
``ssd_chunked`` is the pure-jnp reference the Pallas kernel is validated
against; decode is the O(1) recurrent step.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as nn

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# SSD core (reference implementation; kernels/ssd_scan.py mirrors this)
# ---------------------------------------------------------------------------


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, D: jax.Array,
                chunk: int, initial_state: jax.Array | None = None):
    """SSD over a full sequence.

    x  : (B, S, nh, P)   per-head inputs
    dt : (B, S, nh)      post-softplus step sizes
    A  : (nh,)           negative decay rates
    Bm : (B, S, N)       input projections  (n_groups = 1, shared over heads)
    Cm : (B, S, N)       output projections
    D  : (nh,)           skip
    Returns (y (B,S,nh,P), final_state (B,nh,P,N)).
    """
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    T = min(chunk, S)
    if S % T != 0:
        T = S
    nc = S // T
    f32 = jnp.float32

    xc = x.reshape(Bsz, nc, T, nh, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, T, nh).astype(f32)
    Bc = Bm.reshape(Bsz, nc, T, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, T, N).astype(f32)

    a = dtc * A.astype(f32)                                        # (B,nc,T,nh) <= 0
    cum = jnp.cumsum(a, axis=2)                                    # inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (B,nc,Ti,Tj,nh)
    tri = jnp.tril(jnp.ones((T, T), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (dual quadratic form)
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)                 # (B,nc,Ti,Tj)
    W = scores[..., None] * L * dtc[:, :, None, :, :]              # (B,nc,Ti,Tj,nh)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", W, xc)

    # chunk-local end states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,T,nh)
    Sc = jnp.einsum("bcth,bctn,bcthp->bchpn",
                    decay_to_end * dtc, Bc, xc)                    # (B,nc,nh,P,N)

    # inter-chunk recurrence
    total = jnp.exp(cum[:, :, -1, :])                              # (B,nc,nh)
    s0 = (jnp.zeros((Bsz, nh, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(s, inp):
        tot, sc = inp                                              # (B,nh), (B,nh,P,N)
        s_out = s                                                  # state entering chunk
        s = tot[..., None, None] * s + sc
        return s, s_out

    final, s_in = jax.lax.scan(step, s0, (jnp.moveaxis(total, 1, 0),
                                          jnp.moveaxis(Sc, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                                # (B,nc,nh,P,N)

    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp",
                         Cc, jnp.exp(cum), s_in)
    y = y_intra + y_inter + D.astype(f32)[None, None, None, :, None] * xc
    return y.reshape(Bsz, S, nh, P).astype(x.dtype), final


def ssd_decode(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
               Bm: jax.Array, Cm: jax.Array, D: jax.Array):
    """One recurrent step.  state (B,nh,P,N), x (B,nh,P), dt (B,nh),
    Bm/Cm (B,N).  Returns (y (B,nh,P), new state)."""
    f32 = jnp.float32
    xf, dtf = x.astype(f32), dt.astype(f32)
    a = jnp.exp(dtf * A.astype(f32))                               # (B,nh)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bm.astype(f32))
    state = a[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(f32))
    y = y + D.astype(f32)[None, :, None] * xf
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig) -> Params:
    d, di, N, nh, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.conv_width)
    ks = nn.split_keys(key, 4)
    return {
        "norm_in": jnp.zeros((d,), cfg.dtype),
        "in_proj": nn.dense_init(ks[0], (d, 2 * di + 2 * N + nh), cfg.dtype),
        "conv_w": nn.dense_init(ks[1], (w, di + 2 * N), cfg.dtype, scale=0.5),
        "conv_b": jnp.zeros((di + 2 * N,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),             # softplus ~ 0.01
        "norm_gate": jnp.zeros((di,), cfg.dtype),
        "out_proj": nn.dense_init(ks[2], (di, d), cfg.dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  xbc (B,S,C), w (w,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :],                                        # (w, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return nn.silu(out + b)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xbc, dt


def block_apply(p: Params, cfg: ModelConfig, x: jax.Array,
                initial_state=None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence mamba2 block.  Returns (out, final_ssm_state)."""
    from repro.launch import policy as _pol
    p = _pol.gather_params(p)
    B, S, _ = x.shape
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = nn.rms_norm(x, p["norm_in"])
    z, xbc, dt = _split_proj(cfg, h @ p["in_proj"])
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xi = xbc[..., :di].reshape(B, S, nh, P)
    Bm, Cm = xbc[..., di: di + N], xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    # under a distribution policy the per-head SSD scan is head-sharded
    # (fully local recurrence; B/C are n_groups=1 and stay replicated)
    from repro.launch import policy as _policy
    pol = _policy.active()
    if pol is not None and pol.head_axis and nh % pol.axis_size(pol.head_axis) == 0:
        bsz = 1
        for a in pol.batch_axes:
            bsz *= pol.axis_size(a)
        bspec = pol.batch_axes if (bsz > 1 and B % bsz == 0 and B >= bsz) else None
        xi = _policy.constrain(xi, bspec, None, pol.head_axis, None)
        dt = _policy.constrain(dt, bspec, None, pol.head_axis)
    y, state = ssd_chunked(xi, dt, A, Bm, Cm, p["D"], cfg.ssm_chunk, initial_state)
    y = y.reshape(B, S, di) * nn.silu(z)
    y = nn.rms_norm(y, p["norm_gate"])
    return x + y @ p["out_proj"], state


def block_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                 conv_state: jax.Array, ssm_state: jax.Array):
    """One-token step.  x (B,1,d); conv_state (B,w-1,C); ssm (B,nh,P,N)."""
    B = x.shape[0]
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = nn.rms_norm(x, p["norm_in"])
    z, xbc, dt = _split_proj(cfg, h @ p["in_proj"])                # (B,1,*)
    window = jnp.concatenate([conv_state, xbc.astype(conv_state.dtype)], axis=1)
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc_t = nn.silu(conv).astype(x.dtype)                          # (B,C)
    xi = xbc_t[..., :di].reshape(B, nh, P)
    Bm, Cm = xbc_t[..., di: di + N], xbc_t[..., di + N:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_decode(ssm_state, xi, dtv, A, Bm, Cm, p["D"])
    y = y.reshape(B, 1, di) * nn.silu(z)
    y = nn.rms_norm(y, p["norm_gate"])
    return x + y @ p["out_proj"], window[:, 1:], ssm_state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig) -> Params:
    ks = nn.split_keys(key, cfg.n_layers + 1)
    blocks = [block_init(k, cfg) for k in ks[: cfg.n_layers]]
    return {
        "embed": nn.embed_init(ks[-1], cfg),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def forward(params: Params, cfg: ModelConfig, x: jax.Array,
            collect_states: bool = False):
    blk = jax.checkpoint(partial(block_apply, cfg=cfg))

    def body(carry, p):
        out, state = blk(p, x=carry)
        return out, state if collect_states else None

    x, states = jax.lax.scan(body, x, params["blocks"])
    return nn.rms_norm(x, params["final_norm"]), states


def train_loss(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    from repro.launch import policy as _pol
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    h, _ = forward(params, cfg, x)
    return nn.cross_entropy(_pol.gather_params(params["embed"]), h, batch["labels"])


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    B, S, _ = x.shape
    C = cfg.d_inner + 2 * cfg.ssm_state
    W = cfg.conv_width

    def body(carry, p):
        x = carry
        h = nn.rms_norm(x, p["norm_in"])
        _, xbc, _ = _split_proj(cfg, h @ p["in_proj"])
        conv_tail = xbc[:, -(W - 1):, :]                           # pre-activation tail
        out, state = block_apply(p, cfg, x)
        return out, (state, conv_tail)

    x, (states, conv_tails) = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h[:, -1:])[:, 0]
    return logits, {"ssm": states, "conv": conv_tails}


def decode_step(params: Params, cfg: ModelConfig, cache: dict[str, jax.Array],
                batch: dict[str, jax.Array]):
    x = nn.embed_lookup(params["embed"], batch["token"])

    def body(carry, xs):
        p, conv, ssm = xs
        x = carry
        x, conv, ssm = block_decode(p, cfg, x, conv, ssm)
        return x, (conv, ssm)

    x, (conv, ssm) = jax.lax.scan(body, x, (params["blocks"], cache["conv"], cache["ssm"]))
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h)[:, 0]
    return logits, {"ssm": ssm, "conv": conv}
