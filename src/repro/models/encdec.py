"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv frontend is a stub per the assignment:
``input_specs`` provides precomputed frame embeddings (B, enc_seq, d).
Encoder blocks are bidirectional; decoder blocks interleave causal
self-attention with cross-attention to the encoder output.  Positions use
sinusoidal embeddings (whisper's learned decoder table is a deviation —
DESIGN.md §7).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import policy as _policy
from repro.models import layers as nn

Params = dict[str, Any]


def sinusoid(S: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(S) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    emb = jnp.zeros((S, d), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


def _enc_block_init(key, cfg):
    ks = nn.split_keys(key, 2)
    return {
        "attn": nn.attn_init(ks[0], cfg),
        "mlp": nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype, cfg.gated_mlp),
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def _dec_block_init(key, cfg):
    ks = nn.split_keys(key, 3)
    return {
        "self_attn": nn.attn_init(ks[0], cfg),
        "cross_attn": nn.attn_init(ks[1], cfg),
        "mlp": nn.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype, cfg.gated_mlp),
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm3": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    ks = nn.split_keys(key, cfg.n_enc_layers + cfg.n_layers + 2)
    enc = [_enc_block_init(k, cfg) for k in ks[: cfg.n_enc_layers]]
    dec = [_dec_block_init(k, cfg) for k in ks[cfg.n_enc_layers: -2]]
    return {
        "embed": nn.embed_init(ks[-1], cfg),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


# -- no-rope attention helpers (whisper uses absolute positions) ------------


def _proj_qkv(p, cfg, x):
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    return q, k, v


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    B, T, d = frames.shape
    x = frames + sinusoid(T, d).astype(frames.dtype)

    def body(carry, p):
        x = carry
        p = _policy.gather_params(p)
        h = nn.rms_norm(x, p["norm1"])
        q, k, v = _proj_qkv(p["attn"], cfg, h)
        o = nn.attention(q, k, v, causal=False)
        x = x + o.reshape(B, T, -1) @ p["attn"]["wo"]
        h = nn.rms_norm(x, p["norm2"])
        return x + nn.mlp_apply(p["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return nn.rms_norm(x, params["enc_norm"])


def _dec_block(cfg, p, x, enc_out, pos_offset=0, self_kv=None, pos=None):
    """Decoder block; full-sequence when self_kv is None, else one-step."""
    p = _policy.gather_params(p)
    B = x.shape[0]
    h = nn.rms_norm(x, p["norm1"])
    if self_kv is None:
        S = x.shape[1]
        q, k, v = _proj_qkv(p["self_attn"], cfg, h)
        o = nn.attention(q, k, v)
        new_kv = (k, v)
    else:
        kc, vc = self_kv
        q, k, v = _proj_qkv(p["self_attn"], cfg, h)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = nn.decode_attention(q, kc, vc, pos)
        new_kv = (kc, vc)
    x = x + o.reshape(B, x.shape[1], -1) @ p["self_attn"]["wo"]
    h = nn.rms_norm(x, p["norm2"])
    q, _, _ = _proj_qkv(p["cross_attn"], cfg, h)
    ck = (enc_out @ p["cross_attn"]["wk"]).reshape(B, enc_out.shape[1], cfg.kv_heads, cfg.hd)
    cv = (enc_out @ p["cross_attn"]["wv"]).reshape(B, enc_out.shape[1], cfg.kv_heads, cfg.hd)
    o = nn.attention(q, ck, cv, causal=False)
    x = x + o.reshape(B, x.shape[1], -1) @ p["cross_attn"]["wo"]
    h = nn.rms_norm(x, p["norm3"])
    return x + nn.mlp_apply(p["mlp"], h), new_kv


def decode_seq(params: Params, cfg: ModelConfig, tokens: jax.Array,
               enc_out: jax.Array, collect_kv: bool = False):
    B, S = tokens.shape
    x = nn.embed_lookup(params["embed"], tokens)
    x = x + sinusoid(S, cfg.d_model).astype(x.dtype)

    def body(carry, p):
        x = carry
        x, kv = _dec_block(cfg, p, x, enc_out)
        return x, kv if collect_kv else None

    x, kvs = jax.lax.scan(jax.checkpoint(body), x, params["dec_blocks"])
    return nn.rms_norm(x, params["final_norm"]), kvs


def train_loss(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    enc_out = encode(params, cfg, batch["enc_frames"])
    h, _ = decode_seq(params, cfg, batch["tokens"], enc_out)
    return nn.cross_entropy(_policy.gather_params(params["embed"]), h, batch["labels"])


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    enc_out = encode(params, cfg, batch["enc_frames"])
    h, kvs = decode_seq(params, cfg, batch["tokens"], enc_out, collect_kv=True)
    logits = nn.unembed_logits(params["embed"], h[:, -1:])[:, 0]
    return logits, {"k": kvs[0], "v": kvs[1], "enc_out": enc_out}


def decode_step(params: Params, cfg: ModelConfig, cache: dict[str, jax.Array],
                batch: dict[str, jax.Array]):
    token, pos = batch["token"], batch["pos"]
    enc_out = cache["enc_out"]
    x = nn.embed_lookup(params["embed"], token)
    x = x + sinusoid(1, cfg.d_model, offset=pos).astype(x.dtype)

    def body(carry, xs):
        p, kc, vc = xs
        x = carry
        x, (kc, vc) = _dec_block(cfg, p, x, enc_out, self_kv=(kc, vc), pos=pos)
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"], cache["v"]))
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h)[:, 0]
    return logits, {"k": k, "v": v, "enc_out": enc_out}
