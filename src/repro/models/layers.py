"""Shared model primitives: norms, RoPE, chunked/banded attention, MLPs.

Everything is pure-functional JAX over nested-dict pytrees.  Attention is
query-chunked (flash-style online softmax is unnecessary here because each
chunk materialises only a (chunk x band) score tile); sliding-window
layers use a *banded* K/V slice so SWA compute is genuinely O(S*w), which
matters for honest roofline numbers on mixtral / gemma3 / zamba2.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    # variance in f32, application in the input dtype: avoids a full f32
    # upcast of the residual stream (XLA hoists that convert out of the
    # backward layer loop, costing an (L,B,S,d) f32 buffer)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd) rotated by positions (S,) or scalar."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs          # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast (S, half) across batch/head dims: x is (..., S, n, hd)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core
# ---------------------------------------------------------------------------


def _attend_tile(q, k, v, q_pos, k_pos, window: int, causal: bool) -> jax.Array:
    """q: (B,Cq,K,G,hd)  k,v: (B,Ck,K,hd)  positions: (Cq,), (Ck,).

    Returns (B,Cq,K,G,hd).  window<=0 means unlimited.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgh,bckh->bkgqc", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    dpos = q_pos[:, None] - k_pos[None, :]                    # (Cq, Ck)
    mask = jnp.ones_like(dpos, dtype=bool)
    if causal:
        mask &= dpos >= 0
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # guard fully-masked rows (can happen for padded tiles)
    p = jnp.where(jnp.any(mask, axis=-1)[None, None, None, :, None], p, 0.0)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p.astype(v.dtype), v)
    return out


_SCORE_BUDGET = 2 ** 31            # ~2 GiB of f32 score tile per chunk


def _pick_chunk(Sq: int, B: int, H: int, Skv: int, chunk: int) -> int:
    """Largest chunk whose (B,H,chunk,Skv) f32 score tile fits the budget."""
    cap = max(1, _SCORE_BUDGET // max(1, B * H * Skv * 4))
    c = min(chunk, cap, Sq)
    c = max(c, 1)
    while Sq % c:
        c -= 1 if c <= 8 else c // 2   # find a divisor
    return max(c, 1)


def attention(
    q: jax.Array,                  # (B, Sq, H, hd)
    k: jax.Array,                  # (B, Skv, K, hd)
    v: jax.Array,                  # (B, Skv, K, hd)
    *,
    q_offset: Any = 0,             # int or traced scalar: position of q[0]
    window: int = 0,               # static sliding window (0 = full)
    causal: bool = True,
    chunk: int = 1024,
) -> jax.Array:
    """Query-chunked (and K/V-banded for SWA) attention.  GQA-aware.

    Under an active distribution policy with a ``seq_axis``, full
    self-attention runs sequence-parallel via shard_map (queries stay
    sequence-sharded; K/V are all-gathered once per layer) — see
    launch/policy.py.
    """
    from repro.launch import policy as _policy

    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    pol = _policy.active()
    if (pol is not None and pol.seq_axis is not None
            and isinstance(q_offset, int) and q_offset == 0 and Sq == Skv):
        n = pol.axis_size(pol.seq_axis)
        if n > 1 and Sq % n == 0:
            return _sp_attention(pol, q, k, v, window=window, causal=causal,
                                 chunk=chunk)
    return _attention_local(q, k, v, q_offset=q_offset, window=window,
                            causal=causal, chunk=chunk)


def _sp_attention(pol, q, k, v, *, window, causal, chunk):
    import jax.experimental.shard_map as _shmap
    from jax.sharding import PartitionSpec as P

    B, Sq, H, hd = q.shape
    n = pol.axis_size(pol.seq_axis)
    baxes = pol.batch_axes
    bsz = 1
    for a in baxes:
        bsz *= pol.axis_size(a)
    bspec = baxes if (bsz > 1 and B % bsz == 0 and B >= bsz) else None
    spec = P(bspec, pol.seq_axis, None, None)

    def local_fn(q_l, k_l, v_l):
        k_full = jax.lax.all_gather(k_l, pol.seq_axis, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_l, pol.seq_axis, axis=1, tiled=True)
        off = jax.lax.axis_index(pol.seq_axis) * (Sq // n)
        return _attention_local(q_l, k_full, v_full, q_offset=off,
                                window=window, causal=causal, chunk=chunk)

    fn = _shmap.shard_map(local_fn, mesh=pol.mesh, in_specs=(spec, spec, spec),
                          out_specs=spec, check_rep=False)
    return fn(q, k, v)


def _attention_local(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_offset: Any = 0,
    window: int = 0,
    causal: bool = True,
    chunk: int = 1024,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)

    cq = _pick_chunk(Sq, B, H, Skv, chunk)
    n_chunks = Sq // cq

    band = Skv if window <= 0 else min(Skv, window + cq)

    def one_chunk(ci):
        qs = ci * cq + q_offset                                 # global pos of chunk
        q_pos = qs + jnp.arange(cq)
        qc = jax.lax.dynamic_slice_in_dim(qg, ci * cq, cq, axis=1)
        if band == Skv:
            kc, vc, k_pos = k, v, jnp.arange(Skv)
        else:
            start = jnp.clip(qs - window, 0, Skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
        return _attend_tile(qc, kc, vc, q_pos, k_pos, window, causal)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        # checkpoint: masks/softmax tiles are recomputed in the backward
        # rather than stacked across chunks as loop residuals
        out = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))  # (n, B, cq, K, G, hdv)
        out = jnp.moveaxis(out, 0, 1)
        out = out.reshape(B, Sq, *out.shape[3:])
    return out.reshape(B, Sq, H, -1)   # hdv may differ from hd (MLA)


def decode_attention(
    q: jax.Array,                  # (B, 1, H, hd)
    k_cache: jax.Array,            # (B, S, K, hd)
    v_cache: jax.Array,
    pos: jax.Array,                # scalar: index of the new token
    window: int = 0,
) -> jax.Array:
    """Single-token attention over a (possibly partially-filled) cache."""
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache, preferred_element_type=jnp.float32)
    s = s * scale
    k_pos = jnp.arange(S)
    mask = k_pos <= pos
    if window > 0:
        mask &= (pos - k_pos) < window
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# attention block (params + apply) shared by dense/vlm/hybrid/encdec
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype=None) -> Params:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    dt = dtype or cfg.dtype
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dt),
        "wk": dense_init(ks[1], (d, K * hd), dt),
        "wv": dense_init(ks[2], (d, K * hd), dt),
        "wo": dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def attn_qkv(p: Params, cfg, x: jax.Array, positions: jax.Array):
    """Project + rope.  x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p: Params, cfg, x: jax.Array, *, window: int = 0,
               causal: bool = True) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = attn_qkv(p, cfg, x, jnp.arange(S))
    out = attention(q, k, v, window=window, causal=causal)
    return out.reshape(B, S, -1) @ p["wo"]


def attn_decode(p: Params, cfg, x: jax.Array, k_cache, v_cache, pos,
                *, window: int = 0):
    """x: (B,1,d).  Returns (out, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    q, k, v = attn_qkv(p, cfg, x, pos[None] if pos.ndim == 0 else pos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    out = decode_attention(q, k_cache, v_cache, pos, window=window)
    return out.reshape(B, 1, -1) @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, ff: int, dtype, gated: bool = True) -> Params:
    ks = split_keys(key, 3)
    p = {"w_up": dense_init(ks[0], (d, ff), dtype),
         "w_down": dense_init(ks[1], (ff, d), dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, ff), dtype)
    return p


def mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        h = silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# embedding / loss
# ---------------------------------------------------------------------------


def embed_init(key, cfg) -> jax.Array:
    return dense_init(key, (cfg.vocab_size, cfg.d_model), cfg.dtype)


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed, tokens, axis=0)


def unembed_logits(embed: jax.Array, x: jax.Array) -> jax.Array:
    """Tied unembedding: (B,S,d) -> (B,S,V)."""
    return jnp.einsum("bsd,vd->bsv", x, embed, preferred_element_type=jnp.float32)


def cross_entropy(embed: jax.Array, x: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None, chunk: int = 512) -> jax.Array:
    """Sequence-chunked CE so (B,S,V) never fully materialises.

    Under a sequence-sharded distribution policy the chunk loop is
    disabled: logits stay (B, S/'model', V) sharded — chunk slices would
    straddle shard boundaries and force GSPMD to replicate them."""
    from repro.launch import policy as _policy

    B, S, _ = x.shape
    pol = _policy.active()
    if pol is not None and pol.seq_axis is not None:
        chunk = S
    cs = chunk if S % chunk == 0 and S > chunk else S
    n = S // cs

    def one(ci):
        xc = jax.lax.dynamic_slice_in_dim(x, ci * cs, cs, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, ci * cs, cs, axis=1)
        logits = unembed_logits(embed, xc)                       # (B,cs,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            mc = jax.lax.dynamic_slice_in_dim(mask, ci * cs, cs, axis=1)
            nll = nll * mc
        return jnp.sum(nll)

    if n == 1:
        tot = one(0)
    else:
        # checkpoint: recompute chunk logits in the backward instead of
        # saving (B,cs,V) f32 per chunk
        tot = jnp.sum(jax.lax.map(jax.checkpoint(one), jnp.arange(n)))
    denom = jnp.sum(mask) if mask is not None else (B * S)
    return tot / denom
