"""Family dispatch: build a functional Model bundle from a ModelConfig."""
from __future__ import annotations
from typing import NamedTuple
from collections.abc import Callable

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, moe, ssm, transformer


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable            # (key) -> params
    train_loss: Callable      # (params, batch) -> scalar
    prefill: Callable         # (params, batch) -> (logits, cache)
    decode_step: Callable     # (params, cache, batch) -> (logits, cache)


_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}


def build(cfg: ModelConfig) -> Model:
    mod = _FAMILY[cfg.family]
    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        train_loss=lambda params, batch: mod.train_loss(params, cfg, batch),
        prefill=lambda params, batch: mod.prefill(params, cfg, batch),
        decode_step=lambda params, cache, batch: mod.decode_step(params, cfg, cache, batch),
    )
