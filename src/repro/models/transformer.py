"""Dense decoder-only transformer (qwen3 / granite / gemma3 / minitron and
the internvl2 LM backbone).

Layers are stacked and scanned.  Architectures with a local:global
attention pattern (gemma3) are split into *segments* of consecutive
layers sharing one static window, so sliding-window layers use the banded
attention path (true O(S*w) compute) while global layers use the full
path — the scan runs per segment.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import policy as _policy
from repro.models import layers as nn

Params = dict[str, Any]


def segments(cfg: ModelConfig) -> list[tuple[int, int, int]]:
    """[(start, length, window)] grouping consecutive equal-window layers."""
    out: list[tuple[int, int, int]] = []
    for i in range(cfg.n_layers):
        w = cfg.layer_window(i)
        if out and out[-1][2] == w:
            s, n, _ = out[-1]
            out[-1] = (s, n + 1, w)
        else:
            out.append((i, 1, w))
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig) -> Params:
    ks = nn.split_keys(key, 2)
    return {
        "attn": nn.attn_init(ks[0], cfg),
        "mlp": nn.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype, cfg.gated_mlp),
        "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    ks = nn.split_keys(key, cfg.n_layers + 2)
    blocks = [block_init(k, cfg) for k in ks[: cfg.n_layers]]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": nn.embed_init(ks[-1], cfg),
        "blocks": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block(cfg: ModelConfig, window: int, p: Params, x: jax.Array) -> jax.Array:
    p = _policy.gather_params(p)          # ZeRO-3: gather weights at use
    h = nn.rms_norm(x, p["norm1"])
    x = x + nn.attn_apply(p["attn"], cfg, h, window=window)
    h = nn.rms_norm(x, p["norm2"])
    x = x + nn.mlp_apply(p["mlp"], h)
    return x


def _tree_slice(tree, start: int, length: int):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0), tree)


def forward(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """(B,S,d) hidden in -> final-normed hidden out."""
    for start, length, window in segments(cfg):
        blk = partial(_block, cfg, window)
        blk = jax.checkpoint(blk)

        def body(carry, p, blk=blk):
            return blk(p, carry), None

        x, _ = jax.lax.scan(body, x, _tree_slice(params["blocks"], start, length))
    return nn.rms_norm(x, params["final_norm"])


def embed_inputs(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]) -> jax.Array:
    x = nn.embed_lookup(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "vis_embeds" in batch:
        # overlay the (stub-frontend) patch embeddings on the first Nv slots
        nv = cfg.n_vis_tokens
        x = jnp.concatenate([batch["vis_embeds"].astype(x.dtype), x[:, nv:]], axis=1)
    return x


def train_loss(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]) -> jax.Array:
    # the token-lookup keeps the FSDP-sharded embed (its scatter-add grad
    # then stays sharded); only the CE unembed gathers a replicated copy
    x = embed_inputs(params, cfg, batch)
    h = forward(params, cfg, x)
    mask = None
    if cfg.family == "vlm":
        B, S = batch["tokens"].shape
        mask = (jnp.arange(S) >= cfg.n_vis_tokens)[None, :] * jnp.ones((B, 1))
    return nn.cross_entropy(_policy.gather_params(params["embed"]), h,
                            batch["labels"], mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, batch: dict[str, jax.Array]):
    """Full forward that also materialises the KV cache.

    Returns (last-token logits (B,V), cache {k,v: (L,B,S,K,hd)}).
    """
    params = {**params, "embed": _policy.gather_params(params["embed"])}
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    ks, vs = [], []
    for start, length, window in segments(cfg):
        def body(carry, p, window=window):
            p = _policy.gather_params(p)
            h = nn.rms_norm(carry, p["norm1"])
            q, k, v = nn.attn_qkv(p["attn"], cfg, h, jnp.arange(S))
            o = nn.attention(q, k, v, window=window)
            carry = carry + o.reshape(B, S, -1) @ p["attn"]["wo"]
            h = nn.rms_norm(carry, p["norm2"])
            carry = carry + nn.mlp_apply(p["mlp"], h)
            return carry, (k, v)

        x, (k_seg, v_seg) = jax.lax.scan(
            jax.checkpoint(body), x, _tree_slice(params["blocks"], start, length))
        ks.append(k_seg)
        vs.append(v_seg)
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h[:, -1:])[:, 0]
    cache = {"k": jnp.concatenate(ks, axis=0), "v": jnp.concatenate(vs, axis=0)}
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: dict[str, jax.Array],
                batch: dict[str, jax.Array]):
    """One new token against a KV cache.  batch: {token (B,1), pos ()}.

    Returns (logits (B,V), new cache).
    """
    token, pos = batch["token"], batch["pos"]
    x = nn.embed_lookup(params["embed"], token)
    new_k, new_v = [], []
    for start, length, window in segments(cfg):
        def body(carry, xs, window=window):
            p, kc, vc = xs
            h = nn.rms_norm(carry, p["norm1"])
            o, kc, vc = nn.attn_decode(p["attn"], cfg, h, kc, vc, pos, window=window)
            carry = carry + o
            h = nn.rms_norm(carry, p["norm2"])
            carry = carry + nn.mlp_apply(p["mlp"], h)
            return carry, (kc, vc)

        xs = (_tree_slice(params["blocks"], start, length),
              jax.lax.slice_in_dim(cache["k"], start, start + length, axis=0),
              jax.lax.slice_in_dim(cache["v"], start, start + length, axis=0))
        x, (k_seg, v_seg) = jax.lax.scan(body, x, xs)
        new_k.append(k_seg)
        new_v.append(v_seg)
    h = nn.rms_norm(x, params["final_norm"])
    logits = nn.unembed_logits(params["embed"], h)[:, 0]
    return logits, {"k": jnp.concatenate(new_k, axis=0), "v": jnp.concatenate(new_v, axis=0)}
