"""Simple npz-based pytree checkpointing (params + round state + meta)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, params: Any, *, step: int = 0,
         extra: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(params)
    np.savez(path + ".npz", **arrays)
    meta = {"step": step, "keys": sorted(arrays), "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def restore(path: str, like: Any) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with np.load(path + ".npz") as data:
        arrays = dict(data)
    with open(path + ".json") as f:
        meta = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = arrays[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), meta
