"""npz-based pytree checkpointing (params + round state + meta).

Every write is ATOMIC: the payload goes to a ``<path>.tmp`` sibling
first and is moved into place with ``os.replace``, so a crash mid-save
can never leave a torn snapshot that a recovery path would trust.  The
``.json`` meta is replaced LAST — it is the commit record: a snapshot
whose meta names keys the ``.npz`` lacks (or vice versa) is reported
loudly by ``restore``/``load_arrays`` instead of half-loading.

Two layers:

  * ``save``/``restore`` — the original pytree API (structure template
    supplied at restore time);
  * ``save_arrays``/``load_arrays`` + ``flatten_tree``/``unflatten_like``
    — the raw building blocks ``repro.serve`` composes its write-ahead
    ``ServerState`` snapshots from (many trees + host arrays packed into
    ONE atomic npz under key prefixes).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _path_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def flatten_tree(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    """Pytree -> flat {path: host array} dict ("/"-joined key paths,
    optional ``prefix`` for packing several trees into one namespace)."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        out[prefix + _path_key(path)] = np.asarray(leaf)
    return out


# back-compat alias (pre-serve callers)
_flatten = flatten_tree


def unflatten_like(like: Any, arrays: dict[str, np.ndarray],
                   prefix: str = "", label: str = "checkpoint") -> Any:
    """Rebuild a pytree with the structure/dtypes of ``like`` from a flat
    array dict.  Raises ``ValueError`` naming every missing and every
    shape-mismatched key (not a bare KeyError on the first one)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    missing, mismatched, out = [], [], []
    for p, leaf in leaves:
        key = prefix + _path_key(p)
        arr = arrays.get(key)
        if arr is None:
            missing.append(key)
            continue
        if arr.shape != np.shape(leaf):
            mismatched.append(f"{key}: saved {arr.shape} != "
                              f"expected {np.shape(leaf)}")
            continue
        out.append(arr.astype(np.asarray(leaf).dtype))
    if missing or mismatched:
        parts = []
        if missing:
            parts.append(f"missing keys {missing}")
        if mismatched:
            parts.append(f"shape mismatches [{'; '.join(mismatched)}]")
        raise ValueError(f"{label} does not match the expected structure: "
                         + "; ".join(parts))
    return jax.tree_util.tree_unflatten(treedef, out)


def _atomic_write_npz(path: str, arrays: dict[str, np.ndarray]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez(fh, **arrays)
    os.replace(tmp, path)


def _atomic_write_json(path: str, doc: dict[str, Any]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(doc, fh, indent=2, default=str)
    os.replace(tmp, path)


def save_arrays(path: str, arrays: dict[str, np.ndarray],
                meta: dict[str, Any] | None = None) -> None:
    """Atomically persist a flat array dict + JSON meta as
    ``<path>.npz`` / ``<path>.json`` (arrays first, meta last — the meta
    replace is the commit point)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    _atomic_write_npz(path + ".npz", arrays)
    doc = dict(meta or {})
    doc.setdefault("keys", sorted(arrays))
    _atomic_write_json(path + ".json", doc)


def load_arrays(path: str) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Load a ``save_arrays`` snapshot; raises ``FileNotFoundError`` when
    absent and ``ValueError`` when the npz/meta pair is torn (keys the
    meta committed to that the npz lacks)."""
    with np.load(path + ".npz") as data:
        arrays = dict(data)
    with open(path + ".json") as fh:
        meta = json.load(fh)
    committed = meta.get("keys")
    if committed is not None:
        lost = sorted(set(committed) - set(arrays))
        if lost:
            raise ValueError(f"{path}: torn snapshot — meta commits to "
                             f"keys the npz lacks: {lost}")
    return arrays, meta


def save(path: str, params: Any, *, step: int = 0,
         extra: dict[str, Any] | None = None) -> None:
    save_arrays(path, flatten_tree(params),
                {"step": step, "extra": extra or {}})


def restore(path: str, like: Any) -> tuple[Any, dict[str, Any]]:
    """Restore into the structure of ``like`` (shapes must match).
    A snapshot that lacks keys or carries wrong shapes raises
    ``ValueError`` listing every offending key."""
    arrays, meta = load_arrays(path)
    return unflatten_like(like, arrays, label=path), meta
