"""Post-SPMD HLO analysis: FLOPs / HBM traffic / collective bytes with
while-loop trip-count correction.

``compiled.cost_analysis()`` counts every while-loop (lax.scan) body
ONCE, which under-reports scanned-layer models by ~n_layers.  This
module parses ``compiled.as_text()`` (the per-device program) into a
computation call graph, extracts loop trip counts from the loop
conditions, and multiplies each body's cost through its callers:

  flops      : dot ops (2 * result_elems * contracted_elems) + convs
  hbm bytes  : per *top-level* op (fusion boundaries = HBM round trips):
               operand bytes + result bytes; fused interiors are free
  collectives: per-device tensor bytes with ring multipliers
               (all-reduce 2x, others 1x)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (intra-pod), ~25 GB/s effective DCI (cross-pod).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\([^)]*\)|[\w\[\],]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "iota",
                   "after-all", "partition-id", "replica-id"}

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCI_BW = 25e9


def _shape_list_bytes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _bytes_of(dt_dims) -> int:
    dt, dims = dt_dims
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


@dataclass
class Instr:
    name: str
    op: str
    result_shapes: list
    operands: list[str]
    rhs: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict[str, list] = field(default_factory=dict)  # symbol table


_OP_RE = re.compile(r"\b([a-z][\w\-]*)\(")


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
                # parameters from the signature
                for pm in _PARAM_RE.finditer(m.group(3)):
                    cur.shapes[pm.group(1)] = _shape_list_bytes(pm.group(2))
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # split rhs into "shape op(operands), attrs"
        om = _OP_RE.search(rhs)
        if not om:
            continue
        op = om.group(1)
        result_shapes = _shape_list_bytes(rhs[: om.start()])
        # operands: inside the first balanced paren group after op
        depth, start, end = 0, om.end() - 1, None
        for i in range(om.end() - 1, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = rhs[om.end(): end] if end else ""
        operands = _OPERAND_RE.findall(operand_text)
        cur.shapes[name] = result_shapes
        cur.instrs.append(Instr(name, op, result_shapes, operands,
                                rhs[end + 1:] if end else ""))
    return comps


def _callees(instr: Instr) -> list[tuple[str, str]]:
    """[(role, computation-name)] referenced by this instruction."""
    out = []
    for role in ("body", "condition", "to_apply", "calls"):
        m = re.search(rf"{role}=%?([\w.\-]+)", instr.rhs)
        if m:
            out.append((role, m.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    """Loop bound: the largest s32 constant in the condition computation.
    (All our loops are lax.scan/fori counting 0..N.)"""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.rhs) or \
                re.search(r"constant\((\d+)\)", "constant(" + ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
        m2 = re.search(r"constant\((\d+)\)", ins.rhs)
        if m2:
            best = max(best, int(m2.group(1)))
    return best


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_elems = sum(int(_bytes_of(s) / _DTYPE_BYTES[s[0]]) for s in ins.result_shapes)
    lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    if not lhs_c or not ins.operands:
        return 2.0 * res_elems  # fallback
    lhs_shapes = comp.shapes.get(ins.operands[0], [])
    if not lhs_shapes:
        return 2.0 * res_elems
    dims = lhs_shapes[0][1]
    k = 1
    for d in lhs_c.group(1).split(","):
        if d and int(d) < len(dims):
            k *= dims[int(d)]
    return 2.0 * res_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    res_elems = sum(int(_bytes_of(s) / _DTYPE_BYTES[s[0]]) for s in ins.result_shapes)
    if len(ins.operands) < 2:
        return 2.0 * res_elems
    rhs_shapes = comp.shapes.get(ins.operands[1], [])
    if not rhs_shapes:
        return 2.0 * res_elems
    kdims = rhs_shapes[0][1]
    kernel = 1
    for d in kdims[:-1]:            # spatial x input-feature dims
        kernel *= d
    fg = re.search(r"feature_group_count=(\d+)", ins.rhs)
    if fg:
        kernel = max(1, kernel // int(fg.group(1)))
    return 2.0 * res_elems * kernel


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
            self.coll_counts[k] += mult * other.coll_counts[k]


def analyze(text: str) -> dict[str, float]:
    comps = parse_computations(text)
    memo: dict[str, Cost] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Cost()
        comp = comps[name]
        total = Cost()
        for ins in comp.instrs:
            callees = dict(_callees(ins))
            if ins.op == "while":
                body, cond = callees.get("body"), callees.get("condition")
                ktc = re.search(r'known_trip_count[^0-9]*(\d+)', ins.rhs)
                if ktc:
                    trip = int(ktc.group(1))
                else:
                    trip = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    total.add(cost_of(body, stack + (name,)), mult=trip)
                if cond:
                    total.add(cost_of(cond, stack + (name,)), mult=trip)
                continue
            if ins.op in ("call", "conditional"):
                for _, c in callees.items():
                    total.add(cost_of(c, stack + (name,)))
                continue
            if ins.op == "fusion":
                inner = callees.get("calls")
                if inner:
                    inner_cost = cost_of(inner, stack + (name,))
                    total.flops += inner_cost.flops   # dots inside fusions
                    for k in COLLECTIVES:
                        total.coll[k] += inner_cost.coll[k]
                        total.coll_counts[k] += inner_cost.coll_counts[k]
                # HBM traffic at the fusion boundary
                total.bytes += _io_bytes(ins, comp)
                continue
            if ins.op == "dot":
                total.flops += _dot_flops(ins, comp)
                total.bytes += _io_bytes(ins, comp)
                continue
            if ins.op == "convolution":
                total.flops += _conv_flops(ins, comp)
                total.bytes += _io_bytes(ins, comp)
                continue
            kind = next((k for k in COLLECTIVES if ins.op.startswith(k)), None)
            if kind and not ins.op.endswith("-done"):
                b = max((_bytes_of(s) for s in ins.result_shapes), default=0)
                total.coll[kind] += _MULT[kind] * b
                total.coll_counts[kind] += 1
                total.bytes += _io_bytes(ins, comp)
                continue
            if ins.op not in _SKIP_BYTES_OPS:
                total.bytes += _io_bytes(ins, comp)
        memo[name] = total
        return total

    def _io_bytes(ins: Instr, comp: Computation) -> float:
        """HBM traffic of one op.  In-place patterns (dynamic-update-slice,
        dynamic-slice — scan carries and stacked-param reads) only touch
        the slice, not the whole aliased buffer."""
        res = sum(_bytes_of(s) for s in ins.result_shapes)
        opbytes = []
        for o in ins.operands:
            opbytes.append(sum(_bytes_of(s) for s in comp.shapes.get(o, [])))
        inner_ops = set()
        if ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
            if m and m.group(1) in comps:
                inner_ops = {i.op for i in comps[m.group(1)].instrs}
        if ins.op == "dynamic-update-slice" or "dynamic-update-slice" in inner_ops:
            small = [b for b in opbytes if b < res]
            return float(2 * sum(small)) if small else float(res)
        if ins.op == "dynamic-slice" or "dynamic-slice" in inner_ops:
            return float(2 * res)
        return float(res + sum(opbytes))

    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    c = cost_of(entry.name)
    out = {"flops": c.flops, "hbm_bytes": c.bytes}
    for k in COLLECTIVES:
        out[f"coll_{k}"] = c.coll[k]
        out[f"count_{k}"] = c.coll_counts[k]
    out["collective_bytes"] = sum(c.coll.values())
    return out


def roofline(analysis: dict[str, float], *, cross_pod_bytes: float = 0.0
             ) -> dict[str, float]:
    terms = {
        "compute_s": analysis["flops"] / PEAK_FLOPS,
        "memory_s": analysis["hbm_bytes"] / HBM_BW,
        "collective_s": (analysis["collective_bytes"] / ICI_BW
                         + cross_pod_bytes / DCI_BW),
    }
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom  # type: ignore
    terms.update({k: analysis[k] for k in ("flops", "hbm_bytes", "collective_bytes")})
    return terms
