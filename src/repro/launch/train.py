"""FedLUAR training driver (Alg. 2 end-to-end).

Workloads:
  cnn  — synthetic FEMNIST-style images + the paper's 4-layer CNN
  mlp  — Gaussian-mixture classification (fast)
  lm   — federated fine-tuning of an assigned-architecture LM (reduced or
         scaled variant) on synthetic class-conditioned token streams

  PYTHONPATH=src python -m repro.launch.train --workload lm --arch qwen3-14b \
      --rounds 50 --delta 4 [--scheme luar|random|...] [--mode recycle|drop] \
      [--server fedavg|fedopt|fedacg] [--ckpt out/model]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import ARCH_IDS, get_config
from repro.core import LuarConfig
from repro.obs import Telemetry, run_summary
from repro.data.synthetic import gaussian_mixture, lm_batch, synthetic_images, synthetic_tokens
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.fl.server import ServerConfig
from repro.models.cnn import cnn_init, cnn_apply, mlp_init, mlp_apply, softmax_xent
from repro.models.registry import build


def build_workload(args):
    if args.workload == "cnn":
        x, y = synthetic_images(4000, n_classes=16, seed=args.seed)
        xt, yt = synthetic_images(1000, n_classes=16, seed=args.seed + 1)
        params = cnn_init(jax.random.PRNGKey(args.seed), n_classes=16)
        loss_fn = lambda p, b: softmax_xent(cnn_apply(p, b["x"]), b["y"])
        eval_fn = lambda p: {"acc": float(jnp.mean(
            jnp.argmax(cnn_apply(p, jnp.asarray(xt)), -1) == jnp.asarray(yt)))}
        data, labels, gran = {"x": x, "y": y}, y, "module"
    elif args.workload == "mlp":
        x, y = gaussian_mixture(4000, n_classes=10, d=32, seed=args.seed)
        xt, yt = gaussian_mixture(1000, n_classes=10, d=32, seed=args.seed + 1)
        params = mlp_init(jax.random.PRNGKey(args.seed), n_features=32, n_classes=10)
        loss_fn = lambda p, b: softmax_xent(mlp_apply(p, b["x"]), b["y"])
        eval_fn = lambda p: {"acc": float(jnp.mean(
            jnp.argmax(mlp_apply(p, jnp.asarray(xt)), -1) == jnp.asarray(yt)))}
        data, labels, gran = {"x": x, "y": y}, y, "module"
    else:  # lm
        cfg = get_config(args.arch, reduced=True)
        if args.lm_scale > 1:  # optionally grow toward ~100M params
            cfg = cfg.replace(n_layers=min(args.lm_scale, 12),
                              d_model=128 * args.lm_scale,
                              n_heads=4 * args.lm_scale // 2 * 2 or 4,
                              d_ff=256 * args.lm_scale,
                              vocab_size=8192)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        raw = synthetic_tokens(2048, seq_len=args.seq_len + 1,
                               vocab=cfg.vocab_size, n_classes=8, seed=args.seed)
        d = lm_batch(raw["tokens"])
        test = lm_batch(synthetic_tokens(256, seq_len=args.seq_len + 1,
                                         vocab=cfg.vocab_size, n_classes=8,
                                         seed=args.seed + 1)["tokens"])
        tt, tl = jnp.asarray(test["tokens"]), jnp.asarray(test["labels"])

        def loss_fn(p, b):
            return model.train_loss(p, b)

        @jax.jit
        def _eval(p):
            return model.train_loss(p, {"tokens": tt, "labels": tl})

        eval_fn = lambda p: {"val_loss": float(_eval(p))}
        data, labels, gran = d, raw["labels"], "leaf"
        n_params = sum(a.size for a in jax.tree.leaves(params))
        print(f"# lm model {cfg.name}: {n_params / 1e6:.1f}M params")
    parts = dirichlet_partition(labels, args.clients, alpha=args.alpha,
                                seed=args.seed)
    return loss_fn, eval_fn, params, data, parts, gran


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="cnn", choices=["cnn", "mlp", "lm"])
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--lm-scale", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--active", type=int, default=8)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--delta", type=int, default=0)
    ap.add_argument("--scheme", default="luar")
    ap.add_argument("--mode", default="recycle", choices=["recycle", "drop"])
    ap.add_argument("--server", default="fedavg",
                    choices=["fedavg", "fedopt", "fedacg"])
    ap.add_argument("--prox-mu", type=float, default=0.0)
    ap.add_argument("--codecs", default="",
                    help="update-codec stack as '+'-separated spec strings, "
                         "e.g. 'fedpaq:4+topk:0.1+ef' (repro.compress); "
                         "'down:'-prefixed stages compress the broadcast "
                         "instead, e.g. 'fedpaq:4+down:delta'")
    ap.add_argument("--participation", default="uniform",
                    help="client-participation policy spec "
                         "(repro.participate): 'uniform', 'powd:8', "
                         "'importance:norm', 'avail:diurnal', "
                         "'avail:bernoulli:0.1', 'energy:20'; biased "
                         "policies are HT-reweighted in aggregation")
    ap.add_argument("--fedpaq-bits", type=int, default=0,
                    help="DEPRECATED: use --codecs fedpaq:<bits>")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--trace-out", default="",
                    help="write the structured JSONL round trace "
                         "(repro.obs schema v1) to this path")
    ap.add_argument("--profile", action="store_true",
                    help="time jit-compile vs steady-state spans and "
                         "print the profile table at exit")
    args = ap.parse_args(argv)

    loss_fn, eval_fn, params, data, parts, gran = build_workload(args)
    cfg = FLConfig(
        n_clients=args.clients, n_active=args.active, tau=args.tau,
        batch_size=args.batch_size, rounds=args.rounds, seed=args.seed,
        client=ClientConfig(lr=args.lr, prox_mu=args.prox_mu),
        server=ServerConfig(kind=args.server),
        luar=LuarConfig(delta=args.delta, scheme=args.scheme, mode=args.mode,
                        granularity=gran),
        codecs=args.codecs, participation=args.participation,
        fedpaq_bits=args.fedpaq_bits, eval_every=args.eval_every)

    tele = Telemetry.create(trace_path=args.trace_out or None,
                            profile=args.profile)
    t0 = time.time()
    res = run_fl(loss_fn, params, data, parts, cfg, eval_fn, telemetry=tele)
    for h in res.history:
        print(json.dumps(h))
    # the summary derives from the metrics registry — ONE formatting path
    # shared with the Prometheus exposition (same instruments, same
    # numbers the result dataclass re-derives)
    print(json.dumps(run_summary(
        tele.metrics,
        participation=args.participation,
        fairness=res.fairness,
        agg_counts={n: int(c) for n, c in zip(res.unit_names, res.agg_count)},
        wall_s=round(time.time() - t0, 1))))
    if tele.profiler is not None:
        print(tele.profiler.render())
    tele.close()
    if args.trace_out:
        print(f"# trace -> {args.trace_out}")
    if args.ckpt:
        ckpt.save(args.ckpt, res.params, step=args.rounds,
                  extra={"comm_ratio": res.comm_ratio})
        print(f"# checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
