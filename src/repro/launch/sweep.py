"""Run the full dry-run sweep: every (arch x shape x mesh) combination as
an isolated subprocess (XLA_FLAGS set per process), results cached as
JSON under experiments/dryrun/.

  PYTHONPATH=src python -m repro.launch.sweep [--jobs 3] [--only-missing]
  PYTHONPATH=src python -m repro.launch.sweep --table   # print summary
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor

ARCHS = ["qwen3-14b", "internvl2-76b", "mixtral-8x7b", "granite-34b",
         "zamba2-1.2b", "mamba2-780m", "whisper-small",
         "deepseek-v2-lite-16b", "gemma3-4b", "minitron-8b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
OUT = "experiments/dryrun"


def combos(include_multipod: bool = True):
    for arch in ARCHS:
        for shape in SHAPES:
            yield (arch, shape, False)
            if include_multipod:
                yield (arch, shape, True)


def path_for(arch, shape, multi_pod, strategy="fsdp_sp", static=False):
    mesh_tag = "pod2" if multi_pod else "pod1"
    sfx = (("_" + strategy) if strategy != "fsdp_sp" else "") + ("_static" if static else "")
    return os.path.join(OUT, f"{arch}_{shape}_{mesh_tag}{sfx}.json")


def run_one(arch, shape, multi_pod, timeout=1800):
    p = path_for(arch, shape, multi_pod)
    if os.path.exists(p):
        return (arch, shape, multi_pod, "cached")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", OUT]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH="src")
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout, env=env)
        if r.returncode != 0:
            err = (r.stderr or "")[-2000:]
            with open(p.replace(".json", ".err"), "w") as f:
                f.write(err)
            return (arch, shape, multi_pod, "FAIL")
        return (arch, shape, multi_pod, "ok")
    except subprocess.TimeoutExpired:
        return (arch, shape, multi_pod, "TIMEOUT")


def table():
    rows = []
    for arch, shape, mp in combos():
        p = path_for(arch, shape, mp)
        if not os.path.exists(p):
            rows.append((arch, shape, mp, "missing", {}))
            continue
        rec = json.load(open(p))
        if "skipped" in rec:
            rows.append((arch, shape, mp, "skip", {}))
            continue
        rows.append((arch, shape, mp, "ok", rec))
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':5s} {'stat':7s} "
           f"{'comp_s':>8s} {'mem_s':>8s} {'coll_s':>8s} {'bottleneck':12s} "
           f"{'temp_GB':>8s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for arch, shape, mp, st, rec in rows:
        mesh = "pod2" if mp else "pod1"
        if st != "ok":
            print(f"{arch:22s} {shape:12s} {mesh:5s} {st:7s}")
            continue
        rl = rec.get("roofline", {})
        ma = rec.get("memory_analysis", {})
        temp = ma.get("temp_size_in_bytes", 0) / 1e9 if isinstance(ma, dict) else 0
        print(f"{arch:22s} {shape:12s} {mesh:5s} {st:7s} "
              f"{rl.get('compute_s', 0):8.3f} {rl.get('memory_s', 0):8.3f} "
              f"{rl.get('collective_s', 0):8.3f} {rl.get('bottleneck', '?'):12s} "
              f"{temp:8.2f} {rec.get('useful_flops_ratio', 0):7.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--table", action="store_true")
    ap.add_argument("--pod1-only", action="store_true")
    args = ap.parse_args()
    if args.table:
        table()
        return
    os.makedirs(OUT, exist_ok=True)
    todo = list(combos(include_multipod=not args.pod1_only))
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        for res in ex.map(lambda c: run_one(*c), todo):
            print(*res, flush=True)


if __name__ == "__main__":
    main()
