"""Batched generation loop: prefill a prompt batch, then greedy-decode
with the KV cache.  Works for every assigned architecture family.

(Formerly ``repro.launch.serve`` — renamed so ``repro.serve`` can
unambiguously mean the FL round service; the old module path remains
as a deprecation shim.)

  PYTHONPATH=src python -m repro.launch.generate --arch gemma3-4b --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import build


def pad_cache(cfg, cache, target: int):
    """Grow sequence-indexed cache entries to ``target`` slots."""
    out = {}
    for k, v in cache.items():
        if k in ("k", "v") and v.ndim == 5:
            out[k] = jnp.pad(v, [(0, 0), (0, 0), (0, target - v.shape[2]),
                                 (0, 0), (0, 0)])
        elif k in ("c_kv", "k_pe"):
            out[k] = jnp.pad(v, [(0, 0), (0, 0), (0, target - v.shape[2]), (0, 0)])
        else:
            out[k] = v
    return out


def serve(arch: str, batch: int = 4, prompt_len: int = 32, steps: int = 16,
          reduced: bool = True, seed: int = 0, greedy: bool = True):
    cfg = get_config(arch, reduced=reduced)
    model = build(cfg)
    key, prompt_key = jax.random.split(jax.random.PRNGKey(seed))
    params = model.init(key)
    prompts = jax.random.randint(prompt_key, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    pf_batch = {"tokens": prompts}
    if cfg.family == "vlm":
        pf_batch["vis_embeds"] = 0.1 * jnp.ones(
            (batch, cfg.n_vis_tokens, cfg.d_model), cfg.dtype)
    if cfg.family == "encdec":
        pf_batch["enc_frames"] = 0.1 * jnp.ones(
            (batch, cfg.enc_seq, cfg.d_model), cfg.dtype)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, pf_batch)
    cache = pad_cache(cfg, cache, prompt_len + steps)
    t_prefill = time.time() - t0

    toks = [jnp.argmax(logits, -1)[:, None]]
    t1 = time.time()
    for i in range(steps - 1):
        pos = jnp.asarray(prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, {"token": toks[-1], "pos": pos})
        toks.append(jnp.argmax(logits, -1)[:, None])
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t1
    out = jnp.concatenate(toks, axis=1)
    return out, {"prefill_s": round(t_prefill, 3),
                 "decode_s_per_tok": round(t_decode / max(steps - 1, 1), 4),
                 "batch": batch}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU-scale; do not run on CPU)")
    args = ap.parse_args(argv)
    out, stats = serve(args.arch, args.batch, args.prompt_len, args.steps,
                       reduced=not args.full)
    print("generated token grid:\n", out)
    print(stats)


if __name__ == "__main__":
    main()
