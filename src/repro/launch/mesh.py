"""Production meshes.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod : (2, 16, 16) = 512 chips, axes (pod, data, model) — the 'pod'
axis is the FL silo boundary (DESIGN.md §3): FedLUAR's recycling gates
the cross-pod all-reduce per layer.

These are FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally-available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
