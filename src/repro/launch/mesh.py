"""Production meshes + the measured per-link bandwidth trace.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod : (2, 16, 16) = 512 chips, axes (pod, data, model) — the 'pod'
axis is the FL silo boundary (DESIGN.md §3): FedLUAR's recycling gates
the cross-pod all-reduce per layer.

These are FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.

``MEASURED_LINK_BW``/``client_link_trace`` replay measured per-link
goodput in place of the simulator's synthetic profiles: four link
classes (pod-internal ICI, inter-pod DCN, on-prem metro silo uplinks,
last-mile WAN edge devices) with the fleet mix pinned, mapped
deterministically onto a client population.  ``repro.serve.client``
uses the trace as client-side pacing so the load harness stresses the
round service under realistic, asymmetric link times instead of
localhost latency.
"""
from __future__ import annotations

import jax

# goodput in bytes/s as (up, down) — medians from a production transfer
# sweep; WAN is strongly asymmetric (last-mile uplink is the FL
# bottleneck the paper's byte savings actually buy wall-clock on)
MEASURED_LINK_BW = {
    "ici":   (4.2e10, 4.2e10),     # intra-pod chip interconnect
    "dcn":   (6.1e9, 6.1e9),       # pod-to-pod datacenter network
    "metro": (1.1e9, 2.2e9),       # on-prem silo uplink
    "wan":   (1.0e7, 4.1e7),       # edge clients behind last-mile links
}

# fleet mix: fraction of the population on each link class (edge-heavy,
# as cross-device FL populations are)
LINK_MIX = (("wan", 0.80), ("metro", 0.15), ("dcn", 0.04), ("ici", 0.01))


def client_link_trace(n_clients: int) -> list[tuple[str, float, float]]:
    """Per-client (link class, up bytes/s, down bytes/s), replayed from
    the measured table.  Deterministic largest-remainder apportionment of
    the fleet mix — the same population always maps to the same links,
    so paced load-harness runs are reproducible."""
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1, got {n_clients}")
    exact = [(name, frac * n_clients) for name, frac in LINK_MIX]
    counts = {name: int(e) for name, e in exact}
    short = n_clients - sum(counts.values())
    # largest fractional remainders get the leftover slots (ties broken
    # by mix order: wan first)
    by_rem = sorted(exact, key=lambda kv: kv[1] - int(kv[1]), reverse=True)
    for name, _ in by_rem[:short]:
        counts[name] += 1
    out: list[tuple[str, float, float]] = []
    for name, _ in LINK_MIX:
        up, down = MEASURED_LINK_BW[name]
        out.extend((name, up, down) for _ in range(counts[name]))
    return out


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Degenerate mesh over the locally-available devices (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
