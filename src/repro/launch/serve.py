"""Deprecated alias for :mod:`repro.launch.generate`.

``repro.serve`` is the FL round service; the inference demo that used
to live here is now ``repro.launch.generate``.  This shim keeps old
imports and ``python -m repro.launch.serve`` invocations working, with
a DeprecationWarning.
"""
from __future__ import annotations

import warnings

from repro.launch.generate import main, pad_cache, serve  # noqa: F401

warnings.warn(
    "repro.launch.serve is deprecated: the inference demo moved to "
    "repro.launch.generate (repro.serve is the FL round service)",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
