"""Jitted distributed step functions with FedLUAR integrated.

The production train step IS one FedLUAR round at tau=1 granularity:
each (pod, data) device group is a client cohort; XLA's gradient
all-reduce over those axes is the upload; LUAR gates it per layer-unit.

Two variants (DESIGN.md §3):
  * dynamic (paper-faithful): the recycle mask R_t is a traced array —
    numerics exactly Alg. 1/2, collectives unchanged.
  * static (beyond-paper): R_t is baked into the executable.  Recycled
    units never read the fresh gradient, so XLA dead-code-eliminates
    their weight-grad matmuls AND their cross-client all-reduce.  The
    server samples R_{t+1} between steps and dispatches to a cached
    executable per mask pattern.
"""
from __future__ import annotations
from typing import Any, NamedTuple
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.compress import CodecPipeline
from repro.core.recycle import LuarConfig, LuarState, luar_round
from repro.core.units import UnitMap, build_units
from repro.models.registry import Model

Params = Any


class TrainState(NamedTuple):
    params: Params
    momentum: Params
    luar: LuarState
    codec: Any = None               # update-codec pipeline state (or None)


def train_state_shapes(model: Model,
                       codec: CodecPipeline | None = None
                       ) -> tuple[TrainState, UnitMap]:
    """abstract TrainState (ShapeDtypeStructs only, no allocation)."""
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    um = build_units(params, "leaf")
    n = len(um.names)
    sds = jax.ShapeDtypeStruct
    luar = LuarState(
        prev_update=params,
        mask=sds((n,), jnp.bool_),
        s=sds((n,), jnp.float32),
        staleness=sds((n,), jnp.int32),
        agg_count=sds((n,), jnp.int32),
        round=sds((), jnp.int32),
        key=sds((2,), jnp.uint32),
    )
    codec_sh = (jax.eval_shape(lambda p: codec.init_state(p, um), params)
                if codec is not None else None)
    return TrainState(params=params, momentum=params, luar=luar,
                      codec=codec_sh), um


def make_fedluar_train_step(
    model: Model,
    luar_cfg: LuarConfig,
    um: UnitMap,
    *,
    lr: float = 1e-3,
    momentum: float = 0.9,
    static_mask: Sequence[bool] | None = None,
    codec: CodecPipeline | None = None,
) -> Callable:
    """Returns step(state, batch) -> (state, loss).

    ``codec`` (an update-codec pipeline, ``repro.compress``) encodes the
    pre-aggregation update exactly where the cross-client all-reduce
    sits at pod scale; its state rides in ``TrainState.codec``.  Only
    the dynamic path supports it — the static path's whole point is
    DCE-ing the collective, which a traced codec transform would defeat."""
    if codec is not None and static_mask is not None:
        raise ValueError("codec pipelines compose with the dynamic path "
                         "only (static_mask bakes the collective away)")

    def step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(state.params, batch)

        if static_mask is None:
            # paper-faithful dynamic recycling
            new_m = jax.tree.map(lambda m, g: momentum * m + g,
                                 state.momentum, grads)
            update = jax.tree.map(lambda m: -lr * m, new_m)
            codec_state = state.codec
            if codec is not None:
                update, codec_state, _ = codec.encode(
                    codec_state, update,
                    jax.random.fold_in(state.luar.key, 0x5EC))
            applied, luar = luar_round(state.luar, um, luar_cfg,
                                       update, state.params)
        else:
            # static schedule: recycled leaves never touch `grads`
            codec_state = state.codec
            assert all(isinstance(u, int) for u in um.leaf_unit), \
                "static scheduling requires leaf granularity (whole stacked " \
                "tensors gate the collective; per-depth gating cannot DCE " \
                "inside a scanned layer loop)"
            leaves_m = jax.tree.leaves(state.momentum)
            leaves_g = jax.tree.leaves(grads)
            leaves_prev = jax.tree.leaves(state.luar.prev_update)
            new_m_leaves, applied_leaves = [], []
            for u, m, g, prev in zip(um.leaf_unit, leaves_m, leaves_g, leaves_prev):
                if static_mask[u]:
                    new_m_leaves.append(m)          # frozen; g is DCE'd
                    applied_leaves.append(prev)
                else:
                    nm = momentum * m + g
                    new_m_leaves.append(nm)
                    applied_leaves.append(-lr * nm)
            treedef = um.treedef
            new_m = jax.tree.unflatten(treedef, new_m_leaves)
            applied = jax.tree.unflatten(treedef, applied_leaves)
            mask_arr = jnp.asarray(list(static_mask))
            luar = state.luar._replace(
                prev_update=applied,
                staleness=jnp.where(mask_arr, state.luar.staleness + 1, 0),
                agg_count=state.luar.agg_count + (~mask_arr).astype(jnp.int32),
                round=state.luar.round + 1,
            )

        params = jax.tree.map(lambda p, d: p + d, state.params, applied)
        return TrainState(params, new_m, luar, codec_state), loss

    return step


def make_prefill_step(model: Model) -> Callable:
    def step(params, batch):
        return model.prefill(params, batch)
    return step


def make_decode_step(model: Model) -> Callable:
    def step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return step
