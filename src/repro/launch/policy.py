"""Distribution policy: how model-internal compute maps onto the mesh.

The model code is policy-agnostic; when a policy is active (set by the
launcher/dry-run around tracing), attention/MoE/SSM pick distributed
execution paths:

  seq_axis  : self-attention runs under shard_map with queries sequence-
              sharded on this axis and K/V all-gathered (context/sequence
              parallelism).  Avoids the naive-TP trap of sharding head_dim
              (which all-reduces full score tiles — see EXPERIMENTS.md
              §Perf iteration 1).
  head_axis : SSM / MHA head sharding constraint (zamba2: H=32 % 16 == 0;
              mamba2: nh=48 % 16 == 0) — fully local per-head compute.
  ep_axis   : MoE expert parallelism (DeepSeek 64e) or per-expert ffn TP
              (Mixtral 8e) under shard_map with a psum combine.
  batch_axes: data-parallel axes (the FL client-cohort axes).

No policy (the default) = single-host semantics; CPU tests never touch
this module.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import PartitionSpec as P


@dataclass
class Policy:
    mesh: Any
    batch_axes: tuple[str, ...] = ("data",)
    seq_axis: str | None = "model"
    head_axis: str | None = "model"
    ep_axis: str | None = "model"

    def axis_size(self, name: str | None) -> int:
        if name is None:
            return 1
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(name, 1)


_ACTIVE: Policy | None = None


def active() -> Policy | None:
    return _ACTIVE


@contextlib.contextmanager
def use_policy(policy: Policy | None):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = policy
    try:
        yield policy
    finally:
        _ACTIVE = prev


def constrain(x, *spec):
    """with_sharding_constraint when a policy is active, else identity."""
    pol = _ACTIVE
    if pol is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pol.mesh, P(*spec)))


def gather_params(tree):
    """ZeRO-3 weight gather at point-of-use.

    FSDP-sharded weights are constrained to replicated right before the
    layer uses them: XLA inserts one all-gather per layer per pass (and a
    reduce-scatter for the weight gradient) instead of resharding the
    much larger activations — without this GSPMD picks 'involuntary full
    rematerialization' plans that all-gather (B,S,ff) tensors (see
    EXPERIMENTS.md §Perf iteration 2)."""
    pol = _ACTIVE
    if pol is None:
        return tree
    return jax.tree.map(
        lambda a: jax.lax.with_sharding_constraint(
            a, jax.sharding.NamedSharding(pol.mesh, P(*([None] * a.ndim)))),
        tree)
