"""Sharding rules: parameter layouts, batch/cache layouts per strategy.

Strategies
----------
"fsdp_sp" (default, the tuned layout — EXPERIMENTS.md §Perf):
  * weights: FSDP — the penultimate dim shards over ('data','model')
    combined when divisible (ZeRO-3 style; gathered per layer inside the
    scan), else over whichever axis divides.  No tensor-parallel split of
    head_dim.
  * MoE expert stacks: expert dim on 'model' when divisible (EP), the
    d_model dim on 'data'.
  * activations: batch on the data axes, sequence on 'model'
    (sequence/context parallelism — attention runs under shard_map with
    K/V all-gathers, launch/policy.py).  SSM stacks keep S unsharded and
    shard the SSD heads instead.
  * embeddings (V, d): vocab over ('data','model') — CE logsumexp psums.

"naive_tp" (the first-cut Megatron-ish rule, kept as the §Perf baseline):
  * weights: dim -2 on 'data', dim -1 on 'model'.  For GQA models whose
    K*hd does not split into whole heads this shards head_dim and XLA
    all-reduces full score tiles every layer — measured 20x worse
    collective time (see EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import data_axes, model_axis_size
from repro.launch.policy import Policy

STRATEGIES = ("fsdp_sp", "naive_tp")


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0 and dim >= size


def layout(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(batch_axes, seq_axis) for train/prefill activations.

    If the global batch divides the whole mesh, run pure ZeRO-3 data
    parallelism (batch over every axis, sequence unsharded — smallest
    score tiles, no sequence collectives).  Otherwise batch covers the
    data axes and the sequence dim shards on 'model' (context/sequence
    parallelism).  SSM/hybrid stacks never sequence-shard (the recurrence
    is sequential): they head-shard instead."""
    daxes = data_axes(mesh)
    dtot = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    msz = model_axis_size(mesh)
    B = shape.global_batch
    if cfg.n_experts and shape.seq_len % msz == 0:
        # MoE always sequence-shards: group-wise routing keeps the
        # dispatch tensors O(S/msz * E * C(S/msz)) — EXPERIMENTS.md §Perf H2
        return daxes, "model"
    if B % (dtot * msz) == 0 and B >= dtot * msz:
        return daxes + ("model",), None
    seq = None
    if cfg.family in ("dense", "vlm", "moe", "encdec") and shape.seq_len % msz == 0:
        seq = "model"
    return daxes, seq


def make_policy(mesh, cfg: ModelConfig, strategy: str = "fsdp_sp",
                shape: ShapeSpec | None = None) -> Policy | None:
    if strategy != "fsdp_sp":
        return None
    if shape is None:
        return Policy(mesh=mesh, batch_axes=data_axes(mesh),
                      seq_axis="model", head_axis="model", ep_axis="model")
    baxes, seq = layout(cfg, shape, mesh)
    head = "model" if seq is None and "model" not in baxes else (
        "model" if seq is None else None)
    # pure-DP: nothing to head-shard (everything already local)
    if "model" in baxes:
        head = None
    return Policy(mesh=mesh, batch_axes=baxes, seq_axis=seq,
                  head_axis=head, ep_axis="model")


def _is_expert(path: str, ndim: int) -> bool:
    return any(k in path for k in ("w_gate", "w_up", "w_down")) and \
        "moe" in path and ndim >= 3


def param_spec(path: str, shape: tuple, mesh, cfg: ModelConfig,
               strategy: str = "fsdp_sp") -> P:
    ndim = len(shape)
    dsz = _axis_size(mesh, "data")
    msz = _axis_size(mesh, "model")
    if ndim <= 1:
        return P()

    if strategy == "naive_tp":
        if "embed" in path:
            return P("model" if _fits(shape[0], msz) else None, None)
        if _is_expert(path, ndim):
            e_dim = ndim - 3
            if _fits(shape[e_dim], msz):
                spec = [None] * ndim
                spec[e_dim] = "model"
                if _fits(shape[-2], dsz):
                    spec[-2] = "data"
                return P(*spec)
        spec = [None] * ndim
        if _fits(shape[-2], dsz):
            spec[-2] = "data"
        if _fits(shape[-1], msz):
            spec[-1] = "model"
        return P(*spec)

    # ---- fsdp_sp --------------------------------------------------------
    both = dsz * msz

    def fsdp_axis(dim: int):
        if _fits(dim, both):
            return ("data", "model")
        if _fits(dim, dsz):
            return "data"
        if _fits(dim, msz):
            return "model"
        return None

    if "embed" in path:
        return P(fsdp_axis(shape[0]), None)
    if _is_expert(path, ndim):
        e_dim = ndim - 3
        if _fits(shape[e_dim], msz):
            spec = [None] * ndim
            spec[e_dim] = "model"
            if _fits(shape[-2], dsz):
                spec[-2] = "data"
            return P(*spec)
        # expert dim does not divide: FSDP the d dim, TP the ffn dim
        spec = [None] * ndim
        if _fits(shape[-2], dsz):
            spec[-2] = "data"
        if _fits(shape[-1], msz):
            spec[-1] = "model"
        return P(*spec)
    spec = [None] * ndim
    spec[-2] = fsdp_axis(shape[-2])
    return P(*spec)


def _path_str(path) -> str:
    return ".".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_shardings(params_shapes: Any, mesh, cfg: ModelConfig,
                    strategy: str = "fsdp_sp"):
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape,
                                              mesh, cfg, strategy))
    return jax.tree_util.tree_map_with_path(one, params_shapes)


def replicated(mesh):
    return NamedSharding(mesh, P())


def _bspec(mesh, batch: int):
    daxes = data_axes(mesh)
    dtotal = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    return daxes if (dtotal > 1 and batch % dtotal == 0 and batch >= dtotal) else None


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    strategy: str = "fsdp_sp") -> dict[str, Any]:
    """Shardings for the input_specs() tree."""
    msz = model_axis_size(mesh)
    if strategy == "fsdp_sp" and shape.kind in ("train", "prefill"):
        baxes, seq = layout(cfg, shape, mesh)
        dtot = int(np.prod([_axis_size(mesh, a) for a in baxes]))
        bspec = baxes if (dtot > 1 and shape.global_batch % dtot == 0
                          and shape.global_batch >= dtot) else None
    else:
        bspec = _bspec(mesh, shape.global_batch)
        seq = None
    out: dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = NamedSharding(mesh, P(bspec, seq))
        out["labels"] = NamedSharding(mesh, P(bspec, seq))
    elif shape.kind == "prefill":
        out["tokens"] = NamedSharding(mesh, P(bspec, seq))
    else:
        out["token"] = NamedSharding(mesh, P(bspec, None))
        out["pos"] = replicated(mesh)
    if cfg.family == "vlm" and shape.kind != "decode":
        nv_seq = seq if cfg.n_vis_tokens % msz == 0 else None
        out["vis_embeds"] = NamedSharding(mesh, P(bspec, nv_seq, None))
    if cfg.family == "encdec":
        enc_seq = seq if (seq and cfg.enc_seq % msz == 0) else None
        out["enc_frames"] = NamedSharding(mesh, P(bspec, enc_seq, None))
    return out


def cache_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    strategy: str = "fsdp_sp") -> dict[str, Any]:
    """Decode-cache layouts.

    decode_32k : batch on (pod,data), sequence on 'model'.
    long_500k  : batch=1 -> sequence sharded over every available axis.
    SSM states : batch on data axes, SSD heads on 'model' when divisible.
    """
    daxes = data_axes(mesh)
    dtotal = int(np.prod([_axis_size(mesh, a) for a in daxes]))
    msz = model_axis_size(mesh)
    B, S = shape.global_batch, shape.seq_len
    b_ok = B % max(dtotal, 1) == 0 and dtotal > 1 and B >= dtotal
    bspec = daxes if b_ok else None

    if b_ok:
        seq_axes = "model" if S % msz == 0 else None
    else:
        all_ax = daxes + ("model",)
        tot = dtotal * msz
        seq_axes = all_ax if S % tot == 0 else ("model" if S % msz == 0 else None)

    out: dict[str, Any] = {}

    def kv():
        return NamedSharding(mesh, P(None, bspec, seq_axes, None, None))

    if cfg.family in ("dense", "vlm", "encdec"):
        out["k"] = kv()
        out["v"] = kv()
        if cfg.family == "encdec":
            out["enc_out"] = NamedSharding(mesh, P(bspec, None, None))
    elif cfg.family == "moe":
        if cfg.kv_lora_rank:
            out["c_kv"] = NamedSharding(mesh, P(None, bspec, seq_axes, None))
            out["k_pe"] = NamedSharding(mesh, P(None, bspec, seq_axes, None))
        else:
            out["k"] = kv()
            out["v"] = kv()
    if cfg.family in ("ssm", "hybrid"):
        nh_spec = "model" if cfg.ssm_heads % msz == 0 else None
        out["ssm"] = NamedSharding(mesh, P(None, bspec, nh_spec, None, None))
        out["conv"] = NamedSharding(mesh, P(None, bspec, None, None))
        if cfg.family == "hybrid":
            out["k"] = kv()
            out["v"] = kv()
    return out
