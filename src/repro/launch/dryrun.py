import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh)
combination against placeholder devices, and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
      --shape train_4k [--multi-pod] [--static] [--delta-frac 0.25] \
      [--out experiments/dryrun]

No arrays are allocated: inputs are ShapeDtypeStructs; the product is
compiled.memory_analysis() / cost_analysis() plus the parsed collective
schedule, dumped as JSON for EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import sys
import time
from typing import Any

import jax
import numpy as np

from repro.configs import SHAPES, get_config, input_specs, cache_specs, param_counts
from repro.core.recycle import LuarConfig
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.policy import use_policy
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   make_policy, param_shardings, replicated)
from repro.launch.steps import (TrainState, make_decode_step,
                                make_fedluar_train_step, make_prefill_step,
                                train_state_shapes)
from repro.models.registry import build


def _static_mask(um, frac: float):
    """Representative static recycle set: the largest units by bytes
    (the paper's FEMNIST/AG-News observation: the biggest layer is
    recycled most often)."""
    n = len(um.names)
    k = max(1, int(round(frac * n)))
    order = np.argsort(um.unit_bytes)[::-1]
    mask = [False] * n
    for i in order[:k]:
        mask[i] = True
    return tuple(mask)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              static: bool = False, delta_frac: float = 0.25,
              strategy: str = "fsdp_sp", compile_: bool = True) -> dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "skipped": "full-attention arch; 500k decode requires "
                           "sub-quadratic attention (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()

    pol = make_policy(mesh, cfg, strategy, shape) if shape.kind != "decode" else None
    with use_policy(pol):
        if shape.kind == "train":
            state_shapes, um = train_state_shapes(model)
            mask = _static_mask(um, delta_frac) if static else None
            delta = max(1, int(round(delta_frac * len(um.names))))
            step = make_fedluar_train_step(
                model, LuarConfig(delta=delta), um, static_mask=mask)
            psh = param_shardings(state_shapes.params, mesh, cfg, strategy)
            rep = replicated(mesh)
            luar_sh = state_shapes.luar.__class__(
                prev_update=psh, mask=rep, s=rep, staleness=rep,
                agg_count=rep, round=rep, key=rep)
            state_sh = TrainState(params=psh, momentum=psh, luar=luar_sh)
            bsh = batch_shardings(cfg, shape, mesh, strategy)
            fn = jax.jit(step, in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, rep))
            lowered = fn.lower(state_shapes, input_specs(cfg, shape))
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            psh = param_shardings(params_shapes, mesh, cfg, strategy)
            bsh = batch_shardings(cfg, shape, mesh, strategy)
            fn = jax.jit(make_prefill_step(model), in_shardings=(psh, bsh))
            lowered = fn.lower(params_shapes, input_specs(cfg, shape))
        else:  # decode
            params_shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            # serving layout: weight-stationary TP.  FSDP weight gathers
            # dominate per-token cost (measured 16x worse collective term —
            # EXPERIMENTS.md §Perf H4); the score-tile TP trap of training
            # does not apply to single-token queries.
            serve_strategy = "naive_tp" if strategy == "fsdp_sp" else strategy
            psh = param_shardings(params_shapes, mesh, cfg, serve_strategy)
            csh = cache_shardings(cfg, shape, mesh, strategy)
            bsh = batch_shardings(cfg, shape, mesh, strategy)
            cshapes = cache_specs(cfg, shape.global_batch, shape.seq_len)
            fn = jax.jit(make_decode_step(model),
                         in_shardings=(psh, csh, bsh),
                         out_shardings=(None, csh))
            lowered = fn.lower(params_shapes, cshapes, input_specs(cfg, shape))

        rec: dict[str, Any] = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "static": static, "strategy": strategy, "lower_s": round(time.time() - t0, 1),
        }
        if not compile_:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    # ---- analysis -------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory_analysis"] = f"unavailable: {e}"

    flops = bytes_accessed = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        rec["cost_analysis"] = {"flops": flops, "bytes_accessed": bytes_accessed}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = f"unavailable: {e}"

    # trip-count-corrected HLO analysis (cost_analysis counts loop bodies
    # once — see launch/hlo.py docstring)
    text = compiled.as_text()
    analysis = hlo.analyze(text)
    rec["hlo_analysis"] = {k: v for k, v in analysis.items()}
    rec["roofline"] = hlo.roofline(analysis)

    pc = param_counts(cfg)
    n_chips = 512 if multi_pod else 256
    if shape.kind == "train":
        model_flops = 6.0 * pc["active"] * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * pc["active"] * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * pc["active"] * shape.global_batch
    rec["model_flops_per_chip"] = model_flops / n_chips
    if analysis["flops"]:
        rec["useful_flops_ratio"] = rec["model_flops_per_chip"] / analysis["flops"]
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--static", action="store_true")
    ap.add_argument("--delta-frac", type=float, default=0.25)
    ap.add_argument("--strategy", default="fsdp_sp", choices=["fsdp_sp", "naive_tp"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    rec = lower_one(args.arch, args.shape, multi_pod=args.multi_pod,
                    static=args.static, delta_frac=args.delta_frac,
                    strategy=args.strategy)
    os.makedirs(args.out, exist_ok=True)
    mesh_tag = "pod2" if args.multi_pod else "pod1"
    sfx = (("_" + args.strategy) if args.strategy != "fsdp_sp" else "") + ("_static" if args.static else "") + (f"_{args.tag}" if args.tag else "")
    path = os.path.join(args.out, f"{args.arch}_{args.shape}_{mesh_tag}{sfx}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    print(json.dumps(rec, indent=2, default=str))
    print(f"\nwrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
