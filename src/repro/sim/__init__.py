"""repro.sim — event-driven async/heterogeneous FL simulator.

Prices each client round trip with the wall-clock cost model in
``repro.core.comm`` (download + compute + mask-aware upload) and runs
Alg. 2 under systems realism: heterogeneous devices, stragglers,
deadlines, dropout, and FedBuff-style buffered async aggregation.

    from repro.sim import SimConfig, run_sim, time_to_target
    res = run_sim(loss_fn, params, data, parts, fl_cfg,
                  SimConfig(scenario="bimodal", deadline=30.0), eval_fn)
    time_to_target(res, "acc", 0.9)     # simulated seconds to 90% acc
"""
from repro.configs.base import (SIM_SCENARIOS, SimScenario,  # noqa: F401
                                get_scenario, validate_scenario)
from repro.sim.engine import (DeltaLedger, MaskLedger, SimConfig,  # noqa: F401
                              SimResult, VersionLedger,
                              make_buffer_agg_fn, run_sim, time_to_target)
from repro.sim.events import (ARRIVAL, DEADLINE, DROPOUT, Event,  # noqa: F401
                              EventQueue)
from repro.sim.profiles import describe, sample_resources  # noqa: F401
