"""Event-driven federated simulator with a wall-clock cost model.

Two server modes over the same virtual-clock event queue:

  sync    — synchronous-with-deadline (Alg. 2 under systems realism):
            the server over-provisions a cohort, every member's round
            trip is priced by the cost model (download + tau local steps
            + mask-aware upload), and the round closes at the first of
            {all arrivals, ``collect`` arrivals, the deadline}.  Late
            clients are stragglers and their updates are discarded.
  fedbuff — buffered asynchronous aggregation: clients run continuously
            against whatever model version they last downloaded; the
            server merges every ``buffer_size`` arrivals into one
            staleness-discounted pseudo-update (core/recycle.py) and
            advances the model version.

Both modes compose with the LUAR core: the recycle set R_t means clients
skip those units on the uplink, which shrinks modeled upload time — the
mechanism by which byte savings become wall-clock savings.

Equivalence guarantee (tested): sync mode with the "uniform" scenario,
``deadline=inf``, no over-provisioning and no dropout replays the exact
RNG streams of ``fl/rounds.run_fl`` and runs the same jitted round body
(``make_round_step``), so it reproduces the synchronous trajectory
bit-for-bit — same seeds, same params.

Numerics vs. timing are decoupled (standard discrete-event style): local
training executes when an arrival is popped, but the virtual clock only
moves according to the cost model.  Systems randomness (dropout) draws
from a dedicated RNG stream so it never perturbs the learning RNG.
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_scenario
from repro.core import (luar_init, luar_round, payload_scale,
                        round_trip_time, staleness_weighted_merge)
from repro.core.comm import ClientResources, compute_time, download_time
from repro.fl import baselines
from repro.fl.client import local_update
from repro.fl.rounds import (FLConfig, _stack_client_batches,
                             apply_compressors, client_payload_bytes,
                             make_round_step)
from repro.fl.server import (apply_update, broadcast_point, server_init)
from repro.sim.events import ARRIVAL, DEADLINE, DROPOUT, EventQueue
from repro.sim.profiles import sample_resources

Params = Any


@dataclass
class SimConfig:
    scenario: Any = "uniform"        # SimScenario or name in SIM_SCENARIOS
    mode: str = "sync"               # "sync" | "fedbuff"
    # sync mode
    deadline: float = math.inf       # seconds before the round closes
    overprovision: float = 1.0       # cohort = round(n_active * this)
    collect: int = 0                 # close after this many arrivals (0 = all)
    # fedbuff mode
    buffer_size: int = 8             # K arrivals per aggregation
    staleness_alpha: float = 0.5     # discount (1+tau)^-alpha
    concurrency: int = 0             # clients in flight (0 -> n_active)
    max_sim_time: float = math.inf   # fedbuff stop condition (virtual seconds)
    sys_seed: int = 0                # systems RNG stream (dropout), separate
                                     # from the FLConfig data/cohort stream


@dataclass
class SimResult:
    history: List[Dict[str, float]] = field(default_factory=list)
    comm_ratio: float = 1.0
    sim_time: float = 0.0            # virtual seconds at finish
    rounds_done: int = 0             # aggregations applied (server versions)
    n_received: int = 0              # client updates accepted by the server
    n_stragglers: int = 0            # arrived-too-late / past-deadline drops
    n_dropped: int = 0               # device-vanished dispatches
    params: Any = None
    luar_state: Any = None
    resources: Optional[List[ClientResources]] = None


def time_to_target(result: SimResult, metric: str, target: float,
                   mode: str = "max") -> float:
    """First virtual time at which ``metric`` crosses ``target`` (inf if
    never).  mode="max" for accuracy-like, "min" for loss-like metrics."""
    for h in result.history:
        v = h.get(metric)
        if v is None:
            continue
        if (mode == "max" and v >= target) or (mode == "min" and v <= target):
            return h["t_sim"]
    return math.inf


def run_sim(loss_fn: Callable[[Params, Dict], jax.Array],
            init_params: Params,
            data: Dict[str, np.ndarray],
            parts: List[np.ndarray],
            cfg: FLConfig,
            sim: SimConfig,
            eval_fn: Optional[Callable[[Params], Dict[str, float]]] = None) -> SimResult:
    scenario = get_scenario(sim.scenario)
    resources = sample_resources(scenario, cfg.n_clients, sim.sys_seed)
    if sim.mode == "sync":
        return _run_sync(loss_fn, init_params, data, parts, cfg, sim,
                         resources, eval_fn)
    if sim.mode == "fedbuff":
        return _run_fedbuff(loss_fn, init_params, data, parts, cfg, sim,
                            resources, eval_fn)
    raise ValueError(f"unknown sim mode {sim.mode!r}")


# ---------------------------------------------------------------------------
# synchronous-with-deadline
# ---------------------------------------------------------------------------


def _run_sync(loss_fn, init_params, data, parts, cfg: FLConfig, sim: SimConfig,
              resources, eval_fn) -> SimResult:
    # learning-side RNG: IDENTICAL stream structure to run_fl
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)
    sys_rng = np.random.default_rng(np.random.SeedSequence([sim.sys_seed, 0xE7]))

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    lbgm_state = baselines.lbgm_init(params, um) if cfg.lbgm_threshold else None
    round_step = make_round_step(loss_fn, cfg, um)

    cohort_size = max(1, int(round(cfg.n_active * sim.overprovision)))
    scale = payload_scale(cfg.fedpaq_bits, cfg.prune_keep, cfg.dropout_rate)
    sizes = np.asarray(um.unit_bytes, np.float64)
    total_bytes = sizes.sum()

    queue = EventQueue()
    res = SimResult(resources=resources)
    uploaded = 0.0

    for t in range(cfg.rounds):
        cohort = rng.choice(cfg.n_clients, size=cohort_size, replace=False)
        batches = _stack_client_batches(data, parts, cohort, cfg.tau,
                                        cfg.batch_size, rng)
        key, qkey = jax.random.split(key)
        mask_now = np.asarray(luar_state.mask)

        # -- dispatch the cohort; price each member's round trip ----------
        t0 = queue.now
        n_scheduled = 0
        for pos, c in enumerate(cohort):
            r = resources[c]
            if r.dropout and sys_rng.random() < r.dropout:
                # device vanishes after download+compute, before upload
                queue.push(t0 + download_time(um, r) + compute_time(cfg.tau, r),
                           DROPOUT, int(c), {"pos": pos})
                continue
            queue.push(t0 + round_trip_time(um, mask_now, r, cfg.tau, scale),
                       ARRIVAL, int(c), {"pos": pos})
            n_scheduled += 1
        if math.isfinite(sim.deadline):
            queue.push(t0 + sim.deadline, DEADLINE)
        target = min(sim.collect, n_scheduled) if sim.collect else n_scheduled

        # -- drain events until the round closes --------------------------
        arrived_pos: List[int] = []
        while queue:
            ev = queue.pop()
            if ev.kind == DEADLINE:
                break
            if ev.kind == DROPOUT:
                res.n_dropped += 1
                continue
            arrived_pos.append(ev.payload["pos"])
            if len(arrived_pos) >= target:
                break
        res.n_stragglers += n_scheduled - len(arrived_pos)
        # pending DROPOUT events (device vanished later than the round
        # closed) still count as dropped, not as stragglers
        res.n_dropped += sum(1 for ev in queue.clear_pending()
                             if ev.kind == DROPOUT)

        if not arrived_pos:
            continue                      # nobody made it; model unchanged

        # -- aggregate the survivors (cohort order, not arrival order, so
        #    the homogeneous all-arrive case is bitwise run_fl) -----------
        arrived_pos.sort()
        if len(arrived_pos) == cohort_size:
            sub = batches
        else:
            # each distinct survivor count is a new leading dim and costs
            # one XLA compile of round_step; counts concentrate fast under
            # a fixed deadline, but pad-to-cohort with a weight mask would
            # be the upgrade if recompiles ever dominate (it would also
            # forfeit the bitwise-equality path with run_fl, so not now)
            idx = np.asarray(arrived_pos)
            sub = {k: v[idx] for k, v in batches.items()}
        params, luar_state, server_state, lbgm_state, lbgm_sent = round_step(
            params, luar_state, server_state, lbgm_state, sub, qkey)
        per_client = client_payload_bytes(sizes, mask_now, cfg, lbgm_sent)
        uploaded += per_client * len(arrived_pos)
        res.n_received += len(arrived_pos)
        res.rounds_done += 1

        if eval_fn is not None and ((t + 1) % cfg.eval_every == 0
                                    or t == cfg.rounds - 1):
            metrics = dict(eval_fn(params))
            metrics.update(round=t + 1, t_sim=queue.now,
                           comm_ratio=uploaded / max(total_bytes * res.n_received, 1.0))
            res.history.append(metrics)

    res.sim_time = queue.now
    res.comm_ratio = uploaded / max(total_bytes * res.n_received, 1.0)
    res.params = params
    res.luar_state = luar_state
    return res


# ---------------------------------------------------------------------------
# FedBuff-style buffered async
# ---------------------------------------------------------------------------


def _run_fedbuff(loss_fn, init_params, data, parts, cfg: FLConfig,
                 sim: SimConfig, resources, eval_fn) -> SimResult:
    if cfg.lbgm_threshold:
        raise NotImplementedError("LBGM needs a synchronous anchor; "
                                  "use sim mode='sync'")
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)
    sys_rng = np.random.default_rng(np.random.SeedSequence([sim.sys_seed, 0xE7]))

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    scale = payload_scale(cfg.fedpaq_bits, cfg.prune_keep, cfg.dropout_rate)
    sizes = np.asarray(um.unit_bytes, np.float64)
    total_bytes = sizes.sum()
    alpha = sim.staleness_alpha

    client_fn = jax.jit(lambda p, b: local_update(loss_fn, p, b, cfg.client))
    compress_fn = jax.jit(lambda delta, qkey: apply_compressors(delta, qkey, cfg))

    @jax.jit
    def agg_fn(params, luar_state, server_state, stacked, staleness):
        fresh = staleness_weighted_merge(stacked, staleness, alpha)
        applied, luar_state = luar_round(luar_state, um, cfg.luar, fresh, params)
        params, server_state = apply_update(params, applied, server_state,
                                            cfg.server)
        return params, luar_state, server_state

    queue = EventQueue()
    res = SimResult(resources=resources)
    uploaded = 0.0
    version = 0
    jobs: Dict[int, dict] = {}
    buffer: List[tuple] = []            # (delta, staleness_at_arrival)

    def dispatch(c: int, now: float):
        r = resources[c]
        idx = parts[c]
        sel = rng.choice(idx, size=(cfg.tau, cfg.batch_size), replace=True)
        batches = {k: jnp.asarray(arr[sel]) for k, arr in data.items()}
        mask_now = np.asarray(luar_state.mask)
        jobs[c] = {
            "start": broadcast_point(params, server_state, cfg.server),
            "batches": batches,
            "version": version,
            "bytes": client_payload_bytes(sizes, mask_now, cfg),
        }
        if r.dropout and sys_rng.random() < r.dropout:
            queue.push(now + download_time(um, r) + compute_time(cfg.tau, r),
                       DROPOUT, c)
        else:
            queue.push(now + round_trip_time(um, mask_now, r, cfg.tau, scale),
                       ARRIVAL, c)

    concurrency = min(sim.concurrency or cfg.n_active, cfg.n_clients)
    first = rng.choice(cfg.n_clients, size=concurrency, replace=False)
    # sorted list of idle client ids, maintained incrementally (O(log n)
    # insert + O(n) pop, vs rebuilding a sorted set per event)
    idle = sorted(set(range(cfg.n_clients)) - set(int(c) for c in first))
    for c in first:
        dispatch(int(c), 0.0)

    # hard event cap so a pathological population (e.g. dropout ~1) cannot
    # spin the loop forever when max_sim_time is inf
    max_events = 100 * (cfg.rounds * sim.buffer_size + concurrency)
    n_events = 0
    while version < cfg.rounds and queue and queue.now < sim.max_sim_time:
        n_events += 1
        if n_events > max_events:
            break
        ev = queue.pop()
        c = ev.client
        job = jobs.pop(c)
        bisect.insort(idle, c)          # the slot's device is idle again
        if ev.kind == ARRIVAL:
            key, qkey = jax.random.split(key)
            delta = compress_fn(client_fn(job["start"], job["batches"]), qkey)
            buffer.append((delta, version - job["version"]))
            uploaded += job["bytes"]
            res.n_received += 1
            if len(buffer) >= sim.buffer_size:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *[d for d, _ in buffer])
                stal = jnp.asarray([s for _, s in buffer], jnp.int32)
                params, luar_state, server_state = agg_fn(
                    params, luar_state, server_state, stacked, stal)
                buffer.clear()
                version += 1
                res.rounds_done = version
                if eval_fn is not None and (version % cfg.eval_every == 0
                                            or version == cfg.rounds):
                    metrics = dict(eval_fn(params))
                    metrics.update(round=version, t_sim=queue.now,
                                   comm_ratio=uploaded / max(
                                       total_bytes * res.n_received, 1.0))
                    res.history.append(metrics)
        else:
            res.n_dropped += 1
        # the slot is free again: hand the next idle client a fresh model
        dispatch(idle.pop(int(rng.integers(len(idle)))), queue.now)

    res.sim_time = queue.now
    res.comm_ratio = uploaded / max(total_bytes * res.n_received, 1.0)
    res.params = params
    res.luar_state = luar_state
    return res
