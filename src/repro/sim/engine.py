"""Event-driven federated simulator with a wall-clock cost model.

Two server modes over the same virtual-clock event queue:

  sync    — synchronous-with-deadline (Alg. 2 under systems realism):
            the server over-provisions a cohort, every member's round
            trip is priced by the cost model (download + tau local steps
            + mask-aware upload), and the round closes at the first of
            {all arrivals, ``collect`` arrivals, the deadline}.  Late
            clients are stragglers and their updates are discarded.
  fedbuff — buffered asynchronous aggregation: clients run continuously
            against whatever model version they last downloaded; the
            server merges every ``buffer_size`` arrivals into one
            staleness-discounted pseudo-update (core/recycle.py) and
            advances the model version.

The fedbuff mode is staleness-aware at the MASK level (the LUAR axis of
staleness the paper never faces): the server keeps a ``MaskLedger`` — a
ring buffer of every dispatched recycle set R_v keyed by model version —
and each in-flight client record carries the version it downloaded.  At
merge time the ledger reconstructs exactly which units each buffered
client uploaded, the merge renormalizes its discount weights PER UNIT
over the clients that actually uploaded that unit, and a unit no valid
client uploaded falls back to recycling the server's prev_update
(``staleness_weighted_merge(validity=...)`` + ``luar_round``'s mask
override).  Consequently no uploaded byte is ever silently discarded:
``SimResult.wasted_per_unit`` is exactly zero with the ledger enabled
on a run that completes without ledger misses (rejected miss payloads
and buffer remnants stranded by a max_sim_time cutoff are explicitly
charged to the same ledger), whereas the PR-1 semantics
(``mask_ledger=False``) silently discard every byte a stale client
uploaded for a unit the CURRENT mask recycles.

Both modes compose with the LUAR core: the recycle set R_t means clients
skip those units on the uplink, which shrinks modeled upload time — the
mechanism by which byte savings become wall-clock savings.  The upload
payload itself runs through the declared update-codec pipeline
(``repro.compress``): encode happens on the cohort mean (sync) or per
client delta (fedbuff, where stateful stages like EF error feedback keep
PER-CLIENT state), wall-clock estimates use the pipeline's nominal
pricing at dispatch, and the byte ledger uses the exact aux-refined
pricing after encode.  Diurnal scenarios additionally scale each
dispatch's link bandwidth by the virtual-time-of-day multiplier.

The cost model is BIDIRECTIONAL: every dispatch also prices its
server->client broadcast through the DOWN pipeline (the ``down:``-
prefixed stages of the same ``FLConfig.codecs``).  With ``down:delta``
the fedbuff server keeps a ``DeltaLedger`` — the downlink sibling of the
``MaskLedger``, same ring-buffer eviction — recording each aggregation's
per-unit delta-step price, and a dispatch to a client last served at
version v ships the delta chain v->current when it is still
ledger-resident and cheaper than a cache-seeding full snapshot (priced
host-side in float64, per dispatch).  The sync engine exercises the same
pricing path with the population pinned one version behind the barrier.
``SimResult`` carries the download ledger (``downloaded``/``down_ratio``
vs the full-broadcast baseline, full-vs-delta download counts) next to
the upload one, and downlink bytes whose round trip produced nothing the
server used (dropouts, stragglers, rejected misses, stranded buffers,
in-flight at cutoff) are charged to ``wasted_download_bytes`` — the
broadcast leg was unpriced and uncompressible before, which also hid
that the headline "comm ratio" ignored half of every round trip.

Equivalence guarantee (tested): sync mode with the "uniform" scenario,
``deadline=inf``, no over-provisioning and no dropout replays the exact
RNG streams of ``fl/rounds.run_fl`` and runs the same jitted round body
(``make_round_step``), so it reproduces the synchronous trajectory
bit-for-bit — same seeds, same params.

Numerics vs. timing are decoupled (standard discrete-event style): local
training executes when an arrival is popped, but the virtual clock only
moves according to the cost model.  Systems randomness (dropout) draws
from a dedicated RNG stream so it never perturbs the learning RNG.
"""
from __future__ import annotations

import bisect
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import (Direction, delta_step_price, snapshot_price,
                            versioned_download_price)
from repro.configs.base import get_scenario
from repro.core import (fused_buffer_round, luar_init, luar_round,
                        round_trip_time, staleness_discount,
                        staleness_weighted_merge)
from repro.core.comm import ClientResources, compute_time, download_time
from repro.fl.client import local_update
from repro.fl.rounds import (FLConfig, _stack_client_batches,
                             build_codec_pipeline, init_codec_states,
                             make_round_step, server_broadcast_additive)
from repro.fl.server import (apply_update, broadcast_point, server_init)
from repro.obs import (AGGREGATE, DISPATCH, EVICT, M_ACCEPTED, M_COMM_RATIO,
                       M_DISPATCHES, M_DOWN_RATIO, M_DOWNLOAD_BYTES,
                       M_DOWNLOADS_DELTA, M_DOWNLOADS_FULL, M_DROPOUTS,
                       M_FAIRNESS, M_INFLIGHT_END, M_LEDGER_EVICTIONS,
                       M_LEDGER_MISSES, M_ROUNDS, M_SIM_TIME, M_STALENESS,
                       M_STRAGGLERS, M_STRANDED_END, M_UPLINKS,
                       M_UPLOAD_BYTES, M_WASTED_DOWN, M_WASTED_UP,
                       RUN_END, RUN_START, STALENESS_BUCKETS, Telemetry,
                       UPLOAD, WAKE as TRACE_WAKE, fairness_from_metrics)
from repro.participate import (HT_CLIP, RoundContext, fairness_summary,
                               ht_weights, resolve_policy)
from repro.sim.events import ARRIVAL, DEADLINE, DROPOUT, WAKE, EventQueue
from repro.sim.profiles import (bandwidth_multiplier, sample_resources,
                                scale_bandwidth)

Params = Any


class VersionLedger:
    """Bounded ring buffer keyed by (monotonically growing) server
    version — the shared storage/eviction policy of the per-version
    server ledgers (``MaskLedger`` for the uplink, ``DeltaLedger`` for
    the downlink).  ``record`` is idempotent per version; when capacity
    overflows the OLDEST version is evicted (and counted), so a lookup
    miss means "this version's record aged out while the client was in
    flight".  Size the capacity above the worst-case version lag to make
    misses impossible."""

    def __init__(self, capacity: int = 64,
                 on_evict: Callable[[int], None] | None = None):
        if capacity < 1:
            raise ValueError(f"ledger capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[int, Any] = OrderedDict()
        self.evictions = 0
        self.on_evict = on_evict        # telemetry hook: called with the
                                        # evicted version (repro.obs EVICT)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, version: int) -> bool:
        return version in self._entries

    def record(self, version: int, value: Any) -> None:
        if version in self._entries:
            return
        self._entries[version] = value
        while len(self._entries) > self.capacity:
            old_v, _ = self._entries.popitem(last=False)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old_v)

    def get(self, version: int) -> Any | None:
        """The record at ``version``, or None if evicted/never seen."""
        return self._entries.get(version)

    # -- state extraction (repro.serve checkpointing) -------------------
    # The ledgers are part of the server's recoverable state: a resumed
    # round server must reject/price exactly what the killed one would
    # have, so the ENTRY ORDER (eviction order) and the eviction counter
    # both round-trip.

    def export_state(self) -> tuple[list[tuple[int, Any]], int]:
        """(ordered entries, eviction count) — insertion order preserved."""
        return list(self._entries.items()), self.evictions

    def import_state(self, entries: list[tuple[int, Any]],
                     evictions: int = 0) -> None:
        """Replace contents with ``entries`` (oldest first), bypassing the
        ``on_evict`` hook — restoring is not evicting."""
        if len(entries) > self.capacity:
            raise ValueError(f"cannot import {len(entries)} entries into a "
                             f"capacity-{self.capacity} ledger")
        self._entries = OrderedDict((int(v), val) for v, val in entries)
        self.evictions = int(evictions)


class MaskLedger(VersionLedger):
    """Ring buffer of dispatched recycle sets R_v keyed by server version.

    The fedbuff server records R_v when the first client at version v is
    dispatched (idempotent: the mask only changes when an aggregation
    advances the version); an arrival looks up the version it downloaded
    to reconstruct exactly which units it uploaded.  On a miss (version
    evicted mid-flight) the update is rejected outright — excluded from
    the merge, not counted as received — and its payload charged as
    wasted, the conservative choice since the server can no longer verify
    which recycle set the payload was built against.
    """

    def record(self, version: int, mask: np.ndarray) -> None:
        super().record(version, np.array(mask, bool, copy=True))


class DeltaLedger(VersionLedger):
    """Ring buffer of per-version applied-update records for the
    versioned downlink (``down:delta``) — the downlink sibling of
    ``MaskLedger``, same eviction policy.

    The fedbuff server records one entry per aggregation: the per-unit
    wire price of the delta step v -> v+1 (``compress.delta_step_price``
    of the recycle set that aggregation actually applied) and, when
    ``store_trees`` is on, the applied-update tree itself.  A dispatch to
    a client last served at version v asks for ``chain_price(v, V)``; any
    evicted step forces the full snapshot instead — mirroring the
    MaskLedger's reject-on-miss conservatism on the other link.

    ``store_trees`` keeps O(model) host memory per entry and exists for
    the losslessness guarantee: ``reconstruct`` replays the chain with
    the exact tree additions the additive server performed, so the result
    is bit-for-bit the server's later broadcast (tested).  The engines
    run with prices only.
    """

    def __init__(self, capacity: int = 64, store_trees: bool = False,
                 on_evict: Callable[[int], None] | None = None):
        super().__init__(capacity, on_evict)
        self.store_trees = store_trees

    def record_step(self, version: int, step_price: np.ndarray,
                    applied: Any = None) -> None:
        tree = None
        if self.store_trees:
            tree = jax.tree.map(lambda a: np.array(a, copy=True), applied)
        self.record(version, (np.asarray(step_price, np.float64), tree))

    def chain_price(self, v_from: int, v_to: int,
                    n_units: int) -> np.ndarray | None:
        """Summed per-unit wire bytes of the delta chain
        ``v_from -> v_to``, or None if any step was evicted.  An empty
        chain (client already current) is priced at exactly zero."""
        total = np.zeros(n_units, np.float64)
        for v in range(v_from, v_to):
            entry = self.get(v)
            if entry is None:
                return None
            total = total + entry[0]
        return total

    def reconstruct(self, params: Any, v_from: int, v_to: int) -> Any:
        """Replay the stored applied-update chain onto ``params`` (the
        broadcast at ``v_from``) — the client-side decode of the delta
        download.  Requires ``store_trees``; raises on a missing step."""
        if not self.store_trees:
            raise RuntimeError("reconstruct needs DeltaLedger(store_trees=True)")
        out = params
        for v in range(v_from, v_to):
            entry = self.get(v)
            if entry is None:
                raise KeyError(f"delta step {v} evicted; chain {v_from}->{v_to} "
                               f"is not reconstructible")
            out = jax.tree.map(lambda p, d: p + d, out, entry[1])
        return out


def make_buffer_agg_fn(cfg: FLConfig, um, fedasync: bool = False):
    """The jitted buffered-aggregation body — ONE function shared by the
    fedbuff engine and the ``repro.serve`` round service, so the live
    server's merge is bit-for-bit the simulator's.

    Per-unit validity merge: a unit is averaged only over the clients
    whose dispatched mask says they uploaded it; the weight mass of
    clients that skipped a unit goes to the recycled direction
    (fallback), which keeps small stale subsets from being blown up to
    full magnitude under non-IID data.  ``ht`` (biased policies only;
    None leaves the trace bit-for-bit) folds the policy's
    inverse-inclusion-probability weights into the same normalization,
    so selection bias and staleness discounting are corrected by ONE
    self-normalizing merge.  With ``cfg.luar.fused_agg`` the merge +
    select + Eq. (1) norms collapse into one batched Pallas sweep
    (same math, see ``core.fused_buffer_round``).
    """

    @jax.jit
    def agg_fn(params, luar_state, server_state, stacked, staleness,
               validity, alpha_t, ht=None):
        if cfg.luar.fused_agg:
            applied, luar_state = fused_buffer_round(
                luar_state, um, cfg.luar, stacked, staleness, alpha_t,
                params, validity=validity, ht=ht, fedasync=fedasync)
        else:
            fresh = staleness_weighted_merge(stacked, staleness, alpha_t,
                                             validity=validity, um=um,
                                             fallback=luar_state.prev_update,
                                             ht=ht)
            if fedasync:
                # a K=1 buffer renormalizes any discount back to 1, so the
                # staleness weight must scale the server mixing rate
                # instead: x <- x + (1+tau)^-alpha * delta  (FedAsync)
                eta = staleness_discount(staleness[0], alpha_t)
                fresh = jax.tree.map(lambda l: l * eta, fresh)
            # units NO valid client uploaded recycle prev_update; when
            # every buffered client saw the current mask this is
            # state.mask exactly
            eff_mask = ~jnp.any(validity, axis=0)
            applied, luar_state = luar_round(luar_state, um, cfg.luar,
                                             fresh, params,
                                             mask_override=eff_mask)
        params, server_state = apply_update(params, applied, server_state,
                                            cfg.server)
        return params, luar_state, server_state

    return agg_fn


@dataclass
class SimConfig:
    scenario: Any = "uniform"        # SimScenario or name in SIM_SCENARIOS
    mode: str = "sync"               # "sync" | "fedbuff"
    # sync mode
    deadline: float = math.inf       # seconds before the round closes
    overprovision: float = 1.0       # cohort = round(n_active * this)
    collect: int = 0                 # close after this many arrivals (0 = all)
    # fedbuff mode
    buffer_size: int = 8             # K arrivals per aggregation
    staleness_alpha: float = 0.5     # discount (1+tau)^-alpha
    concurrency: int = 0             # clients in flight (0 -> n_active)
    max_sim_time: float = math.inf   # fedbuff stop condition (virtual seconds)
    mask_ledger: bool = True         # versioned-mask merge: average each unit
                                     # only over clients that uploaded it;
                                     # False = PR-1 semantics (merge against
                                     # the CURRENT mask, stale uploads for
                                     # recycled units silently discarded)
    ledger_capacity: int = 64        # MaskLedger ring size (versions)
    adaptive_alpha: bool = False     # schedule alpha from observed staleness
                                     # quantiles (FedAsync-style; see
                                     # _schedule_alpha)
    staleness_window: int = 512      # trailing arrivals the schedule looks at
    sys_seed: int = 0                # systems RNG stream (dropout), separate
                                     # from the FLConfig data/cohort stream


@dataclass
class SimResult:
    history: list[dict[str, float]] = field(default_factory=list)
    comm_ratio: float = 1.0          # uplink bytes / (full model x every
                                     # SPENT uplink) — the FedAvg baseline
                                     # would have paid for the same straggler
                                     # and rejected uploads, so they appear
                                     # in BOTH numerator and denominator
    downloaded: float = 0.0          # cumulative server->client bytes (f64)
    down_ratio: float = 1.0          # downlink bytes / (full model x every
                                     # dispatch) — the full-broadcast baseline
    sim_time: float = 0.0            # virtual seconds at finish
    rounds_done: int = 0             # aggregations applied (server versions)
    n_received: int = 0              # client updates accepted by the server
    n_uplinks_spent: int = 0         # uploads that actually crossed the wire
                                     # (accepted + stragglers + rejected
                                     # misses; the comm_ratio denominator)
    n_dispatched: int = 0            # downloads served (every dispatch,
                                     # including later dropouts)
    n_full_downloads: int = 0        # snapshot downlinks (versioning off,
                                     # first contact, miss, or chain lost
                                     # the price comparison)
    n_delta_downloads: int = 0       # delta-chain downlinks (down:delta)
    n_stragglers: int = 0            # arrived-too-late / past-deadline drops
    n_dropped: int = 0               # device-vanished dispatches
    n_inflight_end: int = 0          # dispatches still in flight at finish
    # staleness-aware LUAR accounting (fedbuff; sync fills in the trivia)
    wasted_per_unit: np.ndarray | None = None
    #   ^ uploaded-then-discarded bytes per unit; exactly zero with the
    #     mask ledger enabled and no ledger misses (every uploaded unit
    #     is used by the merge)
    wasted_upload_bytes: float = 0.0   # total (== wasted_per_unit.sum())
    wasted_download_bytes: float = 0.0  # downlink bytes whose round trip
                                     # produced nothing the server used:
                                     # dropouts (vanish after download),
                                     # stragglers, rejected misses, stranded
                                     # buffer entries, in-flight at cutoff
    ledger_misses: int = 0           # arrivals whose dispatch-mask version
                                     # was already evicted; with the ledger
                                     # enabled these are rejected outright
                                     # (not merged, not in n_received)
    n_stranded_end: int = 0          # accepted uploads left in a partially
                                     # filled buffer when a truncated run
                                     # (max_sim_time / event cap) stopped;
                                     # their unmerged payload is charged to
                                     # the waste ledger
    # participation telemetry (repro.participate): biased cohort policies
    # are only trustworthy if their bias is observable
    participation_count: np.ndarray | None = None  # dispatches per client
    dropout_count: np.ndarray | None = None        # mid-round deaths per
                                                      # client
    fairness: dict[str, float] | None = None       # min/median/max of
                                                      # participation_count
    staleness_observed: np.ndarray | None = None   # per accepted arrival
    staleness_q: dict[str, float] | None = None    # q50/q90/max summary
    alphas: list[float] = field(default_factory=list)  # alpha per aggregation
    params: Any = None
    luar_state: Any = None
    resources: list[ClientResources] | None = None


def time_to_target(result: SimResult, metric: str, target: float,
                   mode: str = "max") -> float:
    """First virtual time at which ``metric`` crosses ``target`` (inf if
    never).  mode="max" for accuracy-like, "min" for loss-like metrics."""
    if mode not in ("max", "min"):
        # a typo'd mode used to fall through every comparison and return
        # inf — indistinguishable from "never reached the target"
        raise ValueError(f"time_to_target mode must be 'max' or 'min', "
                         f"got {mode!r}")
    for h in result.history:
        v = h.get(metric)
        if v is None:
            continue
        if (mode == "max" and v >= target) or (mode == "min" and v <= target):
            return h["t_sim"]
    return math.inf


def _staleness_quantiles(observed: list[int]) -> dict[str, float] | None:
    if not observed:
        return None
    arr = np.asarray(observed, np.float64)
    return {"q50": float(np.quantile(arr, 0.5)),
            "q90": float(np.quantile(arr, 0.9)),
            "max": float(arr.max())}


_ALPHA_TARGET_W = 0.1               # weight a q90-stale update is pushed to


def _schedule_alpha(base: float, observed: list[int], window: int) -> float:
    """FedAsync-style adaptive alpha from observed staleness quantiles.

    Picks the alpha that discounts an update at the 90th-percentile
    observed staleness (over the trailing ``window`` arrivals) down to
    weight ~1/10 — (1 + q90)^-alpha = 0.1 — clipped to [base/4, 4*base]
    so a pathological tail cannot flatten or obliterate the discount.
    The stability-first direction matters: the stale tail should be
    background signal, not a co-driver (empirically, under-discounting a
    q90 ~ 10 tail on non-IID data diverges, while alpha ~ 1 recovers).
    With no staleness observed yet (or q90 = 0, where any alpha yields
    weight 1) it returns ``base``.
    """
    if not observed:
        return base
    q90 = float(np.quantile(np.asarray(observed[-window:], np.float64), 0.9))
    if q90 <= 0.0:
        return base
    return float(np.clip(math.log(1.0 / _ALPHA_TARGET_W) / math.log1p(q90),
                         0.25 * base, 4.0 * base))


def run_sim(loss_fn: Callable[[Params, dict], jax.Array],
            init_params: Params,
            data: dict[str, np.ndarray],
            parts: list[np.ndarray],
            cfg: FLConfig,
            sim: SimConfig,
            eval_fn: Callable[[Params], dict[str, float]] | None = None,
            telemetry: Telemetry | None = None) -> SimResult:
    scenario = get_scenario(sim.scenario)
    resources = sample_resources(scenario, cfg.n_clients, sim.sys_seed)
    tele = telemetry if telemetry is not None else Telemetry()
    if sim.mode == "sync":
        return _run_sync(loss_fn, init_params, data, parts, cfg, sim,
                         scenario, resources, eval_fn, tele)
    if sim.mode == "fedbuff":
        return _run_fedbuff(loss_fn, init_params, data, parts, cfg, sim,
                            scenario, resources, eval_fn, tele)
    raise ValueError(f"unknown sim mode {sim.mode!r}")


class _Instruments:
    """The engine-side metric handles (one labelset each, grabbed once so
    the hot loops skip the family lookup).  Every ledger the engines used
    to accumulate inline lives behind these now; ``_finalize`` derives
    the SimResult fields from them bit-for-bit."""

    def __init__(self, tele: Telemetry):
        m = tele.metrics
        self.up = m.counter(M_UPLOAD_BYTES, "client->server wire bytes",
                            "bytes").labels()
        self.down = m.counter(M_DOWNLOAD_BYTES, "server->client wire bytes",
                              "bytes").labels()
        self.uplinks = m.counter(M_UPLINKS,
                                 "uploads that crossed the wire").labels()
        self.dispatches = m.counter(M_DISPATCHES, "downloads served").labels()
        self.accepted = m.counter(M_ACCEPTED,
                                  "client updates the server merged").labels()
        self.rounds = m.counter(M_ROUNDS, "aggregations applied").labels()
        self.stragglers = m.counter(M_STRAGGLERS,
                                    "arrived-too-late drops").labels()
        self.dropouts = m.counter(M_DROPOUTS,
                                  "device-vanished dispatches").labels()
        self.misses = m.counter(M_LEDGER_MISSES,
                                "arrivals whose dispatch mask version was "
                                "evicted").labels()
        self.evictions = m.counter(M_LEDGER_EVICTIONS,
                                   "version-ledger evictions")
        self.wasted_up = m.counter(M_WASTED_UP,
                                   "uploaded-then-discarded bytes",
                                   "bytes").labels()
        self.wasted_down = m.counter(M_WASTED_DOWN,
                                     "downlink bytes of fruitless round "
                                     "trips", "bytes").labels()
        self.full_dl = m.counter(M_DOWNLOADS_FULL,
                                 "snapshot downlinks").labels()
        self.delta_dl = m.counter(M_DOWNLOADS_DELTA,
                                  "delta-chain downlinks").labels()
        self.staleness = m.histogram(M_STALENESS,
                                     "version lag per accepted arrival",
                                     "rounds", STALENESS_BUCKETS).labels()

    def finalize(self, m, res: SimResult, total_bytes: float,
                 sim_time: float, part_count, drop_count) -> None:
        """Derive the counter-backed SimResult fields + summary gauges."""
        res.comm_ratio = float(self.up.value
                               / max(total_bytes * self.uplinks.value, 1.0))
        res.downloaded = self.down.value
        res.down_ratio = float(self.down.value
                               / max(total_bytes * self.dispatches.value, 1.0))
        res.n_received = int(self.accepted.value)
        res.n_uplinks_spent = int(self.uplinks.value)
        res.n_dispatched = int(self.dispatches.value)
        res.n_full_downloads = int(self.full_dl.value)
        res.n_delta_downloads = int(self.delta_dl.value)
        res.n_stragglers = int(self.stragglers.value)
        res.n_dropped = int(self.dropouts.value)
        res.rounds_done = int(self.rounds.value)
        res.ledger_misses = int(self.misses.value)
        res.wasted_upload_bytes = self.wasted_up.value
        res.wasted_download_bytes = self.wasted_down.value
        res.sim_time = sim_time
        m.gauge(M_SIM_TIME, "virtual seconds at finish").set(sim_time)
        m.gauge(M_COMM_RATIO, "uplink bytes vs FedAvg same-uplinks").set(
            res.comm_ratio)
        m.gauge(M_DOWN_RATIO, "downlink bytes vs full-model broadcast").set(
            res.down_ratio)
        g_fair = m.gauge(M_FAIRNESS, "participation spread across clients")
        for stat, v in fairness_summary(part_count).items():
            g_fair.labels(stat=stat).set(v)
        res.participation_count = part_count
        res.dropout_count = drop_count
        res.fairness = fairness_from_metrics(m)


# ---------------------------------------------------------------------------
# synchronous-with-deadline
# ---------------------------------------------------------------------------


def _run_sync(loss_fn, init_params, data, parts, cfg: FLConfig, sim: SimConfig,
              scenario, resources, eval_fn, tele: Telemetry) -> SimResult:
    # learning-side RNG: IDENTICAL stream structure to run_fl
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)
    sys_rng = np.random.default_rng(np.random.SeedSequence([sim.sys_seed, 0xE7]))

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    pipeline = build_codec_pipeline(cfg)
    down_pipe = build_codec_pipeline(cfg, Direction.DOWN)
    codec_state = init_codec_states(params, um, pipeline, down_pipe)
    round_step = make_round_step(loss_fn, cfg, um, pipeline, down_pipe)
    step_w = None                    # HT-weighted variant, built on demand

    # cohort selection is a policy decision (repro.participate); the
    # scenario's scalar dropout is subsumed as an avail:bernoulli shim
    policy = resolve_policy(cfg.participation, cfg.n_clients, cfg.seed,
                            scenario)
    all_ids = np.arange(cfg.n_clients)
    part_count = np.zeros(cfg.n_clients, np.int64)
    drop_count = np.zeros(cfg.n_clients, np.int64)

    cohort_size = max(1, int(round(cfg.n_active * sim.overprovision)))
    sizes = np.asarray(um.unit_bytes, np.float64)
    n_units = len(um.names)
    total_bytes = sizes.sum()
    # downlink versioning (down:delta): under the synchronous barrier the
    # subscribed population receives every broadcast, so an already-seeded
    # member is at most ONE aggregation behind — ``pending_chain`` holds
    # the per-unit price of the model change since the last broadcast
    # (zero when no round aggregated, one delta step otherwise) — while a
    # FIRST CONTACT holds no base snapshot and pays the cache-seeding
    # full download.  Non-additive servers (fedopt/fedacg) cannot let
    # clients derive recycled units: versioning disables itself and every
    # dispatch is the plain snapshot.
    additive = server_broadcast_additive(cfg)
    has_delta = down_pipe.has("delta") and additive
    seed_cache = has_delta and cfg.luar.mode == "recycle"
    no_mask = np.zeros(n_units, bool)
    pending_chain: np.ndarray | None = None
    seen: set = set()                # clients holding a base snapshot

    queue = EventQueue()
    res = SimResult(resources=resources,
                    wasted_per_unit=np.zeros(n_units, np.float64))
    # synchronous rounds cannot see mask staleness: every cohort member
    # downloads the current R_t and the merge applies that same R_t
    res.staleness_observed = np.zeros(0, np.int32)
    ins = _Instruments(tele)
    tr = tele.trace
    if tr:
        tr.emit(RUN_START, 0.0, engine="sim", mode="sync",
                n_clients=cfg.n_clients, rounds=cfg.rounds,
                n_units=n_units, units=list(um.names))

    def emit_eval(t: int) -> None:
        """One eval-cadence history row (shared by aggregated AND empty
        rounds, so the schema can never drift between them)."""
        if eval_fn is not None and ((t + 1) % cfg.eval_every == 0
                                    or t == cfg.rounds - 1):
            with tele.span("eval"):
                metrics = dict(eval_fn(params))
            metrics.update(round=t + 1, t_sim=queue.now,
                           up_mb=ins.up.value / 1e6,
                           comm_ratio=ins.up.value / max(
                               total_bytes * ins.uplinks.value, 1.0),
                           down_ratio=ins.down.value / max(
                               total_bytes * ins.dispatches.value, 1.0))
            res.history.append(metrics)

    for t in range(cfg.rounds):
        sel = policy.select(RoundContext(
            rng=rng, n_clients=cfg.n_clients, cohort_size=cohort_size,
            candidates=all_ids, population=True, sim=True, round=t,
            now=queue.now, bw_period=scenario.bw_period))
        cohort = np.asarray(sel.cohort, np.int64)
        np.add.at(part_count, cohort, 1)
        if len(cohort) == 0:
            # nobody eligible (e.g. all batteries flat): the round never
            # opens, but virtual time still passes — the server idles one
            # deadline (or one population-mean round trip when unbounded)
            # so that recharge-with-time policies can ever revive; a
            # frozen clock would silently skip every remaining round.
            # The eval cadence still reports (matching run_fl), so a run
            # whose population dies keeps an honest final history row
            idle_wait = (sim.deadline if math.isfinite(sim.deadline) else
                         float(np.mean([round_trip_time(
                             um, np.asarray(luar_state.mask), r, cfg.tau)
                             for r in resources])))
            queue.push(queue.now + idle_wait, DEADLINE)
            queue.pop()
            emit_eval(t)
            continue
        weights = None if sel.uniform else ht_weights(sel, clip=HT_CLIP)
        batches = _stack_client_batches(data, parts, cohort, cfg.tau,
                                        cfg.batch_size, rng)
        key, qkey = jax.random.split(key)
        mask_now = np.asarray(luar_state.mask)

        # -- dispatch the cohort; price each member's round trip ----------
        # dispatch-time (nominal, aux-free) pricing: the conservative
        # wall-clock estimate for stacks whose exact wire size is only
        # known after encode (LBGM scalars, top-k survivor counts)
        with tele.span("pricing"):
            nominal_per_unit = pipeline.price_per_unit(sizes, mask_now)
            nominal_bytes = float(nominal_per_unit.sum())
            # downlink: price this round's broadcast per member — an
            # already-seeded member ships the pending chain step vs snapshot
            # (whichever is cheaper, host f64), a first contact ships the
            # cache-seeding snapshot — the full pricing path of the async
            # engine with the seeded lag pinned to one
            if has_delta:
                snap_pu = snapshot_price(sizes, mask_now, seed_cache)
                snap_bytes = down_pipe.price_bytes(
                    sizes, no_mask, down_pipe.aux_for("delta", snap_pu))
                chain_pu, used_chain = versioned_download_price(
                    sizes, mask_now, pending_chain, seed_cache=seed_cache)
                chain_bytes = down_pipe.price_bytes(
                    sizes, no_mask, down_pipe.aux_for("delta", chain_pu))
                pending_chain = np.zeros(n_units, np.float64)  # population
                                                               # current
            else:
                snap_bytes = chain_bytes = down_pipe.price_bytes(
                    sizes, no_mask, None)
                used_chain = False
        t0 = queue.now
        bw = bandwidth_multiplier(scenario, t0)     # diurnal link quality
        n_scheduled = 0
        down_by_pos: dict[int, float] = {}
        sched_pos: set = set()
        for pos, c in enumerate(cohort):
            first = has_delta and int(c) not in seen
            seen.add(int(c))
            down_bytes = snap_bytes if first else chain_bytes
            down_by_pos[pos] = down_bytes
            ins.down.add(down_bytes)
            ins.dispatches.inc()
            if used_chain and not first:
                ins.delta_dl.inc()
            else:
                ins.full_dl.inc()
            if tr:
                tr.emit(DISPATCH, t0, round=t, client=int(c),
                        version=int(ins.rounds.value),
                        down_bytes=down_bytes,
                        delta=bool(used_chain and not first), first=first)
            r = scale_bandwidth(resources[c], bw)
            if not policy.dispatch_survives(int(c), r, sys_rng):
                # device vanishes after download+compute, before upload
                t_busy = (download_time(um, r, down_bytes)
                          + compute_time(cfg.tau, r))
                queue.push(t0 + t_busy, DROPOUT, int(c), {"pos": pos})
                policy.observe_dispatch(int(c), now=t0, cost_s=t_busy)
                continue
            t_busy = round_trip_time(um, mask_now, r, cfg.tau,
                                     payload_bytes=nominal_bytes,
                                     download_bytes=down_bytes)
            queue.push(t0 + t_busy, ARRIVAL, int(c), {"pos": pos})
            policy.observe_dispatch(int(c), now=t0, cost_s=t_busy)
            n_scheduled += 1
            sched_pos.add(pos)
        if math.isfinite(sim.deadline):
            queue.push(t0 + sim.deadline, DEADLINE)
        target = min(sim.collect, n_scheduled) if sim.collect else n_scheduled

        # -- drain events until the round closes --------------------------
        arrived_pos: list[int] = []
        n_drop_round = 0
        while queue:
            ev = queue.pop()
            if ev.kind == DEADLINE:
                break
            if ev.kind == DROPOUT:
                n_drop_round += 1
                drop_count[ev.client] += 1
                ins.wasted_down.add(down_by_pos[ev.payload["pos"]])
                if tr:
                    tr.emit(UPLOAD, ev.time, round=t, client=ev.client,
                            status="dropout", bytes=0.0)
                continue
            arrived_pos.append(ev.payload["pos"])
            if tr:
                tr.emit(UPLOAD, ev.time, round=t, client=ev.client,
                        status="accepted", lag=0)
            if len(arrived_pos) >= target:
                break
        n_strag = n_scheduled - len(arrived_pos)
        ins.stragglers.add(n_strag)
        if n_strag:
            # a straggler's uplink was spent and discarded (deadline /
            # collect cutoff): charge it as wasted traffic, symmetric with
            # the fedbuff engine's rejected-arrival accounting (aux-bearing
            # stages — LBGM scalars, top-k counts — are unknowable for
            # non-aggregated clients, so the nominal price is the
            # conservative charge)
            ins.up.add(nominal_bytes * n_strag)
            ins.uplinks.add(n_strag)
            res.wasted_per_unit += nominal_per_unit * n_strag
            ins.wasted_up.add(nominal_bytes * n_strag)
            if tr:
                tr.emit(UPLOAD, queue.now, round=t, status="straggler",
                        n=n_strag, bytes_per_client=nominal_bytes)
        # pending DROPOUT events (device vanished later than the round
        # closed) still count as dropped, not as stragglers — a dropout
        # vanishes before its upload starts, so it spends no uplink.
        # Downlink waste: a dropout downloaded the broadcast then
        # vanished; a straggler's whole round trip was discarded — either
        # way the server paid that member's (priced) downlink for nothing
        for ev in queue.clear_pending():
            if ev.kind == DROPOUT:
                n_drop_round += 1
                drop_count[ev.client] += 1
                ins.wasted_down.add(down_by_pos[ev.payload["pos"]])
                if tr:
                    tr.emit(UPLOAD, queue.now, round=t, client=ev.client,
                            status="dropout", bytes=0.0)
        ins.dropouts.add(n_drop_round)
        ins.wasted_down.add(sum(
            down_by_pos[p] for p in sched_pos - set(arrived_pos)))

        if not arrived_pos:
            continue                      # nobody made it; model unchanged

        # -- aggregate the survivors (cohort order, not arrival order, so
        #    the homogeneous all-arrive case is bitwise run_fl) -----------
        arrived_pos.sort()
        if len(arrived_pos) == len(cohort):
            sub = batches
        else:
            # each distinct survivor count is a new leading dim and costs
            # one XLA compile of round_step; counts concentrate fast under
            # a fixed deadline, but pad-to-cohort with a weight mask would
            # be the upgrade if recompiles ever dominate (it would also
            # forfeit the bitwise-equality path with run_fl, so not now)
            idx = np.asarray(arrived_pos)
            sub = {k: v[idx] for k, v in batches.items()}
        with tele.span("round_step", jitted=True):
            if weights is None:
                # equal weights: the exact (unweighted-mean) legacy trace
                params, luar_state, server_state, codec_state, aux = round_step(
                    params, luar_state, server_state, codec_state, sub, qkey)
            else:
                if step_w is None:
                    step_w = make_round_step(loss_fn, cfg, um, pipeline,
                                             down_pipe, weighted=True,
                                             want_loss=policy.wants_loss,
                                             want_norm=policy.wants_update_norm)
                w_sub = jnp.asarray(weights[np.asarray(arrived_pos)],
                                    jnp.float32)
                (params, luar_state, server_state, codec_state, aux,
                 obs) = step_w(params, luar_state, server_state, codec_state,
                               sub, w_sub, qkey)
                losses, norms = (None if o is None else
                                 np.asarray(o, np.float64) for o in obs)
                policy.observe_round(cohort[np.asarray(arrived_pos)], losses,
                                     norms, now=queue.now)
        with tele.span("pricing"):
            per_client = pipeline.price_bytes(sizes, mask_now, aux)
        ins.up.add(per_client * len(arrived_pos))
        ins.accepted.add(len(arrived_pos))
        ins.uplinks.add(len(arrived_pos))
        ins.rounds.inc()
        if tr:
            tr.emit(AGGREGATE, queue.now, round=t,
                    version=int(ins.rounds.value), n=len(arrived_pos),
                    bytes_per_client=per_client,
                    recycled=[int(i) for i in np.flatnonzero(mask_now)])
        if has_delta:
            # this aggregation is the model change the NEXT broadcast must
            # carry: one delta step against the mask it applied
            pending_chain = pending_chain + delta_step_price(sizes, mask_now)

        emit_eval(t)

    # ratio vs a FedAvg baseline paying for the SAME spent uplinks: the
    # straggler/rejected waste in the numerator is matched by the baseline
    # bytes those same uploads would have cost (denominating over accepted
    # uploads only overstated cost — an uncompressed run could exceed 1);
    # every counter-backed field derives from the registry here
    ins.finalize(tele.metrics, res, total_bytes, queue.now, part_count,
                 drop_count)
    res.params = params
    res.luar_state = luar_state
    if tr:
        tr.emit(RUN_END, queue.now, uploaded=ins.up.value,
                downloaded=ins.down.value, comm_ratio=res.comm_ratio,
                down_ratio=res.down_ratio, rounds_done=res.rounds_done)
    return res


# ---------------------------------------------------------------------------
# FedBuff-style buffered async
# ---------------------------------------------------------------------------


def _run_fedbuff(loss_fn, init_params, data, parts, cfg: FLConfig,
                 sim: SimConfig, scenario, resources, eval_fn,
                 tele: Telemetry) -> SimResult:
    pipeline = build_codec_pipeline(cfg)
    down_pipe = build_codec_pipeline(cfg, Direction.DOWN)
    sync_only = pipeline.sync_only_specs() + down_pipe.sync_only_specs()
    if sync_only:
        raise NotImplementedError(
            f"codec stage(s) {list(sync_only)} are anchored to a "
            "synchronous server view the fedbuff server never holds "
            "(e.g. LBGM's basis coefficients are relative to a "
            "synchronously shared anchor).  Either drop the stage "
            "(FLConfig.codecs without it / legacy lbgm_threshold=0) or "
            "run the synchronous engine (SimConfig(mode='sync')), where "
            "it is fully supported.")
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)
    sys_rng = np.random.default_rng(np.random.SeedSequence([sim.sys_seed, 0xE7]))

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    sizes = np.asarray(um.unit_bytes, np.float64)
    total_bytes = sizes.sum()
    n_units = len(um.names)
    alpha = sim.staleness_alpha
    fedasync = sim.buffer_size == 1      # FedAsync-style immediate apply

    # which idle client a free slot feeds is a policy decision
    # (repro.participate); the scenario's scalar dropout is subsumed as
    # an avail:bernoulli shim
    policy = resolve_policy(cfg.participation, cfg.n_clients, cfg.seed,
                            scenario)
    part_count = np.zeros(cfg.n_clients, np.int64)
    drop_count = np.zeros(cfg.n_clients, np.int64)

    client_fn = jax.jit(lambda p, b: local_update(loss_fn, p, b, cfg.client))
    encode_fn = jax.jit(lambda st, delta, qkey: pipeline.encode(st, delta, qkey))
    # per-client policy signals (loss at dispatch point, raw update norm),
    # compiled only when the bound policy feeds on them
    loss1_fn = jax.jit(lambda p, b: loss_fn(p, b))
    norm_fn = jax.jit(lambda tr: jnp.sqrt(sum(
        jnp.sum(jnp.square(l)) for l in jax.tree.leaves(tr))))

    # -- versioned downlink (the DOWN pipeline) ---------------------------
    # the broadcast a dispatch hands its client runs through the downlink
    # codec stack (lossy stages change the numerics they price; the delta
    # transport is the identity), and the DeltaLedger prices each client's
    # actual version lag: chain of per-version applied-update steps when
    # still ledger-resident and cheaper, cache-seeding full snapshot
    # otherwise.  Downlink codec state is SERVER-side (one broadcast
    # encoder), unlike the per-client uplink state above; its RNG is a
    # dedicated stream so declaring a downlink stack never perturbs the
    # learning RNG.  Non-additive servers (fedopt/fedacg) cannot let a
    # chain follower derive recycled units, so versioning disables itself
    # and every dispatch prices the plain snapshot.
    additive = server_broadcast_additive(cfg)
    has_delta = down_pipe.has("delta") and additive
    seed_cache = has_delta and cfg.luar.mode == "recycle"
    no_mask = np.zeros(n_units, bool)
    ins = _Instruments(tele)
    tr = tele.trace

    def _evict_hook(which: str):
        child = ins.evictions.labels(ledger=which)

        def hook(version: int) -> None:
            child.inc()
            if tr:
                tr.emit(EVICT, queue.now, ledger=which, version=version)
        return hook

    delta_ledger = (DeltaLedger(sim.ledger_capacity,
                                on_evict=_evict_hook("delta"))
                    if has_delta else None)
    last_dl: dict[int, int] = {}        # client -> last downloaded version
    down_state = down_pipe.init_state(params, um) if down_pipe else None
    down_key = jax.random.PRNGKey(np.uint32(cfg.seed ^ 0xD0FF))
    down_encode_fn = jax.jit(
        lambda st, tree, k: down_pipe.encode(st, tree, k))

    def broadcast_for_dispatch():
        nonlocal down_state, down_key
        start = broadcast_point(params, server_state, cfg.server)
        if not down_pipe:
            return start
        down_key, sub = jax.random.split(down_key)
        enc, down_state, _ = down_encode_fn(down_state, start, sub)
        return down_pipe.decode(down_state, enc)

    # codec state is PER CLIENT here (this is what makes EF-style error
    # feedback real: each client's residual tracks what ITS lossy uploads
    # destroyed).  Stateless pipelines share one empty state; stateful
    # ones lazily allocate O(model) per participating client.
    codec_template = pipeline.init_state(params, um)
    codec_states: dict[int, tuple] = {}

    def codec_state_for(c: int) -> tuple:
        if not pipeline.stateful:
            return codec_template
        if c not in codec_states:
            codec_states[c] = pipeline.init_state(init_params, um)
        return codec_states[c]

    # the merge body is SHARED with the repro.serve round service (one
    # definition, one trace): see make_buffer_agg_fn
    agg_fn = make_buffer_agg_fn(cfg, um, fedasync)

    queue = EventQueue()
    ledger = MaskLedger(sim.ledger_capacity, on_evict=_evict_hook("mask"))
    res = SimResult(resources=resources,
                    wasted_per_unit=np.zeros(n_units, np.float64))
    version = 0
    # staleness of every accepted arrival: the histogram's retained raw
    # samples ARE the observation list (floats; int version lags are
    # exact in f64, so the adaptive-alpha schedule and the quantile
    # summary are bit-for-bit what the old list produced)
    observed: list[float] = ins.staleness.samples
    jobs: dict[int, dict] = {}
    if tr:
        tr.emit(RUN_START, 0.0, engine="sim", mode="fedbuff",
                n_clients=cfg.n_clients, rounds=cfg.rounds,
                buffer_size=sim.buffer_size, n_units=n_units,
                units=list(um.names))
    buffer: list[tuple] = []            # (delta, staleness, validity row,
                                        #  uncharged bytes, down bytes, ht)

    def dispatch(c: int, now: float, ht: float = 1.0):
        part_count[c] += 1
        # link quality is sampled at dispatch time (diurnal scenarios)
        r = scale_bandwidth(resources[c], bandwidth_multiplier(scenario, now))
        idx = parts[c]
        sel = rng.choice(idx, size=(cfg.tau, cfg.batch_size), replace=True)
        batches = {k: jnp.asarray(arr[sel]) for k, arr in data.items()}
        mask_now = np.asarray(luar_state.mask)
        ledger.record(version, mask_now)
        with tele.span("pricing"):
            # nominal (aux-free) price: the wall-clock estimate, and the
            # conservative charge for payloads whose encode never runs
            per_unit = pipeline.price_per_unit(sizes, mask_now)
            # downlink: price this client's ACTUAL version lag — delta
            # chain from its last downloaded version when the DeltaLedger
            # still holds every step and the chain is cheaper, else full
            # snapshot (first contact, eviction, or a lag so long dense
            # wins)
            if has_delta:
                chain = (delta_ledger.chain_price(last_dl[c], version,
                                                  n_units)
                         if c in last_dl else None)
                down_pu, used_chain = versioned_download_price(
                    sizes, mask_now, chain, seed_cache=seed_cache)
                down_aux = down_pipe.aux_for("delta", down_pu)
            else:
                down_aux, used_chain = None, False
            down_bytes = down_pipe.price_bytes(sizes, no_mask, down_aux)
        ins.down.add(down_bytes)
        ins.dispatches.inc()
        if used_chain:
            ins.delta_dl.inc()
        else:
            ins.full_dl.inc()
        if tr:
            tr.emit(DISPATCH, now, client=int(c), version=version,
                    down_bytes=down_bytes, delta=bool(used_chain),
                    first=c not in last_dl)
        last_dl[c] = version
        jobs[c] = {
            "start": broadcast_for_dispatch(),
            "batches": batches,
            "version": version,         # the mask version this client saw
            "mask": mask_now,           # the dispatched recycle set itself
            "per_unit": per_unit,       # nominal uplink bytes by unit
            "bytes": float(per_unit.sum()),
            "down_bytes": down_bytes,   # the broadcast leg, pipeline-priced
            "ht": ht,                   # the policy's HT weight (1.0 under
                                        # uniform selection)
        }
        if not policy.dispatch_survives(c, r, sys_rng):
            t_busy = download_time(um, r, down_bytes) + compute_time(cfg.tau, r)
            queue.push(now + t_busy, DROPOUT, c)
        else:
            t_busy = round_trip_time(um, mask_now, r, cfg.tau,
                                     payload_bytes=jobs[c]["bytes"],
                                     download_bytes=down_bytes)
            queue.push(now + t_busy, ARRIVAL, c)
        policy.observe_dispatch(c, now=now, cost_s=t_busy)

    def charge_waste(wasted: np.ndarray):
        res.wasted_per_unit += wasted
        ins.wasted_up.add(float(wasted.sum()))

    concurrency = min(sim.concurrency or cfg.n_active, cfg.n_clients)
    first_sel = policy.select(RoundContext(
        rng=rng, n_clients=cfg.n_clients, cohort_size=concurrency,
        candidates=np.arange(cfg.n_clients), population=True, distinct=True,
        sim=True, round=0, now=0.0, bw_period=scenario.bw_period))
    first = np.asarray(first_sel.cohort, np.int64)
    if first_sel.uniform:
        first_ht = np.ones(len(first))
    else:
        first_ht = ht_weights(first_sel)
        if first_sel.with_replacement:
            # Hansen-Hurwitz divides by the k of a k-draw design, but a
            # fedbuff buffer mixes these wave members with SINGLETON
            # redispatch selections (k=1): every dispatch entering the
            # async merge must be on the same per-dispatch 1/p scale, or
            # wave members are underweighted ~concurrency-fold
            first_ht = first_ht * len(first)
    # sorted list of idle client ids, maintained incrementally (O(log n)
    # insert + O(n) pop, vs rebuilding a sorted set per event)
    idle = sorted(set(range(cfg.n_clients)) - set(int(c) for c in first))
    for c, ht in zip(first, first_ht):
        dispatch(int(c), 0.0, float(ht))

    starved = 0          # freed slots the policy could not feed yet
    # a starved retry with NOTHING else in flight needs a clock advance of
    # its own (identical resources make the whole wave arrive at one
    # instant — zero idle time has elapsed, so recharge cannot have
    # happened yet): WAKE events idle the server one population-mean round
    # trip, with exponential backoff so a long availability trough is
    # eventually crossed and a permanently dark population is bounded by
    # the event cap instead of spinning
    wake_wait = float(np.mean([round_trip_time(um, no_mask, r, cfg.tau)
                               for r in resources]))
    wake_backoff = 1.0

    def feed_starved(now: float):
        """Try to feed every starved slot from the idle pool.  An empty
        selection (every idle client dead/unavailable) leaves the slots
        starved — retried on every later event once the virtual clock has
        moved and batteries/availability may have recovered; if no other
        event exists to move it, a WAKE is scheduled."""
        nonlocal starved, wake_backoff
        while starved and idle:
            sel = policy.select(RoundContext(
                rng=rng, n_clients=cfg.n_clients, cohort_size=1,
                candidates=np.asarray(idle, np.int64), population=False,
                distinct=True, sim=True, round=version, now=now,
                bw_period=scenario.bw_period))
            if len(sel.cohort) == 0:
                # "nothing else will move the clock" must ignore the
                # permanent max_sim_time DEADLINE sentinel — else a
                # finite cutoff suppresses the WAKE and a momentary
                # trough fast-forwards straight to the end of the run
                if queue.pending_count() == queue.pending_count(DEADLINE):
                    queue.push(now + wake_wait * wake_backoff, WAKE)
                    wake_backoff = min(wake_backoff * 2.0, 2.0 ** 20)
                return
            c = int(sel.cohort[0])
            idle.remove(c)
            dispatch(c, now,
                     1.0 if sel.uniform else float(ht_weights(sel)[0]))
            starved -= 1
            wake_backoff = 1.0

    def next_dispatch(now: float):
        """Feed the just-freed slot (the uniform policy replays the
        legacy ``idle.pop(rng.integers(len(idle)))`` draw exactly), plus
        any slots starved earlier."""
        nonlocal starved
        starved += 1
        feed_starved(now)

    if len(first) < concurrency:
        # the policy could not fill the whole first wave (e.g. everyone
        # dead or in the diurnal trough at t=0): the missing slots start
        # starved, and with no dispatch in flight the WAKE path is what
        # moves the clock until somebody becomes eligible
        starved = concurrency - len(first)
        feed_starved(0.0)
    if math.isfinite(sim.max_sim_time):
        # exact cutoff: events scheduled past this never execute
        queue.push(sim.max_sim_time, DEADLINE)

    # hard event cap so a pathological population (e.g. dropout ~1) cannot
    # spin the loop forever when max_sim_time is inf
    max_events = 100 * (cfg.rounds * sim.buffer_size + concurrency)
    n_events = 0
    while version < cfg.rounds and queue:
        n_events += 1
        if n_events > max_events:
            break
        ev = queue.pop()
        if ev.kind == DEADLINE:
            break
        if ev.kind == WAKE:
            # the clock advanced for its own sake: retry starved slots
            if tr:
                tr.emit(TRACE_WAKE, queue.now)
            feed_starved(queue.now)
            continue
        c = ev.client
        job = jobs.pop(c)
        bisect.insort(idle, c)          # the slot's device is idle again
        if ev.kind == ARRIVAL:
            mask_v = ledger.get(job["version"])
            if mask_v is None:
                ins.misses.inc()
            if sim.mask_ledger and mask_v is None:
                # dispatch mask evicted: the server can no longer verify
                # which recycle set the payload was built against — reject
                # the update outright and charge every uploaded byte (at
                # the nominal price; the rejected payload is never decoded
                # so aux-exact pricing does not exist for it).  The whole
                # round trip produced nothing: its downlink is waste too.
                ins.up.add(job["bytes"])
                ins.uplinks.inc()
                charge_waste(job["per_unit"].copy())
                ins.wasted_down.add(job["down_bytes"])
                if tr:
                    tr.emit(UPLOAD, queue.now, client=int(c),
                            version=job["version"],
                            lag=version - job["version"],
                            bytes=job["bytes"], status="rejected")
                next_dispatch(queue.now)
                continue
            key, qkey = jax.random.split(key)
            cstate = codec_state_for(c)
            with tele.span("client_step", jitted=True):
                raw = client_fn(job["start"], job["batches"])
                delta, cstate, aux = encode_fn(cstate, raw, qkey)
            if pipeline.stateful:
                codec_states[c] = cstate
            if policy.wants_loss or policy.wants_update_norm:
                # policy signals, priced off this arrival: the client's
                # loss at its dispatch point and its raw update norm
                lo = (np.asarray([float(loss1_fn(
                    job["start"], {k: v[0] for k, v in
                                   job["batches"].items()}))])
                    if policy.wants_loss else None)
                no = (np.asarray([float(norm_fn(raw))])
                      if policy.wants_update_norm else None)
                policy.observe_round([c], lo, no, now=queue.now)
            # the uplink was spent either way; exact post-encode pricing
            # against the DISPATCHED mask (aux: top-k survivor counts etc.)
            with tele.span("pricing"):
                per_unit = pipeline.price_per_unit(sizes, job["mask"], aux)
            ins.up.add(float(per_unit.sum()))
            ins.uplinks.inc()
            stal = version - job["version"]
            ins.staleness.observe(stal)
            if tr:
                tr.emit(UPLOAD, queue.now, client=int(c),
                        version=job["version"], lag=int(stal),
                        bytes=float(per_unit.sum()), status="accepted")
            if sim.mask_ledger:
                valid = ~mask_v         # every uploaded unit is used
                uncharged = per_unit
            else:
                # PR-1 semantics: the server merges against the CURRENT
                # mask, so bytes a stale client uploaded for a now-recycled
                # unit are discarded — the waste the ledger eliminates
                # (per_unit is zero on units the client skipped)
                mask_now = np.asarray(luar_state.mask)
                valid = ~mask_now
                charge_waste(np.where(mask_now, per_unit, 0.0))
                uncharged = np.where(mask_now, 0.0, per_unit)
            # uncharged: payload bytes still unaccounted if this update
            # never reaches a merge (stranded in a partial buffer);
            # down_bytes rides along so a stranded round trip can charge
            # its broadcast leg too; ht is the dispatch-time policy weight
            buffer.append((delta, stal, valid, uncharged, job["down_bytes"],
                           job["ht"]))
            ins.accepted.inc()
            if len(buffer) >= sim.buffer_size:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                       *[b[0] for b in buffer])
                stal_arr = jnp.asarray([b[1] for b in buffer], jnp.int32)
                valid_np = np.stack([b[2] for b in buffer])
                valid_arr = jnp.asarray(valid_np)
                alpha_t = (_schedule_alpha(alpha, observed, sim.staleness_window)
                           if sim.adaptive_alpha else alpha)
                res.alphas.append(alpha_t)
                cur_mask = np.asarray(luar_state.mask)   # pre-agg R_v
                with tele.span("aggregate", jitted=True):
                    if policy.weighted:
                        # fold the policy's inverse-inclusion weights into
                        # the staleness merge (self-normalizing);
                        # truncated-IPS clip RELATIVE TO THIS BUFFER (each
                        # dispatch is a singleton selection, so the cap
                        # only exists at merge time).  The unweighted call
                        # below keeps the uniform trace bit-for-bit
                        hts = np.asarray([b[5] for b in buffer], np.float64)
                        hts = np.minimum(hts, HT_CLIP * hts.min())
                        params, luar_state, server_state = agg_fn(
                            params, luar_state, server_state, stacked,
                            stal_arr, valid_arr, jnp.float32(alpha_t),
                            jnp.asarray(hts, jnp.float32))
                    else:
                        params, luar_state, server_state = agg_fn(
                            params, luar_state, server_state, stacked,
                            stal_arr, valid_arr, jnp.float32(alpha_t))
                if has_delta:
                    # the downlink sibling of ledger.record: price the
                    # delta step this aggregation just created.  Scalar
                    # (derivable) pricing only for units the aggregation
                    # EFFECTIVELY recycled (no valid client uploaded —
                    # the host-side mirror of agg_fn's eff_mask) that are
                    # ALSO in the current mask R_v: snapshots at v seed
                    # exactly R_v, and every fresh or dense-priced unit
                    # in a later step refreshes the follower's cache, so
                    # eff-but-not-current units (possible when the whole
                    # buffer is stale) must ship dense — a unit a
                    # just-seeded client could not otherwise derive
                    eff_mask = ~np.any(valid_np, axis=0)
                    delta_ledger.record_step(
                        version, delta_step_price(sizes, eff_mask & cur_mask))
                n_merged = len(buffer)
                buffer.clear()
                version += 1
                ins.rounds.inc()
                if tr:
                    tr.emit(AGGREGATE, queue.now, version=version,
                            n=n_merged, alpha=float(alpha_t),
                            recycled=[int(i) for i in
                                      np.flatnonzero(~np.any(valid_np,
                                                             axis=0))])
                if eval_fn is not None and (version % cfg.eval_every == 0
                                            or version == cfg.rounds):
                    with tele.span("eval"):
                        metrics = dict(eval_fn(params))
                    metrics.update(round=version, t_sim=queue.now,
                                   up_mb=ins.up.value / 1e6,
                                   comm_ratio=ins.up.value / max(
                                       total_bytes * ins.uplinks.value, 1.0),
                                   down_ratio=ins.down.value / max(
                                       total_bytes * ins.dispatches.value,
                                       1.0))
                    res.history.append(metrics)
        else:
            # the device downloaded the broadcast, computed, and vanished
            # before its upload started: zero uplink spent, but the served
            # downlink is pure waste
            ins.dropouts.inc()
            drop_count[c] += 1
            ins.wasted_down.add(job["down_bytes"])
            if tr:
                tr.emit(UPLOAD, queue.now, client=int(c),
                        version=job["version"],
                        lag=version - job["version"], bytes=0.0,
                        status="dropout")
        # the slot is free again: hand the next idle client a fresh model
        next_dispatch(queue.now)

    # a truncated run (max_sim_time / event cap) can strand accepted
    # uploads in a partially filled buffer: they never reach a merge, so
    # their remaining payload — and the broadcast leg that produced it —
    # is wasted traffic
    res.n_stranded_end = len(buffer)
    for _, _, _, uncharged, down_bytes, _ in buffer:
        charge_waste(uncharged)
        ins.wasted_down.add(down_bytes)
    res.n_inflight_end = len(jobs)      # incl. pending DROPOUT dispatches
    # in-flight downloads were served but their round trips never finished
    for job in jobs.values():
        ins.wasted_down.add(job["down_bytes"])
    m = tele.metrics
    m.gauge(M_STRANDED_END, "accepted uploads stranded in a partial "
            "buffer at finish").set(res.n_stranded_end)
    m.gauge(M_INFLIGHT_END, "dispatches still in flight at finish").set(
        res.n_inflight_end)
    ins.finalize(m, res, total_bytes, queue.now, part_count, drop_count)
    res.staleness_observed = np.asarray(observed, np.int32)
    res.staleness_q = _staleness_quantiles(observed)
    res.params = params
    res.luar_state = luar_state
    if tr:
        tr.emit(RUN_END, queue.now, version=version,
                uploaded=ins.up.value, downloaded=ins.down.value,
                comm_ratio=res.comm_ratio, n_events=n_events)
    return res
