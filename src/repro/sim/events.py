"""Virtual-clock discrete-event queue for the federated systems simulator.

Events are (time, seq, kind, client, payload); ``seq`` is a monotonically
increasing push counter so simultaneous events pop in dispatch (FIFO)
order — the tie-break that makes homogeneous runs deterministic and lets
the ideal-regime sync engine reproduce `fl/rounds.py` bit-for-bit (the
cohort arrives "at once" but still aggregates in cohort order).
"""
from __future__ import annotations

import heapq
import math
from typing import Any, NamedTuple

ARRIVAL = "arrival"        # a client finished download+compute+upload
DEADLINE = "deadline"      # the synchronous round deadline fired
DROPOUT = "dropout"        # a dispatched client vanished (never uploads)
WAKE = "wake"              # clock-advance retry for starved fedbuff slots
                           # (participation policy found nobody eligible and
                           # no other event would ever move the clock)


class Event(NamedTuple):
    time: float
    seq: int
    kind: str
    client: int
    payload: dict[str, Any]


class EventQueue:
    """Min-heap on (time, seq).  Pure host-side; no RNG of its own."""

    def __init__(self):
        self._heap: list = []
        self._seq = 0
        self.now = 0.0          # advances monotonically on pop

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, kind: str, client: int = -1,
             payload: dict[str, Any] | None = None) -> Event:
        if not math.isfinite(time):
            raise ValueError(f"event time must be finite, got {time}")
        if time < self.now:
            raise ValueError(f"cannot schedule into the past: {time} < {self.now}")
        ev = Event(float(time), self._seq, kind, client, payload or {})
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        return ev

    def peek_time(self) -> float:
        return self._heap[0].time if self._heap else math.inf

    def pending_count(self, kind: str | None = None) -> int:
        """Queued events, optionally of one kind only (end-of-run
        accounting: e.g. ARRIVAL events still pending when the fedbuff
        engine stops are dispatches left in flight)."""
        if kind is None:
            return len(self._heap)
        return sum(1 for ev in self._heap if ev.kind == kind)

    def clear_pending(self) -> list:
        """Drop and return every queued event (sync engine: close out a
        round; the caller still needs the kinds for accounting)."""
        events = list(self._heap)
        self._heap.clear()
        return events
