"""Sampling per-client resources from a population heterogeneity scenario.

A ``SimScenario`` (configs/base.py) describes the population; this module
draws one ``ClientResources`` per client.  Sampling is seeded and uses a
dedicated RNG stream so the systems side never perturbs the data/cohort
RNG stream of the learning algorithm (required for the ideal-regime
equivalence with ``fl/rounds.py``).
"""
from __future__ import annotations

import math
from collections.abc import Sequence

import numpy as np

from repro.configs.base import SimScenario, get_scenario
from repro.core.comm import ClientResources


def sample_resources(scenario, n_clients: int, seed: int = 0) -> list[ClientResources]:
    sc: SimScenario = get_scenario(scenario)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x51D]))
    if sc.kind in ("uniform", "diurnal"):
        # diurnal: identical clients; virtual TIME carries the variation
        # (bandwidth_multiplier, looked up per dispatch by the engines)
        return [ClientResources(sc.step_time, sc.up_bw, sc.down_bw, sc.dropout)
                for _ in range(n_clients)]
    if sc.kind == "lognormal":
        # multiplicative scatter with mean 1 (mu = -sigma^2/2)
        mu = -0.5 * sc.sigma ** 2
        slow = rng.lognormal(mu, sc.sigma, n_clients)        # compute slowdown
        link = rng.lognormal(mu, sc.sigma, n_clients)        # shared link quality
        return [ClientResources(sc.step_time * s, sc.up_bw * l,
                                sc.down_bw * l, sc.dropout)
                for s, l in zip(slow, link)]
    if sc.kind == "bimodal":
        fast = rng.random(n_clients) < sc.fast_fraction
        jitter = rng.lognormal(0.0, 0.1, n_clients)          # mild within-mode scatter
        out = []
        for f, j in zip(fast, jitter):
            if f:   # datacenter: fast compute, fat symmetric pipes, reliable
                out.append(ClientResources(sc.step_time / sc.fast_speedup * j,
                                           sc.up_bw * sc.fast_bw_scale,
                                           sc.down_bw * sc.fast_bw_scale, 0.0))
            else:   # mobile: slow compute, thin uplink, flaky
                out.append(ClientResources(sc.step_time * j, sc.up_bw,
                                           sc.down_bw, sc.dropout))
        return out
    raise ValueError(f"unknown scenario kind {sc.kind!r}")


def bandwidth_multiplier(scenario, t: float) -> float:
    """Link-quality multiplier at virtual time ``t`` (1.0 = the mean).

    Only the "diurnal" kind varies:  m(t) = 1 + A sin(2 pi t / P + phi)
    with A = ``bw_amplitude`` in [0, 1) so bandwidth never reaches zero.
    The engines sample this once per DISPATCH and price the whole round
    trip at that instant's bandwidth — a client's transfer is short next
    to the cycle period, so the within-transfer variation is noise the
    model deliberately ignores.  Parameter validation happens once at
    scenario resolution (``configs.base.validate_scenario``), not here in
    the per-dispatch hot path."""
    sc: SimScenario = get_scenario(scenario)
    if sc.kind != "diurnal" or sc.bw_amplitude == 0.0:
        return 1.0
    return 1.0 + sc.bw_amplitude * math.sin(
        2.0 * math.pi * t / sc.bw_period + sc.bw_phase)


def scale_bandwidth(res: ClientResources, m: float) -> ClientResources:
    """The same device behind links scaled by ``m`` (compute untouched)."""
    if m == 1.0:
        return res
    return res._replace(up_bw=res.up_bw * m, down_bw=res.down_bw * m)


def describe(resources: Sequence[ClientResources]) -> dict:
    """Population summary (for logs/benchmarks)."""
    st = np.array([r.step_time for r in resources])
    up = np.array([r.up_bw for r in resources])
    return {
        "n": len(resources),
        "step_time_p50": float(np.median(st)),
        "step_time_p95": float(np.percentile(st, 95)),
        "up_bw_p50": float(np.median(up)),
        "up_bw_p05": float(np.percentile(up, 5)),
    }
