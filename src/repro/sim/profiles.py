"""Sampling per-client resources from a population heterogeneity scenario.

A ``SimScenario`` (configs/base.py) describes the population; this module
draws one ``ClientResources`` per client.  Sampling is seeded and uses a
dedicated RNG stream so the systems side never perturbs the data/cohort
RNG stream of the learning algorithm (required for the ideal-regime
equivalence with ``fl/rounds.py``).

``sample_resource_arrays`` is the struct-of-arrays form the fleet engine
consumes: identical RNG draws and identical elementwise arithmetic, so
``sample_resources(sc, n, seed)[i] == arrays.row(i)`` bitwise — the list
form is just rows of the array form (tested in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import math
from collections.abc import Sequence

import numpy as np

from repro.configs.base import SimScenario, get_scenario
from repro.core.comm import ClientResources, ResourceArrays
from repro.launch.mesh import LINK_MIX, MEASURED_LINK_BW


def _measured_link_counts(n_clients: int) -> list[tuple[str, int]]:
    """Largest-remainder apportionment of ``LINK_MIX`` over the fleet —
    the same rule as ``launch.mesh.client_link_trace`` (which builds the
    O(n) per-client list; at fleet scale only the counts are needed)."""
    exact = [(name, frac * n_clients) for name, frac in LINK_MIX]
    counts = {name: int(e) for name, e in exact}
    short = n_clients - sum(counts.values())
    by_rem = sorted(exact, key=lambda kv: kv[1] - int(kv[1]), reverse=True)
    for name, _ in by_rem[:short]:
        counts[name] += 1
    return [(name, counts[name]) for name, _ in LINK_MIX]


def sample_resource_arrays(scenario, n_clients: int,
                           seed: int = 0) -> ResourceArrays:
    """Struct-of-arrays resource draw (f64, shape (n_clients,) each)."""
    sc: SimScenario = get_scenario(scenario)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x51D]))
    full = np.full

    if sc.kind in ("uniform", "diurnal"):
        # diurnal: identical clients; virtual TIME carries the variation
        # (bandwidth_multiplier, looked up per dispatch by the engines)
        return ResourceArrays(full(n_clients, sc.step_time),
                              full(n_clients, sc.up_bw),
                              full(n_clients, sc.down_bw),
                              full(n_clients, sc.dropout))
    if sc.kind == "lognormal":
        # multiplicative scatter with mean 1 (mu = -sigma^2/2)
        mu = -0.5 * sc.sigma ** 2
        slow = rng.lognormal(mu, sc.sigma, n_clients)        # compute slowdown
        link = rng.lognormal(mu, sc.sigma, n_clients)        # shared link quality
        return ResourceArrays(sc.step_time * slow, sc.up_bw * link,
                              sc.down_bw * link,
                              full(n_clients, sc.dropout))
    if sc.kind == "bimodal":
        fast = rng.random(n_clients) < sc.fast_fraction
        jitter = rng.lognormal(0.0, 0.1, n_clients)          # mild within-mode scatter
        # datacenter: fast compute, fat symmetric pipes, reliable;
        # mobile: slow compute, thin uplink, flaky
        return ResourceArrays(
            np.where(fast, sc.step_time / sc.fast_speedup * jitter,
                     sc.step_time * jitter),
            np.where(fast, sc.up_bw * sc.fast_bw_scale, sc.up_bw),
            np.where(fast, sc.down_bw * sc.fast_bw_scale, sc.down_bw),
            np.where(fast, 0.0, sc.dropout))
    if sc.kind == "measured":
        # measured per-link goodput (launch/mesh.py), grouped by link
        # class exactly like client_link_trace lays the population out
        ups, downs = [], []
        for name, count in _measured_link_counts(n_clients):
            up, down = MEASURED_LINK_BW[name]
            ups.append(full(count, up))
            downs.append(full(count, down))
        return ResourceArrays(full(n_clients, sc.step_time),
                              np.concatenate(ups), np.concatenate(downs),
                              full(n_clients, sc.dropout))
    raise ValueError(f"unknown scenario kind {sc.kind!r}")


def sample_resources(scenario, n_clients: int, seed: int = 0) -> list[ClientResources]:
    arrays = sample_resource_arrays(scenario, n_clients, seed)
    return [arrays.row(i) for i in range(n_clients)]


def bandwidth_multiplier(scenario, t: float) -> float:
    """Link-quality multiplier at virtual time ``t`` (1.0 = the mean).

    A nonzero ``bw_amplitude`` varies the links of ANY kind (the diurnal
    preset sets it; a measured or lognormal scenario can layer the same
    day/night cycle on top):  m(t) = 1 + A sin(2 pi t / P + phi) with
    A = ``bw_amplitude`` in [0, 1) so bandwidth never reaches zero.
    The engines sample this once per DISPATCH and price the whole round
    trip at that instant's bandwidth — a client's transfer is short next
    to the cycle period, so the within-transfer variation is noise the
    model deliberately ignores.  Parameter validation happens once at
    scenario resolution (``configs.base.validate_scenario``), not here in
    the per-dispatch hot path."""
    sc: SimScenario = get_scenario(scenario)
    if sc.bw_amplitude == 0.0:
        return 1.0
    return 1.0 + sc.bw_amplitude * math.sin(
        2.0 * math.pi * t / sc.bw_period + sc.bw_phase)


def scale_bandwidth(res: ClientResources, m: float) -> ClientResources:
    """The same device behind links scaled by ``m`` (compute untouched)."""
    if m == 1.0:
        return res
    return res._replace(up_bw=res.up_bw * m, down_bw=res.down_bw * m)


def describe(resources: Sequence[ClientResources]) -> dict:
    """Population summary (for logs/benchmarks)."""
    st = np.array([r.step_time for r in resources])
    up = np.array([r.up_bw for r in resources])
    return {
        "n": len(resources),
        "step_time_p50": float(np.median(st)),
        "step_time_p95": float(np.percentile(st, 95)),
        "up_bw_p50": float(np.median(up)),
        "up_bw_p05": float(np.percentile(up, 5)),
    }
