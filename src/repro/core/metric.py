"""Eq. (1) and (2): the gradient-to-weight ratio metric and the sampling
distribution over layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap, unit_sq_norms

_EPS = 1e-12
_S_MAX = 1e18       # cap for a diverged (overflowed-norm) unit: huge but
                    # finite, so every OTHER unit's Eq. (2) probability
                    # stays well-defined (1/s underflows to ~0 for it)


def s_from_sq(d2: jax.Array, x2: jax.Array) -> jax.Array:
    """Eq. (1) from per-unit squared norms, with the pathological cases
    pinned to finite values:

      * zero/zero (zero-init bias, fully-pruned layer with zero params):
        the shared eps makes this EXACTLY 1.0 — a neutral "no signal"
        score, neither hot nor cold under Eq. (2);
      * zero denominator, nonzero numerator: eps-clamped to the large
        finite ||Delta||/1e-6;
      * inf numerator (f32 overflow on a diverged unit): capped at
        ``_S_MAX`` instead of inf, so 1/s underflows to ~0 for that unit
        but the normalizing sum over units stays finite;
      * NaN (inf/inf, or a NaN update): mapped to the neutral 1.0, so
        one poisoned unit cannot turn EVERY unit's probability NaN
        through the Eq. (2) normalizer.

    For finite s the guard is the identity (bitwise), which keeps all
    fingerprint-pinned trajectories intact."""
    s = jnp.sqrt(d2 + _EPS) / jnp.sqrt(x2 + _EPS)
    return jnp.nan_to_num(s, nan=1.0, posinf=_S_MAX)


def s_metric(um: UnitMap, update, params) -> jax.Array:
    """s_{t,l} = ||Delta_{t,l}|| / ||x_{t,l}||  per unit, (n_units,) f32."""
    return s_from_sq(unit_sq_norms(um, update), unit_sq_norms(um, params))


def recycle_probs(s: jax.Array, staleness: jax.Array = None,
                  staleness_penalty: float = 0.0) -> jax.Array:
    """p_{t,l} = (1/s_{t,l}) / sum_l (1/s_{t,l}).

    With ``staleness_penalty`` > 0 the unnormalized weight of unit l is
    additionally damped by exp(-penalty * staleness_l), so a unit that has
    been recycled many consecutive rounds re-enters aggregation with
    boosted probability — the staleness-conditioned selection used by the
    buffered-async (FedBuff) path, where the expectation argument of the
    paper no longer bounds worst-case lag.  penalty=0 (the default) is
    bitwise the paper's Eq. (2).
    """
    inv = 1.0 / jnp.clip(s, _EPS)
    if staleness is not None and staleness_penalty:
        inv = inv * jnp.exp(-staleness_penalty * staleness.astype(jnp.float32))
    return inv / jnp.sum(inv)
