"""Eq. (1) and (2): the gradient-to-weight ratio metric and the sampling
distribution over layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap, unit_sq_norms

_EPS = 1e-12


def s_metric(um: UnitMap, update, params) -> jax.Array:
    """s_{t,l} = ||Delta_{t,l}|| / ||x_{t,l}||  per unit, (n_units,) f32."""
    d2 = unit_sq_norms(um, update)
    x2 = unit_sq_norms(um, params)
    return jnp.sqrt(d2 + _EPS) / jnp.sqrt(x2 + _EPS)


def recycle_probs(s: jax.Array, staleness: jax.Array = None,
                  staleness_penalty: float = 0.0) -> jax.Array:
    """p_{t,l} = (1/s_{t,l}) / sum_l (1/s_{t,l}).

    With ``staleness_penalty`` > 0 the unnormalized weight of unit l is
    additionally damped by exp(-penalty * staleness_l), so a unit that has
    been recycled many consecutive rounds re-enters aggregation with
    boosted probability — the staleness-conditioned selection used by the
    buffered-async (FedBuff) path, where the expectation argument of the
    paper no longer bounds worst-case lag.  penalty=0 (the default) is
    bitwise the paper's Eq. (2).
    """
    inv = 1.0 / jnp.clip(s, _EPS)
    if staleness is not None and staleness_penalty:
        inv = inv * jnp.exp(-staleness_penalty * staleness.astype(jnp.float32))
    return inv / jnp.sum(inv)
