"""High-level FedLUAR API: one object owning config + state + accounting.

    luar = FedLUAR(params, delta=4)
    for round in ...:
        applied = luar.aggregate(client_mean_update, params)
        params = jax.tree.map(lambda p, d: p + d, params, applied)
    luar.comm_ratio()   # cumulative upload cost vs FedAvg

``use_kernel=True`` routes the per-unit select + Eq.(1) norms through the
fused Pallas server op (kernels/luar_agg.py) — one HBM pass per layer
instead of three; on CPU it runs in interpret mode and is only sensible
for validation.
"""
from __future__ import annotations
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import comm_init, comm_ratio, comm_update
from repro.core.metric import recycle_probs
from repro.core.recycle import LuarConfig, LuarState, luar_init, luar_round
from repro.core.selection import select_recycle_set
from repro.core.units import UnitMap


class FedLUAR:
    def __init__(self, params: Any, *, delta: int = 0, scheme: str = "luar",
                 mode: str = "recycle", granularity: str = "leaf",
                 max_staleness: int = 0, staleness_penalty: float = 0.0,
                 n_active: int = 1, seed: int = 0, use_kernel: bool = False):
        self.cfg = LuarConfig(delta=delta, scheme=scheme, mode=mode,
                              granularity=granularity,
                              max_staleness=max_staleness,
                              staleness_penalty=staleness_penalty)
        self.state, self.um = luar_init(params, self.cfg, jax.random.PRNGKey(seed))
        if use_kernel and any(isinstance(u, tuple) for u in self.um.leaf_unit):
            raise ValueError("use_kernel supports leaf/module granularity only")
        self.comm = comm_init()
        self.n_active = n_active
        self.use_kernel = use_kernel

    # -- Alg. 2 line 5: what the clients must NOT upload this round -------
    @property
    def recycle_set(self) -> np.ndarray:
        return np.asarray(self.state.mask)

    @property
    def recycled_unit_names(self):
        return [n for n, m in zip(self.um.names, self.recycle_set) if m]

    # -- Alg. 1 ------------------------------------------------------------
    def aggregate(self, fresh_update: Any, params: Any) -> Any:
        self.comm = comm_update(self.comm, self.um, self.state.mask,
                                self.n_active)
        if self.use_kernel:
            applied, new_state = _kernel_round(self.state, self.um, self.cfg,
                                               fresh_update, params)
        else:
            applied, new_state = luar_round(self.state, self.um, self.cfg,
                                            fresh_update, params)
        self.state = new_state
        return applied

    # -- accounting ---------------------------------------------------------
    def comm_ratio(self) -> float:
        return comm_ratio(self.comm, self.um, self.n_active)

    def diagnostics(self) -> dict:
        return {
            "round": int(self.state.round),
            "s": np.asarray(self.state.s),
            "probs": np.asarray(recycle_probs(self.state.s)),
            "staleness": np.asarray(self.state.staleness),
            "agg_count": np.asarray(self.state.agg_count),
            "comm_ratio": self.comm_ratio(),
        }


def _kernel_round(state: LuarState, um: UnitMap, cfg: LuarConfig,
                  fresh_update: Any, params: Any):
    """Alg. 1 with the fused Pallas server op per unit: one pass computes
    the recycle/aggregate select and both Eq.(1) norms."""
    from repro.core.units import n_units
    from repro.kernels import ops

    if cfg.mode == "recycle":
        prev = jax.tree.leaves(state.prev_update)
    else:
        prev = [jnp.zeros_like(a) for a in jax.tree.leaves(state.prev_update)]
    fresh = jax.tree.leaves(fresh_update)
    xs = jax.tree.leaves(params)

    n = n_units(um)
    d2 = [jnp.zeros((), jnp.float32) for _ in range(n)]
    x2 = [jnp.zeros((), jnp.float32) for _ in range(n)]
    applied_leaves = []
    for u, f, p, x in zip(um.leaf_unit, fresh, prev, xs):
        a, dd, xx = ops.luar_agg(f, x, p, state.mask[u].astype(jnp.float32))
        applied_leaves.append(a)
        d2[u] = d2[u] + dd
        x2[u] = x2[u] + xx
    applied = jax.tree.unflatten(um.treedef, applied_leaves)

    eps = 1e-12
    s = jnp.sqrt(jnp.stack(d2) + eps) / jnp.sqrt(jnp.stack(x2) + eps)
    key, sub = jax.random.split(state.key)
    new_staleness = jnp.where(state.mask, state.staleness + 1, 0)
    next_mask = select_recycle_set(sub, cfg.scheme, cfg.delta, s=s,
                                   grad_sq=jnp.stack(d2),
                                   staleness=new_staleness,
                                   staleness_penalty=cfg.staleness_penalty)
    if cfg.max_staleness > 0:
        next_mask = next_mask & (new_staleness < cfg.max_staleness)
    new_state = LuarState(
        prev_update=applied, mask=next_mask, s=s, staleness=new_staleness,
        agg_count=state.agg_count + (~state.mask).astype(jnp.int32),
        round=state.round + 1, key=key)
    return applied, new_state
