"""Layer-selection schemes (Table 4 ablation) and weighted sampling
without replacement.

``Random_Choice([L], delta, p)`` from Alg. 1 is weighted sampling without
replacement; the Gumbel-top-k trick realises exactly the sequential
(Plackett-Luce) draw jit-compatibly: argtop_k(log p + Gumbel noise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.metric import recycle_probs

SCHEMES = ("luar", "random", "grad_norm", "top", "bottom", "deterministic")

_EPS = 1e-12


def gumbel_topk_mask(key, logp: jax.Array, k: int) -> jax.Array:
    """Boolean mask with exactly k True, sampled w/o replacement ~ p."""
    n = logp.shape[0]
    g = -jnp.log(-jnp.log(jax.random.uniform(key, (n,), minval=1e-9, maxval=1.0)))
    scores = logp + g
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros((n,), bool).at[idx].set(True)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros((scores.shape[0],), bool).at[idx].set(True)


def select_recycle_set(key, scheme: str, delta: int, *,
                       s: jax.Array, grad_sq: jax.Array,
                       staleness: jax.Array = None,
                       staleness_penalty: float = 0.0) -> jax.Array:
    """Choose R_{t+1}: per-unit boolean mask with delta True entries.

    s: Eq.(1) metric per unit.  grad_sq: per-unit squared update norms
    (for the gradient-norm ablation scheme).

    staleness / staleness_penalty: optional staleness-conditioned
    selection for the async path — each unit's (log-)selection score is
    reduced by ``penalty * staleness``, so units recycled many versions
    in a row re-enter aggregation with boosted probability.  Positional
    schemes (top/bottom) ignore the penalty.  penalty=0 is bitwise the
    original behaviour.
    """
    n = s.shape[0]
    delta = min(delta, n)
    conditioned = staleness is not None and staleness_penalty
    if delta == 0:
        return jnp.zeros((n,), bool)
    if scheme == "luar":
        p = recycle_probs(s, staleness, staleness_penalty)
        return gumbel_topk_mask(key, jnp.log(p + _EPS), delta)
    if scheme == "random":
        logp = jnp.zeros((n,))
        if conditioned:
            logp = -staleness_penalty * staleness.astype(jnp.float32)
        return gumbel_topk_mask(key, logp, delta)
    if scheme == "grad_norm":
        # favour layers with the smallest update norm (the SOTA heuristic
        # the paper argues against)
        p = recycle_probs(jnp.sqrt(grad_sq + _EPS), staleness, staleness_penalty)
        return gumbel_topk_mask(key, jnp.log(p + _EPS), delta)
    if scheme == "top":            # input-side layers
        return jnp.arange(n) < delta
    if scheme == "bottom":         # output-side layers
        return jnp.arange(n) >= (n - delta)
    if scheme == "deterministic":  # always the delta smallest-s layers
        if conditioned:
            # log-domain so the additive penalty composes with the s
            # ranking (log is monotone: penalty=0 would reproduce -s)
            return topk_mask(-(jnp.log(s + _EPS)
                               + staleness_penalty
                               * staleness.astype(jnp.float32)), delta)
        return topk_mask(-s, delta)
    raise ValueError(f"unknown scheme {scheme!r}; one of {SCHEMES}")
