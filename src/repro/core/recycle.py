"""Algorithm 1 — Layer-wise Update Aggregation with Recycling (LUAR).

Functional state machine: ``luar_init`` builds the round state;
``luar_round`` consumes the freshly aggregated client update and returns
the applied global update Delta-hat plus the next state (with R_{t+1}
already sampled, so the server can tell the next cohort which layers to
omit — Alg. 2 line 5).

Everything inside ``luar_round`` is jit-compatible; the recycle set is a
per-unit boolean mask.
"""
from __future__ import annotations
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.metric import s_from_sq, s_metric
from repro.core.selection import select_recycle_set
from repro.core.units import UnitMap, build_units, n_units, select_per_leaf, unit_sq_norms

_MERGE_EPS = 1e-30                  # guards the per-unit renormalization


class LuarConfig(NamedTuple):
    delta: int = 0                  # layers to recycle; 0 -> vanilla FedAvg
    scheme: str = "luar"            # selection scheme (Table 4)
    mode: str = "recycle"           # "recycle" | "drop" (Table 5 ablation)
    granularity: str = "leaf"       # "leaf" | "module"
    max_staleness: int = 0          # beyond-paper: force re-aggregation after
                                    # this many consecutive recycles (0 = off).
                                    # The paper bounds staleness only in
                                    # expectation (stochastic selection); this
                                    # makes the Lemma-1 k explicit and worst-
                                    # case bounded.
    staleness_penalty: float = 0.0  # staleness-conditioned selection: each
                                    # unit's selection score is damped by
                                    # exp(-penalty * consecutive_recycles), so
                                    # long-recycled units re-enter aggregation
                                    # with boosted probability (async path;
                                    # 0 = off, bitwise the paper's sampling).
    fused_agg: bool = False         # route the server round through the
                                    # batched multi-unit Pallas kernel
                                    # (kernels/luar_agg.luar_agg_batched):
                                    # merge + select + Eq. (1) norms in one
                                    # VMEM-resident sweep.  Off by default —
                                    # the per-leaf reference path is the
                                    # fingerprint-pinned trajectory.


class LuarState(NamedTuple):
    prev_update: Any                # \hat{Delta}_{t-1}
    mask: jax.Array                 # R_t  (n_units,) bool
    s: jax.Array                    # s_{t-1,l} (diagnostic)
    staleness: jax.Array            # consecutive recycles per unit (int32)
    agg_count: jax.Array            # aggregations per unit (Fig. 3)
    round: jax.Array                # t
    key: jax.Array


def luar_init(params: Any, cfg: LuarConfig, key) -> tuple[LuarState, UnitMap]:
    um = build_units(params, cfg.granularity)
    n = n_units(um)
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = LuarState(
        prev_update=zeros,
        mask=jnp.zeros((n,), bool),          # R_0 = empty set (Alg. 2 line 2)
        s=jnp.zeros((n,), jnp.float32),
        staleness=jnp.zeros((n,), jnp.int32),
        agg_count=jnp.zeros((n,), jnp.int32),
        round=jnp.zeros((), jnp.int32),
        key=key,
    )
    return state, um


def luar_round(state: LuarState, um: UnitMap, cfg: LuarConfig,
               fresh_update: Any, params: Any, mask_override=None):
    """One LUAR aggregation (Alg. 1).

    fresh_update: the client-averaged update u_t (valid only for units
    outside R_t — inside R_t the clients did not upload, so whatever is
    there is ignored).  params: x_t (pre-update).

    mask_override: optional (n_units,) bool replacing ``state.mask`` as
    the recycle set actually applied THIS round.  The buffered-async
    engine passes the per-unit "no valid client uploaded this unit" mask
    derived from its mask ledger: under version skew the dispatched R_t
    differs per client, so the effective recycle set is what arrived,
    not what was sampled.  Staleness/agg_count bookkeeping follows the
    effective mask; R_{t+1} is sampled as usual.  When every buffered
    client saw the current mask this equals ``state.mask`` exactly.

    Returns (applied_update \\hat{Delta}_t, new_state).
    """
    mask = state.mask if mask_override is None else mask_override
    if cfg.mode not in ("recycle", "drop"):
        raise ValueError(f"unknown mode {cfg.mode!r}")

    if cfg.fused_agg:
        # K=1 degenerate merge: wn == 1 makes the kernel's weighted
        # reduction the identity on the fresh update, so the fused call
        # is exactly select + Eq. (1) norms in one pass
        rec = 1.0 if cfg.mode == "recycle" else 0.0
        a_prev = jnp.where(mask, rec, 0.0).astype(jnp.float32)
        a_fresh = jnp.where(mask, 0.0, 1.0).astype(jnp.float32)
        wn = jnp.ones((1, n_units(um)), jnp.float32)
        applied, s, grad_sq = _fused_apply(
            um, [l[None] for l in jax.tree_util.tree_leaves(fresh_update)],
            params, state.prev_update, wn, a_prev, a_fresh)
    else:
        if cfg.mode == "recycle":
            recycled_src = state.prev_update
        else:
            recycled_src = jax.tree.map(jnp.zeros_like, state.prev_update)
        applied = select_per_leaf(um, mask, recycled_src, fresh_update)
        # Eq. (1) on what the server actually has (recycled units keep a
        # stale numerator until they are re-aggregated — the stochastic
        # selection guarantees they eventually are).
        s = s_metric(um, applied, params)
        grad_sq = unit_sq_norms(um, applied)

    return applied, _advance_state(state, cfg, applied, s, grad_sq, mask)


def _advance_state(state: LuarState, cfg: LuarConfig, applied, s, grad_sq,
                   mask) -> LuarState:
    """Shared tail of every round variant: sample R_{t+1}, advance the
    staleness/agg-count bookkeeping against the EFFECTIVE mask."""
    key, sub = jax.random.split(state.key)
    new_staleness = jnp.where(mask, state.staleness + 1, 0)
    next_mask = select_recycle_set(sub, cfg.scheme, cfg.delta, s=s,
                                   grad_sq=grad_sq, staleness=new_staleness,
                                   staleness_penalty=cfg.staleness_penalty)
    if cfg.max_staleness > 0:
        # staleness bound: a unit recycled max_staleness times in a row is
        # forced back into the aggregation set next round
        next_mask = next_mask & (new_staleness < cfg.max_staleness)

    return LuarState(
        prev_update=applied,
        mask=next_mask,
        s=s,
        staleness=new_staleness,
        agg_count=state.agg_count + (~mask).astype(jnp.int32),
        round=state.round + 1,
        key=key,
    )


def _fused_apply(um: UnitMap, delta_leaves, params, prev_update,
                 wn, a_prev, a_fresh):
    """One batched-kernel sweep -> (applied tree, s, grad_sq).

    The kernel's per-unit ||applied||^2 IS Eq. (1)'s numerator AND the
    grad_norm selection signal, and ||x||^2 its denominator — nothing
    else in the round needs another pass over the model."""
    from repro.kernels import luar_agg as _la
    from repro.kernels.ops import _default_interpret
    applied_leaves, d2, x2 = _la.luar_agg_batched(
        delta_leaves, jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(prev_update), um.leaf_unit,
        wn=wn, a_prev=a_prev, a_fresh=a_fresh,
        interpret=_default_interpret())
    applied = jax.tree_util.tree_unflatten(um.treedef, applied_leaves)
    return applied, s_from_sq(d2, x2), d2


# ---------------------------------------------------------------------------
# Staleness-aware aggregation (buffered-async / FedBuff path, repro.sim)
# ---------------------------------------------------------------------------


def staleness_discount(staleness: jax.Array, alpha: float = 0.5) -> jax.Array:
    """FedBuff-style polynomial discount w = (1 + tau)^-alpha for an update
    computed ``tau`` server versions ago (alpha=0.5 -> 1/sqrt(1+tau))."""
    return (1.0 + staleness.astype(jnp.float32)) ** (-alpha)


def staleness_weighted_merge(stacked_updates: Any, staleness: jax.Array,
                             alpha: float = 0.5, *,
                             validity: jax.Array | None = None,
                             um: UnitMap | None = None,
                             fallback: Any = None,
                             ht: jax.Array | None = None) -> Any:
    """Merge a buffer of K client updates into one pseudo-update.

    stacked_updates: pytree whose leaves have leading axis K (one slice per
    buffered client delta); staleness: (K,) int server-version lags.
    Returns the discount-weighted mean — the ``u_t`` fed to ``luar_round``
    when the server aggregates a buffer instead of a synchronous cohort.

    ht: optional (K,) Horvitz–Thompson inverse-inclusion-probability
    weights from the participation policy that selected these clients
    (``repro.participate.ht_weights``).  They multiply the staleness
    discounts BEFORE any normalization, so a client a biased cohort
    policy was likely to pick counts for proportionally less — every
    branch below self-normalizes over the combined weights, which keeps
    the merged update an (asymptotically) unbiased estimate of the
    population mean under biased selection.  ``ht=None`` is bitwise the
    pre-participation behaviour.

    validity: optional (K, n_units) bool — True where buffered client k
    actually uploaded unit u (i.e. u was NOT in the recycle mask that
    client downloaded; the mask ledger reconstructs this per client).
    With it, a unit is only ever averaged over the clients that uploaded
    it, so a stale client that skipped a unit can never inject garbage
    into it, and the per-unit combination is guarded so an all-invalid
    unit never divides by zero.  How a unit's missing weight mass is
    handled depends on ``fallback``:

      fallback given (the server's prev_update):  a client skipped unit
        u exactly because its dispatched mask said "u will be recycled",
        so its discount weight is allocated to the recycled direction —
        merged_u = (sum_{k in V_u} w_k d_ku + (sum_k w_k - z_u) fb_u)
        / sum_k w_k with z_u the valid weight mass.  A unit nobody
        uploaded is exactly fb_u (fallback-to-recycle), a unit everybody
        uploaded is exactly the plain discounted mean, and in between
        the recycled direction absorbs the missing mass instead of a
        small (stale, client-biased) subset being renormalized to full
        magnitude — the stable choice under non-IID staleness.

      fallback None:  the weights renormalize over the valid subset
        (convex per-unit mean); an all-invalid unit comes out zero.

    Requires ``um`` to map units onto pytree leaves.  validity=None is
    bitwise the original whole-buffer behaviour, and so is the validity
    path whenever every client saw the current mask.
    """
    w = staleness_discount(staleness, alpha)
    if ht is not None:
        w = w * ht
    if validity is None:
        w = w / jnp.sum(w)

        def merge(leaf):
            wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return jnp.sum(leaf * wb, axis=0)

        return jax.tree.map(merge, stacked_updates)

    if um is None:
        raise ValueError("validity merge needs the UnitMap (um=...)")
    wv = w[:, None] * validity.astype(w.dtype)          # (K, n_units)
    z = jnp.sum(wv, axis=0)                             # valid mass per unit
    if fallback is not None:
        wtot = jnp.sum(w)
        wn = wv / wtot                                  # full-buffer mass
        miss = (wtot - z) / wtot                        # -> recycled direction
    else:
        wn = wv / jnp.maximum(z, _MERGE_EPS)[None, :]   # subset-renormalized
        miss = None
    leaves = jax.tree_util.tree_leaves(stacked_updates)
    fb = (jax.tree_util.tree_leaves(fallback) if fallback is not None
          else [jnp.zeros(l.shape[1:], l.dtype) for l in leaves])
    out = []
    for u, leaf, f in zip(um.leaf_unit, leaves, fb):
        if isinstance(u, tuple):                        # stacked depth unit
            start, L = u
            tail = (1,) * (leaf.ndim - 2)
            wb = wn[:, start:start + L].reshape((-1, L) + tail)
            merged = jnp.sum(leaf * wb, axis=0)
            if miss is not None:
                merged = merged + miss[start:start + L].reshape((L,) + tail) * f
            else:                       # zero out all-invalid units
                ok = (z > 0.0)[start:start + L].reshape((L,) + tail)
                merged = jnp.where(ok, merged, f)
        else:
            wb = wn[:, u].reshape((-1,) + (1,) * (leaf.ndim - 1))
            merged = jnp.sum(leaf * wb, axis=0)
            if miss is not None:
                merged = merged + miss[u] * f
            else:
                merged = jnp.where(z[u] > 0.0, merged, f)
        out.append(merged)              # miss path: all-invalid -> exactly f
    return jax.tree_util.tree_unflatten(um.treedef, out)


def fused_buffer_round(state: LuarState, um: UnitMap, cfg: LuarConfig,
                       stacked_updates: Any, staleness: jax.Array,
                       alpha: float, params: Any, *,
                       validity: jax.Array,
                       ht: jax.Array | None = None,
                       fedasync: bool = False):
    """The fedbuff server round in ONE batched-kernel sweep.

    Mathematically identical (to f32 accumulation order) to

        fresh = staleness_weighted_merge(stacked, staleness, alpha,
                                         validity=validity, um=um,
                                         fallback=state.prev_update, ht=ht)
        [fresh *= eta  if fedasync]
        luar_round(state, um, cfg, fresh, params,
                   mask_override=~any(validity, axis=0))

    but instead of four tree-wide passes (merge, select, s-metric,
    grad-norms) the whole thing collapses into per-unit coefficients of

        applied_u = a_prev[u] * prev_u + a_fresh[u] * sum_k wn[k,u] d_ku

    with  a_prev = rec            on units no valid client uploaded
                 = eta * miss_u   elsewhere (the fallback mass of the
                                  clients whose dispatched mask skipped u)
          a_fresh = 0 / eta       respectively,

    which the batched Pallas kernel evaluates alongside the Eq. (1)
    norms in a single VMEM-resident pass.  Weight algebra is O(K x
    n_units) scalars on the host side of the trace.

    Returns (applied_update, new_state) — a drop-in for the unfused
    merge+round pair in the fedbuff ``agg_fn``.
    """
    w = staleness_discount(staleness, alpha)
    if ht is not None:
        w = w * ht
    wv = w[:, None] * validity.astype(w.dtype)          # (K, n_units)
    z = jnp.sum(wv, axis=0)
    wtot = jnp.sum(w)
    wn = wv / wtot
    miss = (wtot - z) / wtot
    eff_mask = ~jnp.any(validity, axis=0)
    rec = 1.0 if cfg.mode == "recycle" else 0.0
    if cfg.mode not in ("recycle", "drop"):
        raise ValueError(f"unknown mode {cfg.mode!r}")
    # a K=1 buffer renormalizes any discount back to 1, so FedAsync
    # scales the server mixing rate instead: x <- x + eta * delta
    eta = (staleness_discount(staleness[0], alpha) if fedasync
           else jnp.float32(1.0))
    a_prev = jnp.where(eff_mask, rec, eta * miss).astype(jnp.float32)
    a_fresh = jnp.where(eff_mask, 0.0, eta).astype(jnp.float32)
    applied, s, grad_sq = _fused_apply(
        um, jax.tree_util.tree_leaves(stacked_updates), params,
        state.prev_update, wn, a_prev, a_fresh)
    return applied, _advance_state(state, cfg, applied, s, grad_sq, eff_mask)
