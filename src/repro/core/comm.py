"""Communication/memory accounting (Table 1, Table 2 'Comm' columns).

Upload cost of a round = bytes of all units NOT in R_t, times active
clients.  All ratios are relative to FedAvg (delta=0) as in the paper.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.units import UnitMap


class CommStats(NamedTuple):
    bytes_uploaded: jax.Array       # cumulative client->server bytes
    rounds: jax.Array


def comm_init() -> CommStats:
    return CommStats(jnp.zeros((), jnp.float64 if jax.config.jax_enable_x64
                               else jnp.float32), jnp.zeros((), jnp.int32))


def round_upload_bytes(um: UnitMap, mask: jax.Array, n_active: int) -> jax.Array:
    """Bytes uploaded this round given recycle mask R_t."""
    sizes = jnp.asarray(um.unit_bytes, jnp.float32)
    return jnp.sum(jnp.where(mask, 0.0, sizes)) * n_active


def comm_update(stats: CommStats, um: UnitMap, mask: jax.Array,
                n_active: int) -> CommStats:
    return CommStats(stats.bytes_uploaded + round_upload_bytes(um, mask, n_active),
                     stats.rounds + 1)


def comm_ratio(stats: CommStats, um: UnitMap, n_active: int) -> float:
    """Cumulative cost relative to FedAvg over the same number of rounds."""
    full = float(sum(um.unit_bytes)) * n_active * float(stats.rounds)
    return float(stats.bytes_uploaded) / max(full, 1.0)


def server_memory_bytes(um: UnitMap, delta_bytes: int, n_active: int) -> dict:
    """Table 1 model: FedAvg a*d vs FedLUAR a*(d-k)+k."""
    d = sum(um.unit_bytes)
    k = delta_bytes
    return {
        "fedavg": n_active * d,
        "fedluar": n_active * (d - k) + k,
    }


def expected_delta_bytes(um: UnitMap, mask: np.ndarray) -> int:
    return int(sum(b for b, m in zip(um.unit_bytes, mask) if m))
