"""Communication/memory accounting (Table 1, Table 2 'Comm' columns)
plus the per-client wall-clock cost model used by ``repro.sim``.

Upload cost of a round = bytes of all units NOT in R_t, times active
clients.  All ratios are relative to FedAvg (delta=0) as in the paper.

Cumulative byte accounting is HOST-side (Python float64/int): a float32
device scalar silently loses integer precision past ~16M bytes, which a
single transformer round exceeds.  ``round_upload_bytes`` stays a
device-side helper for jitted code paths.

The wall-clock model prices one client round trip as

    download(model) + tau * step_time + upload(~R_t payload)

so the LUAR recycle mask directly shrinks the modeled upload time — the
systems-level payoff the event-driven simulator measures.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.units import UnitMap


class CommStats(NamedTuple):
    bytes_uploaded: float           # cumulative client->server bytes (host f64)
    rounds: int


def comm_init() -> CommStats:
    return CommStats(0.0, 0)


def round_upload_bytes(um: UnitMap, mask: jax.Array, n_active: int) -> jax.Array:
    """Bytes uploaded this round given recycle mask R_t (device-side)."""
    sizes = jnp.asarray(um.unit_bytes, jnp.float32)
    return jnp.sum(jnp.where(mask, 0.0, sizes)) * n_active


def masked_upload_bytes(um: UnitMap, mask: Any, scale: float = 1.0) -> float:
    """Host-side payload bytes of ONE client upload under recycle mask R_t.

    ``scale`` folds in orthogonal compressors (FedPAQ bits/32, pruning,
    dropout) exactly as the round engine accounts them."""
    sizes = np.asarray(um.unit_bytes, np.float64)
    mask = np.asarray(mask, bool)
    return float(sizes[~mask].sum()) * scale


def payload_scale(fedpaq_bits: int = 0, prune_keep: float = 0.0,
                  dropout_rate: float = 0.0) -> float:
    """Relative upload size of the compressor stack (1.0 = dense fp32)."""
    scale = (fedpaq_bits / 32.0) if fedpaq_bits else 1.0
    if prune_keep:
        # sparse upload: values + indices ~= 2 * keep_fraction
        scale *= min(2.0 * prune_keep, 1.0)
    if dropout_rate:
        scale *= (1.0 - dropout_rate)
    return scale


def comm_update(stats: CommStats, um: UnitMap, mask: Any,
                n_active: int) -> CommStats:
    return CommStats(stats.bytes_uploaded + masked_upload_bytes(um, mask) * n_active,
                     stats.rounds + 1)


def comm_ratio(stats: CommStats, um: UnitMap, n_active: int) -> float:
    """Cumulative cost relative to FedAvg over the same number of rounds."""
    full = float(sum(um.unit_bytes)) * n_active * float(stats.rounds)
    return float(stats.bytes_uploaded) / max(full, 1.0)


def server_memory_bytes(um: UnitMap, delta_bytes: int, n_active: int) -> dict:
    """Table 1 model: FedAvg a*d vs FedLUAR a*(d-k)+k."""
    d = sum(um.unit_bytes)
    k = delta_bytes
    return {
        "fedavg": n_active * d,
        "fedluar": n_active * (d - k) + k,
    }


def expected_delta_bytes(um: UnitMap, mask: np.ndarray) -> int:
    return int(sum(b for b, m in zip(um.unit_bytes, mask) if m))


# ---------------------------------------------------------------------------
# Per-client wall-clock cost model (repro.sim)
# ---------------------------------------------------------------------------


class ClientResources(NamedTuple):
    """One simulated device: compute speed and link bandwidths.

    step_time : seconds per local SGD step
    up_bw     : uplink bytes/second
    down_bw   : downlink bytes/second
    dropout   : probability the device vanishes mid-round
    """
    step_time: float
    up_bw: float
    down_bw: float
    dropout: float = 0.0


def download_time(um: UnitMap, res: ClientResources) -> float:
    """Broadcast is always the full model: recycled units still change on
    the server (the recycled update is applied), so clients cannot skip
    them on the way down."""
    return float(sum(um.unit_bytes)) / res.down_bw


def compute_time(tau: int, res: ClientResources) -> float:
    return tau * res.step_time


def upload_time(um: UnitMap, mask: Any, res: ClientResources,
                scale: float = 1.0) -> float:
    """Mask-aware: units in R_t are never serialized to the uplink."""
    return masked_upload_bytes(um, mask, scale) / res.up_bw


def round_trip_time(um: UnitMap, mask: Any, res: ClientResources, tau: int,
                    scale: float = 1.0) -> float:
    """Dispatch-to-arrival latency of one client round."""
    return (download_time(um, res) + compute_time(tau, res)
            + upload_time(um, mask, res, scale))
