"""Communication/memory accounting (Table 1, Table 2 'Comm' columns)
plus the per-client wall-clock cost model used by ``repro.sim``.

Upload cost of a round = bytes of all units NOT in R_t, times active
clients.  All ratios are relative to FedAvg (delta=0) as in the paper.

Cumulative byte accounting is HOST-side (Python float64/int): a float32
device scalar silently loses integer precision past ~16M bytes, which a
single transformer round exceeds.  Compressor pricing is the codec
pipeline's job (``repro.compress.CodecPipeline.price_per_unit``); the
helpers here only gate raw unit bytes by the recycle mask, or accept
already-priced payload bytes (the old device-side ``round_upload_bytes``
and hand-maintained ``payload_scale`` duplicated that pricing and could
diverge from the host ledger, so they are gone).

The wall-clock model prices one client round trip as

    download(broadcast payload) + tau * step_time + upload(~R_t payload)

so the LUAR recycle mask directly shrinks the modeled upload time — the
systems-level payoff the event-driven simulator measures.  BOTH legs
accept pipeline-priced ``payload_bytes`` overrides: the downlink is no
longer hard-coded to the full model — under the versioned broadcast
(``down:delta``) a client at server version v downloads the delta chain
v->current whenever the server's ``DeltaLedger`` still holds it and it
is cheaper than a snapshot, and downlink codecs (``down:fedpaq:8``)
price the broadcast exactly like uplink codecs price the update.
"""
from __future__ import annotations
from typing import Any, NamedTuple

import numpy as np

from repro.core.units import UnitMap


class CommStats(NamedTuple):
    bytes_uploaded: float           # cumulative client->server bytes (host f64)
    rounds: int


def comm_init() -> CommStats:
    return CommStats(0.0, 0)


def masked_upload_bytes(um: UnitMap, mask: Any, scale: float = 1.0) -> float:
    """Host-side payload bytes of ONE client upload under recycle mask R_t.

    ``scale`` is a plain multiplier for callers that already know their
    compression ratio; exact compressor pricing routes through
    ``CodecPipeline.price_per_unit`` instead (pass the result to the
    ``payload_bytes`` override of ``upload_time``/``round_trip_time``)."""
    sizes = np.asarray(um.unit_bytes, np.float64)
    mask = np.asarray(mask, bool)
    return float(sizes[~mask].sum()) * scale


def comm_update(stats: CommStats, um: UnitMap, mask: Any,
                n_active: int) -> CommStats:
    return CommStats(stats.bytes_uploaded + masked_upload_bytes(um, mask) * n_active,
                     stats.rounds + 1)


def comm_ratio(stats: CommStats, um: UnitMap, n_active: int) -> float:
    """Cumulative cost relative to FedAvg over the same number of rounds."""
    full = float(sum(um.unit_bytes)) * n_active * float(stats.rounds)
    return float(stats.bytes_uploaded) / max(full, 1.0)


def server_memory_bytes(um: UnitMap, delta_bytes: int, n_active: int) -> dict:
    """Table 1 model: FedAvg a*d vs FedLUAR a*(d-k)+k."""
    d = sum(um.unit_bytes)
    k = delta_bytes
    return {
        "fedavg": n_active * d,
        "fedluar": n_active * (d - k) + k,
    }


def expected_delta_bytes(um: UnitMap, mask: np.ndarray) -> int:
    return int(sum(b for b, m in zip(um.unit_bytes, mask) if m))


# ---------------------------------------------------------------------------
# Per-client wall-clock cost model (repro.sim)
# ---------------------------------------------------------------------------


class ClientResources(NamedTuple):
    """One simulated device: compute speed and link bandwidths.

    step_time : seconds per local SGD step
    up_bw     : uplink bytes/second
    down_bw   : downlink bytes/second
    dropout   : probability the device vanishes mid-round
    """
    step_time: float
    up_bw: float
    down_bw: float
    dropout: float = 0.0


def download_time(um: UnitMap, res: ClientResources,
                  payload_bytes: float | None = None) -> float:
    """Broadcast leg of the round trip.

    Default (``payload_bytes=None``) is the full model — recycled units
    still change on the server (the recycled update is applied), so an
    unversioned client cannot skip them on the way down.  A versioned
    downlink (delta chain against the client's last version, or any
    ``down:`` codec stack) passes its pipeline-priced ``payload_bytes``
    so the wall-clock model and the byte ledger price the same wire."""
    if payload_bytes is None:
        payload_bytes = float(sum(um.unit_bytes))
    return payload_bytes / res.down_bw


def compute_time(tau: int, res: ClientResources) -> float:
    return tau * res.step_time


def upload_time(um: UnitMap, mask: Any, res: ClientResources,
                scale: float = 1.0,
                payload_bytes: float | None = None) -> float:
    """Mask-aware: units in R_t are never serialized to the uplink.

    ``payload_bytes`` (codec-pipeline-priced) overrides the mask-gated
    raw bytes, so the wall-clock model and the byte ledger price the
    same stack."""
    if payload_bytes is None:
        payload_bytes = masked_upload_bytes(um, mask, scale)
    return payload_bytes / res.up_bw


def round_trip_time(um: UnitMap, mask: Any, res: ClientResources, tau: int,
                    scale: float = 1.0,
                    payload_bytes: float | None = None,
                    download_bytes: float | None = None) -> float:
    """Dispatch-to-arrival latency of one client round (both transfer
    legs take pipeline-priced byte overrides)."""
    return (download_time(um, res, download_bytes) + compute_time(tau, res)
            + upload_time(um, mask, res, scale, payload_bytes))


# ---------------------------------------------------------------------------
# Vectorized (fleet-scale) cost model — struct-of-arrays counterparts
# ---------------------------------------------------------------------------
#
# ``repro.fleet`` prices whole cohorts per wave instead of one client per
# event.  These are the EXACT array-program counterparts of the scalar
# helpers above: host-side numpy float64 end to end (never device f32 —
# same precision argument as the byte ledgers), and elementwise they
# perform the same IEEE operations as the scalar path.  Unit byte counts
# are whole numbers well below 2^53, so the mask-gated sums are exact in
# f64 regardless of summation order — ``tests/test_fleet.py`` pins
# bitwise equality against a per-client scalar loop.


class ResourceArrays(NamedTuple):
    """Struct-of-arrays view of N ``ClientResources`` (all f64, shape (N,))."""
    step_time: np.ndarray
    up_bw: np.ndarray
    down_bw: np.ndarray
    dropout: np.ndarray

    @property
    def n_clients(self) -> int:
        return int(self.step_time.shape[0])

    def row(self, i: int) -> ClientResources:
        """The scalar view of client ``i`` (for host-side spot checks)."""
        return ClientResources(float(self.step_time[i]), float(self.up_bw[i]),
                               float(self.down_bw[i]), float(self.dropout[i]))

    def take(self, ids: np.ndarray) -> "ResourceArrays":
        ids = np.asarray(ids)
        return ResourceArrays(self.step_time[ids], self.up_bw[ids],
                              self.down_bw[ids], self.dropout[ids])


def resources_to_arrays(resources: list[ClientResources]) -> ResourceArrays:
    """Pack a host-side resource list into the struct-of-arrays form."""
    return ResourceArrays(
        np.asarray([r.step_time for r in resources], np.float64),
        np.asarray([r.up_bw for r in resources], np.float64),
        np.asarray([r.down_bw for r in resources], np.float64),
        np.asarray([r.dropout for r in resources], np.float64),
    )


def masked_upload_bytes_vec(um: UnitMap, masks: np.ndarray,
                            scale: float = 1.0) -> np.ndarray:
    """(N, n_units) recycle masks -> (N,) upload payload bytes, f64."""
    sizes = np.asarray(um.unit_bytes, np.float64)
    masks = np.asarray(masks, bool)
    return np.where(masks, 0.0, sizes[None, :]).sum(axis=1) * scale


def download_time_vec(um: UnitMap, res: ResourceArrays,
                      payload_bytes: np.ndarray | float | None = None) -> np.ndarray:
    if payload_bytes is None:
        payload_bytes = float(sum(um.unit_bytes))
    return np.asarray(payload_bytes, np.float64) / res.down_bw


def compute_time_vec(tau: int, res: ResourceArrays) -> np.ndarray:
    return tau * res.step_time


def upload_time_vec(um: UnitMap, masks: np.ndarray, res: ResourceArrays,
                    scale: float = 1.0,
                    payload_bytes: np.ndarray | float | None = None) -> np.ndarray:
    if payload_bytes is None:
        payload_bytes = masked_upload_bytes_vec(um, masks, scale)
    return np.asarray(payload_bytes, np.float64) / res.up_bw


def round_trip_time_vec(um: UnitMap, masks: np.ndarray, res: ResourceArrays,
                        tau: int, scale: float = 1.0,
                        payload_bytes: np.ndarray | float | None = None,
                        download_bytes: np.ndarray | float | None = None) -> np.ndarray:
    """(N,) dispatch-to-arrival latencies for one cohort wave."""
    return (download_time_vec(um, res, download_bytes)
            + compute_time_vec(tau, res)
            + upload_time_vec(um, masks, res, scale, payload_bytes))
