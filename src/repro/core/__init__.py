"""FedLUAR core: the paper's contribution as a composable JAX module."""
from repro.core.comm import (  # noqa: F401
    ClientResources,
    CommStats,
    comm_init,
    comm_ratio,
    comm_update,
    compute_time,
    download_time,
    masked_upload_bytes,
    round_trip_time,
    server_memory_bytes,
    upload_time,
)
from repro.core.metric import recycle_probs, s_from_sq, s_metric  # noqa: F401
from repro.core.recycle import (  # noqa: F401
    LuarConfig,
    LuarState,
    fused_buffer_round,
    luar_init,
    luar_round,
    staleness_discount,
    staleness_weighted_merge,
)
from repro.core.selection import SCHEMES, gumbel_topk_mask, select_recycle_set  # noqa: F401
from repro.core.units import UnitMap, build_units, n_units, unit_sq_norms  # noqa: F401
from repro.core.luar import FedLUAR  # noqa: F401
