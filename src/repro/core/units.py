"""Layer units: the granularity at which LUAR recycles.

The paper recycles per network layer (each conv/FC tensor on ResNet/CNN,
each weight tensor on DistilBERT).  For pytree models we support:
  - "module": group leaves by their first path component (the paper's
    granularity for the CNN: conv1/conv2/fc1/fc2 -> 4 units);
  - "leaf": every parameter leaf is a unit (transformer stacks: each
    stacked tensor like blocks.attn.wq is one unit);
  - "depth": stacked leaves (under blocks/enc_blocks/dec_blocks, scanned
    over the first axis) expand into one unit PER LAYER — the closest
    match to the paper's per-layer granularity on an L-layer transformer
    (40-layer DistilBERT-style model -> 40 units per weight kind).
"""
from __future__ import annotations
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_STACKED_PREFIXES = ("blocks", "enc_blocks", "dec_blocks")

# leaf -> unit mapping: an int (whole leaf is one unit) or (start, count)
# (stacked leaf: units start..start+count-1, one per first-axis slice)
LeafUnit = int | tuple[int, int]


class UnitMap(NamedTuple):
    names: tuple[str, ...]          # unit names, ordered
    leaf_unit: tuple[LeafUnit, ...]
    treedef: Any
    unit_bytes: tuple[int, ...]     # parameter bytes per unit


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def build_units(params: Any, granularity: str = "leaf") -> UnitMap:
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    names: list[str] = []
    leaf_unit: list[LeafUnit] = []
    nbytes: list[int] = []
    index: dict[str, int] = {}
    for path, leaf in leaves_with_path:
        full = _path_str(path)
        total = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if granularity == "depth" and full.split(".")[0] in _STACKED_PREFIXES \
                and leaf.ndim >= 2:
            L = leaf.shape[0]
            start = len(names)
            for i in range(L):
                names.append(f"{full}[{i}]")
                nbytes.append(total // L)
            leaf_unit.append((start, L))
            continue
        key = full.split(".")[0] if granularity == "module" else full
        if key not in index:
            index[key] = len(names)
            names.append(key)
            nbytes.append(0)
        u = index[key]
        leaf_unit.append(u)
        nbytes[u] += total
    return UnitMap(tuple(names), tuple(leaf_unit), treedef, tuple(nbytes))


def n_units(um: UnitMap) -> int:
    return len(um.names)


def unit_sq_norms(um: UnitMap, tree: Any) -> jax.Array:
    """Per-unit squared L2 norms, shape (n_units,) f32."""
    leaves = jax.tree_util.tree_leaves(tree)
    acc = [jnp.zeros((), jnp.float32) for _ in um.names]
    for u, leaf in zip(um.leaf_unit, leaves):
        sq = jnp.square(leaf.astype(jnp.float32))
        if isinstance(u, tuple):
            start, L = u
            per_depth = jnp.sum(sq.reshape(L, -1), axis=1)
            for i in range(L):
                acc[start + i] = acc[start + i] + per_depth[i]
        else:
            acc[u] = acc[u] + jnp.sum(sq)
    return jnp.stack(acc)


def select_per_leaf(um: UnitMap, mask: jax.Array, when_true: Any, when_false: Any) -> Any:
    """tree_map-style select driven by a per-unit boolean mask."""
    lt = jax.tree_util.tree_leaves(when_true)
    lf = jax.tree_util.tree_leaves(when_false)
    out = []
    for u, a, b in zip(um.leaf_unit, lt, lf):
        if isinstance(u, tuple):
            start, L = u
            m = jax.lax.dynamic_slice_in_dim(mask, start, L)
            m = m.reshape((L,) + (1,) * (a.ndim - 1))
            out.append(jnp.where(m, a, b))
        else:
            out.append(jnp.where(mask[u], a, b))
    return jax.tree_util.tree_unflatten(um.treedef, out)
