"""Participation-policy registry and spec-string grammar.

Mirrors ``repro.compress.registry``:

    spec   ::= name [":" arg (sep arg)*]      sep ::= ":" | ","
    name   ::= registered policy name          (uniform | powd |
                                                importance | avail | energy)
    arg    ::= int | float | identifier

Examples: ``"uniform"``, ``"powd:8"``, ``"importance:norm"``,
``"avail:bernoulli:0.1"``, ``"avail:diurnal:0.4"``, ``"energy:20:0.5"``.
Unlike codec stacks there is exactly ONE policy per run (who trains is a
single decision), so specs don't compose with ``+``.

``resolve_policy`` is the engines' entry point: it parses + binds the
declared policy and subsumes the retired ``SimScenario.dropout`` scalar
— a population-wide scalar dropout on a uniform/diurnal scenario is
shimmed onto ``avail:bernoulli:<rate>`` (DeprecationWarning), which
replays the legacy engine behaviour bit-for-bit (same uniform selection
calls, same single systems-stream draw per dispatch).  Per-mode dropout
(the bimodal presets, where the rate is a RESOURCE property of the
mobile mode, not a population scalar) stays on the resources and is
honoured by every policy's default ``dispatch_survives``.
"""
from __future__ import annotations

import re
import warnings
from collections.abc import Callable

from repro.participate.policies import (AvailBernoulli, AvailDiurnal,
                                        EnergyBudget, ImportanceNorm,
                                        PowerOfChoice, UniformPolicy)
from repro.participate.policy import ParticipationPolicy

Arg = int | float | str

POLICIES: dict[str, Callable[..., ParticipationPolicy]] = {}


def register_policy(name: str):
    """Register a policy factory under ``name`` (usable as decorator)."""
    def deco(factory):
        POLICIES[name] = factory
        return factory
    return deco


def _make_avail(kind: Arg = "bernoulli", *args: Arg) -> ParticipationPolicy:
    if kind == "bernoulli":
        return AvailBernoulli(*args)
    if kind == "diurnal":
        return AvailDiurnal(*args)
    raise ValueError(f"unknown availability kind {kind!r}; "
                     f"have: bernoulli, diurnal")


def _make_importance(kind: Arg = "norm") -> ParticipationPolicy:
    if kind != "norm":
        raise ValueError(f"unknown importance signal {kind!r}; have: norm")
    return ImportanceNorm()


register_policy("uniform")(UniformPolicy)
register_policy("powd")(PowerOfChoice)
register_policy("importance")(_make_importance)
register_policy("avail")(_make_avail)
register_policy("energy")(EnergyBudget)


def _parse_arg(tok: str) -> Arg:
    tok = tok.strip()
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            return tok                  # identifier args ("norm", "diurnal")


def parse_policy(spec: str | ParticipationPolicy | None
                 ) -> ParticipationPolicy:
    """One spec string -> one (unbound) policy instance.  An
    already-constructed policy passes through; empty/None means
    uniform."""
    if isinstance(spec, ParticipationPolicy):
        return spec
    body = (spec or "uniform").strip()
    name, _, argstr = body.partition(":")
    name = name.strip()
    if name not in POLICIES:
        raise ValueError(f"unknown participation policy {name!r} in spec "
                         f"{spec!r}; registered: {sorted(POLICIES)}")
    args = [_parse_arg(a) for a in re.split("[,:]", argstr) if a.strip()] \
        if argstr else []
    return POLICIES[name](*args)


def make_policy(spec: str | ParticipationPolicy | None, n_clients: int,
                seed: int = 0) -> ParticipationPolicy:
    """Parse + bind: the fresh per-run policy instance the engines use."""
    return parse_policy(spec).bind(n_clients, seed)


def resolve_policy(spec: str | ParticipationPolicy | None,
                   n_clients: int, seed: int = 0,
                   scenario: object | None = None) -> ParticipationPolicy:
    """``make_policy`` plus the ``SimScenario.dropout`` deprecation shim.

    A population-wide scalar dropout (uniform/diurnal scenario kinds,
    where ``sample_resources`` stamps the same rate on every client)
    under the default uniform policy IS ``avail:bernoulli:<rate>`` — the
    shim constructs exactly that policy, bit-for-bit: uniform selection
    consumes the learning rng identically and the survival hook makes
    the same single systems-stream draw per dispatch the engines used to
    hard-code.  Any explicitly declared non-uniform policy wins over the
    scalar (its own availability/survival semantics apply)."""
    policy = parse_policy(spec)
    sc_dropout = float(getattr(scenario, "dropout", 0.0) or 0.0)
    if (sc_dropout > 0.0 and getattr(scenario, "kind", "") in
            ("uniform", "diurnal") and isinstance(policy, UniformPolicy)):
        warnings.warn(
            f"SimScenario.dropout={sc_dropout:g} as a population scalar is "
            f"deprecated; declare participation="
            f"'avail:bernoulli:{sc_dropout:g}' instead (bit-for-bit)",
            DeprecationWarning, stacklevel=3)
        policy = AvailBernoulli(sc_dropout)
    return policy.bind(n_clients, seed)
