"""Vectorized (fleet-scale) participation policies.

``repro.fleet`` asks "who is eligible right now?" about the WHOLE
population at once, once per wave — not one host callback per client per
event.  These are the array-program counterparts of the host policies in
``policies.py``: same spec grammar, same parameters, and elementwise the
SAME float64 arithmetic, so eligibility/battery trajectories match the
host policies bitwise (pinned in ``tests/test_fleet.py``).  They live in
their own registry (``VECTOR_POLICIES``) alongside the host one — the
host/device split the participation registry was designed for.

The vectorized family covers the *availability/energy* policies, which
are uniform-within-the-eligible-set (every inclusion probability equal,
HT weight 1.0).  The *weighted* policies (``powd``, ``importance``) need
per-client loss/update-norm feedback threaded through the merge and are
not vectorized yet — ``make_vector_policy`` raises ``NotImplementedError``
for them rather than silently dropping the bias correction.

Selection itself (uniform without replacement over the eligible mask) is
NOT done here: the fleet engine draws it with a jitted Gumbel top-k over
the population (``fleet/waves.py``), sharded across the mesh.
"""
from __future__ import annotations

import math
import re
from collections.abc import Callable

import numpy as np

from repro.participate.registry import POLICIES, _parse_arg

Arg = int | float | str


class VectorPolicy:
    """Whole-population participation hooks (struct-of-arrays, host f64).

    Lifecycle mirrors ``ParticipationPolicy``: construct from spec args,
    ``bind(n_clients, seed)`` once per run, then per wave:

      eligible(now, bw_period)        -> (N,) bool mask
      survival_prob(ids, res_dropout) -> per-dispatch death probabilities
      observe_dispatch(ids, now, cost_s) — batched busy/energy accounting
    """

    name = "vector"

    def __init__(self, *args: Arg):
        self.spec = self.name + "".join(f":{a}" for a in args)
        self.n_clients = 0

    def bind(self, n_clients: int, seed: int = 0) -> "VectorPolicy":
        self.n_clients = int(n_clients)
        self._rng = np.random.default_rng(np.random.SeedSequence(
            [seed & 0xFFFFFFFF, 0x9A7, sum(ord(c) for c in self.name)]))
        self._bind_state()
        return self

    def _bind_state(self) -> None:
        pass

    def eligible(self, now: float, bw_period: float = 600.0) -> np.ndarray:
        return np.ones(self.n_clients, bool)

    def survival_prob(self, ids: np.ndarray,
                      res_dropout: np.ndarray) -> np.ndarray:
        """Per-dispatch vanish probability (the vectorized counterpart of
        ``dispatch_survives``; resources' own flakiness by default)."""
        return np.asarray(res_dropout, np.float64)

    def observe_dispatch(self, ids: np.ndarray, now: float,
                         cost_s: np.ndarray) -> None:
        pass


class VUniform(VectorPolicy):
    name = "uniform"


class VAvailBernoulli(VectorPolicy):
    """avail:bernoulli:p — uniform selection; every dispatch dies with
    probability max(p, resource dropout), exactly the host policy's
    ``dispatch_survives`` arithmetic."""

    name = "avail"

    def __init__(self, rate: float = 0.0):
        super().__init__("bernoulli", float(rate))
        self.rate = float(rate)
        if not 0.0 <= self.rate < 1.0:
            raise ValueError(f"avail:bernoulli rate must be in [0, 1), "
                             f"got {rate}")

    def survival_prob(self, ids, res_dropout) -> np.ndarray:
        return np.maximum(self.rate, np.asarray(res_dropout, np.float64))


class VAvailDiurnal(VectorPolicy):
    """avail:diurnal[:frac[:period]] — the host policy's availability
    curve evaluated for the whole population at once: client i is up
    while sin(2 pi t / P + 2 pi i / N) >= cos(pi * frac)."""

    name = "avail"

    def __init__(self, frac: float = 0.5, period: float = 0.0):
        super().__init__("diurnal", float(frac), float(period))
        self.frac = float(frac)
        self.period = float(period)          # 0 -> caller's bw_period
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"avail:diurnal duty fraction must be in "
                             f"(0, 1], got {frac}")

    def eligible(self, now: float, bw_period: float = 600.0) -> np.ndarray:
        ids = np.arange(self.n_clients, dtype=np.int64)
        P = self.period or bw_period
        phase = 2.0 * math.pi * ids / max(self.n_clients, 1)
        lvl = np.sin(2.0 * math.pi * now / P + phase)
        return lvl >= math.cos(math.pi * self.frac)


class VEnergy(VectorPolicy):
    """energy:J[:recharge[:power]] — the host ``EnergyBudget`` arrays
    verbatim, with dispatch accounting batched over a wave (every client
    in a wave is charged at the same instant, which is exactly the
    sequential host bookkeeping when the timestamps coincide: accrual is
    idempotent at a fixed ``now``)."""

    name = "energy"

    def __init__(self, capacity: float = 20.0, recharge: float = -1.0,
                 power: float = 1.0):
        if capacity <= 0:
            raise ValueError(f"energy capacity must be positive, got {capacity}")
        self.capacity = float(capacity)
        self.recharge = (0.02 * self.capacity if recharge < 0
                         else float(recharge))
        self.power = float(power)
        super().__init__(self.capacity, self.recharge, self.power)

    def _bind_state(self) -> None:
        self.battery = np.full(self.n_clients, self.capacity, np.float64)
        self._busy_until = np.zeros(self.n_clients, np.float64)
        self._last_acc = np.zeros(self.n_clients, np.float64)

    def _accrue(self, now: float) -> None:
        idle_from = np.maximum(self._last_acc, self._busy_until)
        gain = self.recharge * np.maximum(0.0, now - idle_from)
        self.battery = np.minimum(self.capacity, self.battery + gain)
        self._last_acc = np.maximum(self._last_acc, now)

    def eligible(self, now: float, bw_period: float = 600.0) -> np.ndarray:
        self._accrue(now)
        return self.battery > 0.0

    def observe_dispatch(self, ids, now, cost_s) -> None:
        self._accrue(now)
        cost = np.asarray(cost_s, np.float64)
        self.battery[ids] = np.maximum(0.0, self.battery[ids]
                                       - self.power * cost)
        self._busy_until[ids] = now + cost


def _make_vavail(kind: Arg = "bernoulli", *args: Arg) -> VectorPolicy:
    if kind == "bernoulli":
        return VAvailBernoulli(*args)
    if kind == "diurnal":
        return VAvailDiurnal(*args)
    raise ValueError(f"unknown availability kind {kind!r}; "
                     f"have: bernoulli, diurnal")


VECTOR_POLICIES: dict[str, Callable[..., VectorPolicy]] = {
    "uniform": VUniform,
    "avail": _make_vavail,
    "energy": VEnergy,
}


def register_vector_policy(name: str):
    """Register a vectorized policy factory under ``name`` (decorator)."""
    def deco(factory):
        VECTOR_POLICIES[name] = factory
        return factory
    return deco


def make_vector_policy(spec: str | VectorPolicy | None, n_clients: int,
                       seed: int = 0) -> VectorPolicy:
    """Spec string -> bound vectorized policy (same grammar as
    ``make_policy``); weighted host policies raise rather than losing
    their bias correction silently."""
    if isinstance(spec, VectorPolicy):
        return spec.bind(n_clients, seed)
    body = (spec or "uniform").strip()
    name, _, argstr = body.partition(":")
    name = name.strip()
    if name not in VECTOR_POLICIES:
        if name in POLICIES:
            raise NotImplementedError(
                f"participation policy {name!r} is host-side only (weighted "
                f"selection needs per-client feedback); the fleet engine "
                f"supports: {sorted(VECTOR_POLICIES)}")
        raise ValueError(f"unknown participation policy {name!r} in spec "
                         f"{spec!r}; registered: {sorted(VECTOR_POLICIES)}")
    args = [_parse_arg(a) for a in re.split("[,:]", argstr) if a.strip()] \
        if argstr else []
    return VECTOR_POLICIES[name](*args).bind(n_clients, seed)
