"""The client-participation protocol (host side).

Who trains this round used to be three hard-coded
``rng.choice(n_clients, n_active, replace=False)`` sites scattered over
``fl/rounds.py`` and both ``sim/engine.py`` server paths — the same
copy-pasted-flags shape the codec registry removed from the compressor
stack.  This module is the participation analogue of
``repro.compress.codec``: one protocol (``ParticipationPolicy``), one
host-side context object (``RoundContext``), one selection result
(``Selection``) carrying the inclusion probabilities that make biased
cohorts correctable, and the Horvitz–Thompson weight helper the engines
thread into aggregation.

Estimator contract
------------------

A policy returns the cohort it selected AND the probability each member
had of being selected (``Selection.probs``).  The engines turn those
into inverse-probability weights (``ht_weights``) and aggregate

    u_t = sum_i w_i * delta_i / sum_i w_i        (self-normalized HT)

so the merged update estimates the population mean over the policy's
support even when selection is biased toward hot clients.  The pure
(un-normalized) Horvitz–Thompson estimator ``(1/N) sum_i delta_i / pi_i``
is exactly unbiased and is what the property tests pin; the engines use
the self-normalized form because its magnitude does not fluctuate with
the realized sum of weights (the ratio bias is O(1/cohort)).  A policy
whose realized probabilities are all equal sets ``Selection.uniform`` —
the engines then keep the exact unweighted-mean code path, which is what
makes ``participation="uniform"`` replay the pre-policy trajectories
bit-for-bit.

Two sampling designs are distinguished because their weights differ:

  without replacement  (``with_replacement=False``): ``probs`` are the
      inclusion probabilities pi_i; HT weight 1/pi_i.
  with replacement     (``with_replacement=True``): ``probs`` are the
      per-draw probabilities p_i of a ``k``-draw design; Hansen–Hurwitz
      weight 1/(k p_i).  Duplicates in the cohort are separate draws.

Availability/energy state is PER POLICY INSTANCE: bind a fresh policy to
each run (``make_policy``), exactly like codec pipeline state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple
from collections.abc import Sequence

import numpy as np


@dataclass
class RoundContext:
    """Everything a policy may look at when selecting.

    ``rng`` is the LEARNING RNG stream (the one cohort sampling always
    consumed): a policy draws its selection randomness from it so the
    uniform policy reproduces the legacy call sequence exactly.
    ``population`` distinguishes the two legacy call shapes: a fresh
    cohort drawn from the whole population (sync rounds, the fedbuff
    initial wave — ``rng.choice(n, size, replace=False)``) versus a
    single redispatch from the currently idle set (fedbuff steady state
    — ``rng.integers(len(idle))``).  ``distinct`` forbids duplicate
    cohort members (fedbuff: one in-flight job per client).  ``sim`` is
    True under the event engines, where mid-round failures exist and
    availability is priced by the dispatch-survival hook instead of at
    selection time.  ``now`` is virtual seconds under the engines and
    the round index in ``run_fl`` (which has no clock)."""
    rng: np.random.Generator
    n_clients: int
    cohort_size: int
    candidates: np.ndarray                 # eligible client ids
    population: bool = True
    distinct: bool = False
    sim: bool = False
    round: int = 0
    now: float = 0.0
    bw_period: float = 600.0               # diurnal cycle period (phase lock)


class Selection(NamedTuple):
    """One policy decision: who, and how probable each pick was."""
    cohort: np.ndarray                     # selected client ids (len k)
    probs: np.ndarray                      # per-member pi_i (or draw p_i)
    with_replacement: bool = False
    uniform: bool = True                   # all members equally weighted ->
                                           # engines keep the exact
                                           # unweighted-mean path


class ParticipationPolicy:
    """Base class every cohort policy extends.

    Subclasses override ``select`` (required) and any of the state hooks.
    ``weighted`` declares that selections may be non-uniform, so the
    engines build the HT-weighted aggregation variant (and collect the
    per-client observation signals the policy asks for via
    ``wants_loss``/``wants_update_norm``).  Policies with
    ``weighted=False`` are guaranteed to return ``uniform=True``
    selections and ride the exact legacy aggregation path."""

    name: str = ""
    weighted: bool = False                 # may return non-uniform probs
    wants_loss: bool = False               # feed per-client losses
    wants_update_norm: bool = False        # feed per-client update norms

    def __init__(self, *args: Any):
        self.args = args
        self.n_clients = 0
        self._rng: np.random.Generator | None = None

    # -- lifecycle ---------------------------------------------------------
    def bind(self, n_clients: int, seed: int = 0) -> "ParticipationPolicy":
        """Allocate per-client state for one run.  The policy's OWN rng
        stream is derived from (seed, name) so policy-internal randomness
        (e.g. run_fl-side availability draws) never perturbs the learning
        or systems streams."""
        self.n_clients = int(n_clients)
        self._rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0xFFFFFFFF, 0x9A7,
                                    sum(ord(c) for c in self.name)]))
        self._bind_state()
        return self

    def _bind_state(self) -> None:        # per-client arrays live here
        pass

    # -- the decision ------------------------------------------------------
    def select(self, ctx: RoundContext) -> Selection:
        raise NotImplementedError

    # -- state hooks (all optional no-ops) ---------------------------------
    def observe_round(self, cohort: Sequence[int],
                      losses: np.ndarray | None = None,
                      update_norms: np.ndarray | None = None,
                      now: float = 0.0) -> None:
        """Per-client signals after the cohort's updates were computed."""

    def observe_dispatch(self, c: int, now: float = 0.0,
                         cost_s: float | None = None) -> None:
        """One client was dispatched at ``now``; ``cost_s`` is the cost
        model's estimate of its busy seconds (None in ``run_fl``, which
        has no clock — policies fall back to unit cost per round)."""

    def dispatch_survives(self, c: int, res: Any,
                          sys_rng: np.random.Generator) -> bool:
        """Does this dispatch survive to upload?  Default replicates the
        legacy per-resource dropout draw BIT-FOR-BIT: a single systems-
        stream draw, made only when the device's dropout rate is
        nonzero."""
        return not (res.dropout and sys_rng.random() < res.dropout)

    # -- misc --------------------------------------------------------------
    def spec(self) -> str:
        if not self.args:
            return self.name
        return self.name + ":" + ",".join(f"{a:g}" if isinstance(a, float)
                                          else str(a) for a in self.args)

    def __repr__(self) -> str:           # pragma: no cover - debugging aid
        return f"<policy {self.spec()}>"


def uniform_selection(ctx: RoundContext,
                      candidates: np.ndarray | None = None) -> Selection:
    """The legacy sampling calls, verbatim — shared by every policy that
    falls back to uniform choice over some candidate pool.

    population=True  ->  rng.choice(n, size=k, replace=False)
    population=False ->  candidates[rng.integers(len(candidates))]

    With ``candidates`` defaulting to ``ctx.candidates`` and covering the
    full population, these are byte-for-byte the calls the engines
    hard-coded before the policy API existed."""
    cand = ctx.candidates if candidates is None else candidates
    if ctx.population and len(cand) == ctx.n_clients:
        k = min(ctx.cohort_size, ctx.n_clients)
        cohort = ctx.rng.choice(ctx.n_clients, size=k, replace=False)
    elif ctx.population:
        k = min(ctx.cohort_size, len(cand))
        cohort = ctx.rng.choice(cand, size=k, replace=False)
    else:
        cohort = np.asarray([cand[int(ctx.rng.integers(len(cand)))]])
    pool = max(len(cand), 1)
    probs = np.full(len(cohort), len(cohort) / pool, np.float64)
    return Selection(np.asarray(cohort, np.int64), probs,
                     with_replacement=False, uniform=True)


HT_CLIP = 8.0        # engine default for ``ht_weights(clip=...)``: truncated
                     # IPS — an unlikely pick can outweigh the likeliest
                     # cohort member by at most this factor.  Unclipped HT is
                     # exactly unbiased but its variance is 1/min(pi): one
                     # epsilon-exploration pick with pi ~ 1e-3 would dominate
                     # an entire merge and (empirically) diverge non-IID
                     # training; the clip trades a bounded reweighting bias
                     # for bounded variance, the standard IPS truncation.


def ht_weights(sel: Selection, clip: float | None = None) -> np.ndarray:
    """Inverse-probability aggregation weights for one selection.

    Without replacement the weight is the Horvitz–Thompson 1/pi_i; with
    replacement it is the Hansen–Hurwitz 1/(k p_i).  The engines feed
    these to a SELF-NORMALIZING merge (weights are divided by their sum,
    or folded into the staleness-discount normalization under fedbuff),
    so any common scale factor — including the 1/N of the textbook
    population-mean estimator — cancels and is omitted here.

    ``clip`` (the engines pass ``HT_CLIP``) caps each weight at ``clip``
    times the selection's smallest weight; ``None`` is the pure,
    exactly-unbiased estimator the property tests pin."""
    probs = np.asarray(sel.probs, np.float64)
    if np.any(probs <= 0.0):
        raise ValueError(f"selection carries non-positive inclusion "
                         f"probabilities: {probs}; HT weights undefined")
    w = 1.0 / probs
    if sel.with_replacement:
        w = w / max(len(sel.cohort), 1)
    if clip is not None and len(w):
        w = np.minimum(w, clip * w.min())
    return w


def fairness_summary(participation_count: np.ndarray) -> dict:
    """min/median/max participation across the population — the
    one-glance biased-cohort telemetry on every result object."""
    c = np.asarray(participation_count, np.float64)
    return {"min": float(c.min()) if c.size else 0.0,
            "median": float(np.median(c)) if c.size else 0.0,
            "max": float(c.max()) if c.size else 0.0}
