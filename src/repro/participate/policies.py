"""The built-in cohort policies.

  uniform          — the legacy sampler, verbatim (bit-for-bit replay)
  powd:d           — power-of-choice: sample d candidates uniformly, keep
                     the cohort with the highest tracked client loss;
                     inclusion probabilities are EXACT (hypergeometric
                     over the loss ranking), so HT reweighting debiases
                     the loss-hungry cohorts
  importance:norm  — update-norm-proportional sampling (with
                     replacement; Hansen–Hurwitz weights 1/(k p_i))
  avail:bernoulli:p— every dispatch independently fails with probability
                     p: the participation-layer form of the retired
                     ``SimScenario.dropout`` scalar (bit-for-bit shim
                     under the engines; selection-time filtering in
                     ``run_fl``, which has no mid-round failure model)
  avail:diurnal[:f[:P]] — per-client availability curves phase-locked to
                     the diurnal bandwidth cycle: client i is available
                     while sin(2 pi t / P + 2 pi i / N) clears the
                     threshold that makes its duty cycle f (default 0.5);
                     P defaults to the scenario's ``bw_period``
  energy:J[:r[:w]] — per-client battery of J joules, depleted at w J/s
                     (default 1) for the cost model's busy seconds of
                     every dispatch and recharged at r J/s (default
                     0.02*J) while idle; dead clients are unselectable
                     until they recharge above zero

Selection randomness draws from the LEARNING rng in the round context
(the stream the legacy samplers consumed); policy-internal randomness
(run_fl-side Bernoulli availability) uses the policy's own bound stream.
"""
from __future__ import annotations

import math

import numpy as np

from repro.participate.policy import (ParticipationPolicy, RoundContext,
                                      Selection, uniform_selection)


class UniformPolicy(ParticipationPolicy):
    """The pre-policy behaviour, exactly: uniform without replacement
    from the population (sync cohorts, the fedbuff first wave) and a
    uniform pick from the idle set (fedbuff redispatch)."""

    name = "uniform"

    def select(self, ctx: RoundContext) -> Selection:
        return uniform_selection(ctx)


# ---------------------------------------------------------------------------
# power-of-choice (loss-biased) with exact inclusion probabilities
# ---------------------------------------------------------------------------


def _hypergeom_cdf(k: int, pop: int, successes: int, draws: int) -> float:
    """P(X <= k) for X ~ Hypergeometric(pop, successes, draws), via
    log-binomials (no scipy in the dependency set)."""
    if k < 0:
        return 0.0
    if draws <= 0 or successes <= 0:
        return 1.0

    def lchoose(n: int, j: int) -> float:
        if j < 0 or j > n:
            return -math.inf
        return (math.lgamma(n + 1) - math.lgamma(j + 1)
                - math.lgamma(n - j + 1))

    denom = lchoose(pop, draws)
    total = 0.0
    for j in range(max(0, draws - (pop - successes)),
                   min(k, successes, draws) + 1):
        total += math.exp(lchoose(successes, j)
                          + lchoose(pop - successes, draws - j) - denom)
    return min(total, 1.0)


class PowerOfChoice(ParticipationPolicy):
    """powd:d[:eps] — Cho et al.'s power-of-choice under the policy
    protocol, with an epsilon-greedy floor.

    With probability 1-eps: sample ``d`` candidates uniformly without
    replacement from the eligible pool, keep the ``cohort_size`` with
    the highest tracked loss (never-observed clients rank highest, so
    the population is explored before exploitation starts; ties break by
    client id, and the SAME total order prices the inclusion
    probabilities, so they are exact).  With probability eps (default
    0.1): a plain uniform cohort.  The exploration floor is what keeps
    every inclusion probability POSITIVE — pure power-of-choice gives a
    client ranked below M-d+k a probability of exactly zero, where the
    HT estimator is undefined and the selection bias uncorrectable.  For
    a client ranked with ``r`` pool members strictly ahead of it,

        pi = (1-eps) * (d/M) * P[Hypergeom(M-1, r, d-1) <= k-1]
             + eps * k/M

    — in the d-sample with fewer than k sampled rivals outranking it,
    mixed with the uniform floor."""

    name = "powd"
    weighted = True
    wants_loss = True

    def __init__(self, d: int = 8, eps: float = 0.1):
        super().__init__(int(d), float(eps))
        self.d = int(d)
        self.eps = float(eps)
        if self.d < 1:
            raise ValueError(f"powd candidate-set size must be >= 1, got {d}")
        if not 0.0 < self.eps <= 1.0:
            raise ValueError(f"powd exploration eps must be in (0, 1], "
                             f"got {eps}")

    def _bind_state(self) -> None:
        self.client_loss = np.full(self.n_clients, math.inf, np.float64)

    def _ranked(self, pool: np.ndarray) -> np.ndarray:
        """Pool ids ordered by (loss desc, id asc) — the selection AND
        pricing order."""
        pool = np.asarray(pool, np.int64)
        order = np.lexsort((pool, -self.client_loss[pool]))
        return pool[order]

    def _inclusion(self, pool: np.ndarray, cohort: np.ndarray, k: int,
                   d: int) -> np.ndarray:
        M = len(pool)
        rank = {int(c): r for r, c in enumerate(self._ranked(pool))}
        return np.asarray(
            [(1.0 - self.eps) * (d / M)
             * _hypergeom_cdf(k - 1, M - 1, rank[int(c)], d - 1)
             + self.eps * k / M for c in cohort], np.float64)

    def select(self, ctx: RoundContext) -> Selection:
        pool = np.asarray(ctx.candidates, np.int64)
        M = len(pool)
        k = min(ctx.cohort_size, M)
        d = min(max(self.d, k), M)
        if ctx.rng.random() < self.eps:         # exploration floor
            cohort = ctx.rng.choice(pool, size=k, replace=False)
        else:
            sample = ctx.rng.choice(pool, size=d, replace=False)
            cohort = self._ranked(sample)[:k]
        return Selection(np.asarray(cohort, np.int64),
                         self._inclusion(pool, cohort, k, d),
                         with_replacement=False, uniform=False)

    def observe_round(self, cohort, losses=None, update_norms=None,
                      now: float = 0.0) -> None:
        if losses is None:
            return
        for c, l in zip(cohort, np.asarray(losses, np.float64)):
            self.client_loss[int(c)] = float(l)


# ---------------------------------------------------------------------------
# importance (update-norm-proportional) sampling
# ---------------------------------------------------------------------------


class ImportanceNorm(ParticipationPolicy):
    """importance:norm — draw probabilities proportional to each client's
    last observed update norm (smoothed so every probability stays
    positive and HT weights exist; unseen clients score at the running
    maximum, so they are explored before the norms take over).

    Sampling is WITH replacement (k i.i.d. draws, exact Hansen–Hurwitz
    weights 1/(k p_i)); under ``distinct`` contexts (fedbuff: one
    in-flight job per client) it degrades to numpy's sequential
    without-replacement draw with the same per-draw probabilities — the
    weights are then the standard importance approximation."""

    name = "importance"
    weighted = True
    wants_update_norm = True
    _SMOOTH = 0.05                      # floor, as a fraction of the mean score

    def _bind_state(self) -> None:
        self.norm = np.full(self.n_clients, np.nan, np.float64)

    def _probs(self, pool: np.ndarray) -> np.ndarray:
        s = self.norm[pool]
        seen = ~np.isnan(s)
        fill = float(np.nanmax(self.norm)) if seen.any() else 1.0
        s = np.where(seen, s, max(fill, 1e-30))
        s = s + self._SMOOTH * float(s.mean()) + 1e-30
        return s / s.sum()

    def select(self, ctx: RoundContext) -> Selection:
        pool = np.asarray(ctx.candidates, np.int64)
        k = min(ctx.cohort_size, len(pool))
        p = self._probs(pool)
        cohort = ctx.rng.choice(pool, size=k, replace=not ctx.distinct, p=p)
        by_id = {int(c): p[i] for i, c in enumerate(pool)}
        probs = np.asarray([by_id[int(c)] for c in cohort], np.float64)
        return Selection(np.asarray(cohort, np.int64), probs,
                         with_replacement=True, uniform=False)

    def observe_round(self, cohort, losses=None, update_norms=None,
                      now: float = 0.0) -> None:
        if update_norms is None:
            return
        for c, n in zip(cohort, np.asarray(update_norms, np.float64)):
            self.norm[int(c)] = float(n)


# ---------------------------------------------------------------------------
# availability policies
# ---------------------------------------------------------------------------


class AvailBernoulli(ParticipationPolicy):
    """avail:bernoulli:p — the participation-layer home of the retired
    ``SimScenario.dropout`` scalar.

    Under the event engines this is mid-round failure, exactly as the
    scalar was: selection stays uniform (bit-for-bit the legacy calls)
    and every dispatch draws ONE systems-stream Bernoulli in
    ``dispatch_survives`` — the same draw, at the same sequence point,
    the engines used to hard-code, so ``SimScenario(dropout=p)`` and
    ``participation="avail:bernoulli:p"`` produce identical
    trajectories.  ``run_fl`` has no mid-round failure model, so there
    the rate filters availability at selection time instead (from the
    policy's own stream — the learning rng is untouched)."""

    name = "avail"

    def __init__(self, rate: float = 0.0):
        super().__init__("bernoulli", float(rate))
        self.rate = float(rate)
        if not 0.0 <= self.rate < 1.0:
            raise ValueError(f"avail:bernoulli rate must be in [0, 1), "
                             f"got {rate}")

    def select(self, ctx: RoundContext) -> Selection:
        if ctx.sim or self.rate == 0.0:
            return uniform_selection(ctx)
        cand = np.asarray(ctx.candidates, np.int64)
        avail = cand[self._rng.random(len(cand)) >= self.rate]
        if len(avail) == 0:
            return Selection(np.zeros(0, np.int64), np.zeros(0), False, True)
        return uniform_selection(ctx, avail)

    def dispatch_survives(self, c, res, sys_rng) -> bool:
        # the policy's population rate never LOWERS a device's own
        # (bimodal per-mode) failure rate: the effective rate is the
        # worse of the two — and exactly ``res.dropout`` under the
        # scenario-scalar shim (where both are the same number), so the
        # legacy draw sequence is preserved bit-for-bit
        p = max(self.rate, res.dropout)
        return not (p and sys_rng.random() < p)


class AvailDiurnal(ParticipationPolicy):
    """avail:diurnal[:frac[:period]] — deterministic per-client duty
    cycles phase-locked to the diurnal bandwidth cycle.

    Client i is available while sin(2 pi t / P + phi_i) >= cos(pi*frac),
    with phases phi_i = 2 pi i / N spread evenly over the population —
    at any instant about ``frac`` of the population is reachable, and
    WHICH clients those are rotates with the (virtual) time of day, the
    biased-availability regime of the practicality surveys.  ``period``
    defaults to the round context's ``bw_period`` so the availability
    trough lines up with the bandwidth trough of the "diurnal" scenario.
    Selection is uniform over the available candidates (equal weights);
    when fewer than the requested cohort are available the cohort
    SHRINKS to the available set rather than conscripting offline
    clients — ``n_forced`` counts the redispatches where nobody at all
    was available and the policy had to fall back to the full pool.
    Under ``run_fl`` (no clock) ``now`` is the round index and the
    context's period defaults to one full cycle per run, so the duty
    rotation survives outside the event engines too; pass an explicit
    ``period`` (in rounds there, virtual seconds in the sims) to pin
    it."""

    name = "avail"

    def __init__(self, frac: float = 0.5, period: float = 0.0):
        super().__init__("diurnal", float(frac), float(period))
        self.frac = float(frac)
        self.period = float(period)          # 0 -> ctx.bw_period
        if not 0.0 < self.frac <= 1.0:
            raise ValueError(f"avail:diurnal duty fraction must be in "
                             f"(0, 1], got {frac}")
        self.n_forced = 0

    def available(self, ids: np.ndarray, now: float,
                  bw_period: float = 600.0) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        P = self.period or bw_period
        phase = 2.0 * math.pi * ids / max(self.n_clients, 1)
        lvl = np.sin(2.0 * math.pi * now / P + phase)
        return ids[lvl >= math.cos(math.pi * self.frac)]

    def select(self, ctx: RoundContext) -> Selection:
        avail = self.available(ctx.candidates, ctx.now, ctx.bw_period)
        if len(avail) == 0:
            if ctx.population:
                return Selection(np.zeros(0, np.int64), np.zeros(0),
                                 False, True)
            self.n_forced += 1           # a slot must be fed: fall back
            return uniform_selection(ctx)
        return uniform_selection(ctx, avail)


# ---------------------------------------------------------------------------
# energy budgets
# ---------------------------------------------------------------------------


class EnergyBudget(ParticipationPolicy):
    """energy:J[:recharge[:power]] — per-client battery accounting.

    Every dispatch depletes the client's battery by ``power`` J/s times
    the cost model's busy seconds for that round trip (download +
    compute + upload; ``run_fl`` has no clock, so a round costs one
    nominal busy-second there).  Idle seconds recharge at ``recharge``
    J/s (default: 2% of capacity per second) up to the capacity cap.  A
    client whose battery is at zero is DEAD — unselectable until idle
    recharge lifts it above zero — so the selectable population, and
    with it the fairness telemetry, breathes with the energy budget.
    When nobody eligible is alive the cohort is empty (the engines skip
    the round / leave the slot idle) rather than conscripting a dead
    device."""

    name = "energy"

    def __init__(self, capacity: float = 20.0, recharge: float = -1.0,
                 power: float = 1.0):
        if capacity <= 0:
            raise ValueError(f"energy capacity must be positive, got {capacity}")
        self.capacity = float(capacity)
        # negative = unset -> default 2%/s; an explicit 0 means NO recharge
        self.recharge = (0.02 * self.capacity if recharge < 0
                         else float(recharge))
        self.power = float(power)
        super().__init__(self.capacity, self.recharge, self.power)

    def _bind_state(self) -> None:
        self.battery = np.full(self.n_clients, self.capacity, np.float64)
        self._busy_until = np.zeros(self.n_clients, np.float64)
        self._last_acc = np.zeros(self.n_clients, np.float64)

    def _accrue(self, now: float) -> None:
        """Credit idle recharge up to ``now`` (lazy, all clients)."""
        idle_from = np.maximum(self._last_acc, self._busy_until)
        gain = self.recharge * np.maximum(0.0, now - idle_from)
        self.battery = np.minimum(self.capacity, self.battery + gain)
        self._last_acc = np.maximum(self._last_acc, now)

    def select(self, ctx: RoundContext) -> Selection:
        self._accrue(ctx.now)
        cand = np.asarray(ctx.candidates, np.int64)
        alive = cand[self.battery[cand] > 0.0]
        if len(alive) == 0:
            return Selection(np.zeros(0, np.int64), np.zeros(0), False, True)
        return uniform_selection(ctx, alive)

    def observe_dispatch(self, c: int, now: float = 0.0,
                         cost_s: float | None = None) -> None:
        self._accrue(now)
        cost = 1.0 if cost_s is None else float(cost_s)
        self.battery[c] = max(0.0, self.battery[c] - self.power * cost)
        self._busy_until[c] = now + cost
