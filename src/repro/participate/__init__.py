"""repro.participate — composable client-participation policies.

One protocol (``ParticipationPolicy``), one declaration syntax (spec
strings via the registry, mirroring ``repro.compress``) for the whole
who-trains-this-round axis: cohort selection, availability traces,
energy budgets, and the Horvitz–Thompson inclusion-probability weights
that keep aggregation unbiased under biased selection.

    from repro.fl.rounds import FLConfig
    cfg = FLConfig(participation="powd:8")        # loss-biased cohorts,
    # HT-debiased merge; "avail:diurnal", "energy:20", "importance:norm",
    # "avail:bernoulli:0.1" (the retired SimScenario.dropout scalar) ...
"""
from repro.participate.policies import (AvailBernoulli, AvailDiurnal,  # noqa: F401
                                        EnergyBudget, ImportanceNorm,
                                        PowerOfChoice, UniformPolicy)
from repro.participate.policy import (HT_CLIP, ParticipationPolicy,  # noqa: F401
                                      RoundContext, Selection,
                                      fairness_summary, ht_weights,
                                      uniform_selection)
from repro.participate.registry import (POLICIES, make_policy,  # noqa: F401
                                        parse_policy, register_policy,
                                        resolve_policy)
from repro.participate.vectorized import (VECTOR_POLICIES,  # noqa: F401
                                          VAvailBernoulli, VAvailDiurnal,
                                          VectorPolicy, VEnergy, VUniform,
                                          make_vector_policy,
                                          register_vector_policy)
