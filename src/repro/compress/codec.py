"""The update-codec protocol and the composable pipeline.

An ``UpdateCodec`` is one stage of the client<->server transfer path: it
transforms the update tree (jit-traceably), optionally threads per-round
state (LBGM anchors, EF residuals), and prices its own wire format
host-side.  A ``CodecPipeline`` chains stages so the whole compressor
stack is declared as data — ``FLConfig.codecs = ("fedpaq:4", "topk:0.1",
"ef")`` — instead of hard-coded flags re-implemented at every call site.

Every stage has a ``Direction``: ``UP`` (client->server update upload,
the default) or ``DOWN`` (server->client model broadcast, declared with
the ``down:`` spec prefix — ``"down:delta"``, ``"down:fedpaq:8"``).  A
pipeline is one direction; the engines build one pipeline per direction
from the same ``FLConfig.codecs`` declaration
(``registry.partition_codec_specs``), so the downlink rides the exact
same encode/price machinery as the uplink.

Protocol (all device-side methods are jit-traceable):

  init_state(params, um) -> state
      Per-pipeline (sync engines: the cohort-mean "virtual client") or
      per-client (fedbuff engine) codec state; None for stateless
      stages.  Stages that need the unit map (LBGM, TopK) bind it here,
      so a pipeline instance belongs to ONE model after init_state.
  encode(state, update, key) -> (encoded, state, aux)
      The lossy/lossless transform.  ``encoded`` is the value-domain
      reconstruction the server works with (a real transport would
      serialize the wire form; the simulator transmits the decoded
      values and prices the wire bytes separately).  ``aux`` is the
      per-round pricing evidence (LBGM's sent-full mask, TopK's
      per-unit survivor counts) or None.
  decode(state, encoded) -> update
      Explicit inverse hook; identity for every stage here because
      ``encode`` already returns decoded-domain values.
  commit(state, injected, final) -> state
      Post-pipeline hook (``needs_commit = True`` stages only): called
      once per encode pass with the value the stage injected and the
      final pipeline output, so error-feedback can measure exactly what
      the downstream stages destroyed.
  price_per_unit(per_unit, sizes, mask, aux) -> np.ndarray
      HOST-side float64 pricing, composable: receives the running
      per-unit byte array (already gated by the dispatched recycle mask
      — composes with the dispatched-mask pricing of the async waste
      ledger) and returns the refined one.  ``aux=None`` must price a
      conservative nominal (used for dispatch-time wall-clock estimates
      and rejected payloads whose encode never ran).

Ordering: stages encode in listed order — wire order for the lossy
stack — EXCEPT error-feedback stages, which the pipeline hoists to the
front.  EF compensates the error of everything downstream of it, so
``("fedpaq:4", "topk:0.1", "ef")`` reads naturally ("quantize, sparsify,
with error feedback") and still puts the residual injection before the
lossy stages, the only position where EF21-style compensation is
well-defined.
"""
from __future__ import annotations

import enum
from typing import Any
from collections.abc import Sequence

import jax
import numpy as np

from repro.core.units import UnitMap

Params = Any


class Direction(enum.Enum):
    """Which link a codec stage compresses."""
    UP = "up"                       # client -> server (update upload)
    DOWN = "down"                   # server -> client (model broadcast)


class UpdateCodec:
    """Base stage: identity transform, dense pricing, no state."""

    name: str = "identity"
    direction: Direction = Direction.UP   # set per instance by the parser
                                          # from the "down:" spec prefix
    down_only: bool = False         # True -> only meaningful on the
                                    # broadcast (the parser rejects the
                                    # bare spec without "down:")
    front: bool = False             # True -> hoisted to the pipeline head
                                    # (delta transport must price before
                                    # the lossy stages scale the bytes)
    stateful: bool = False          # True -> per-client state under async
    needs_commit: bool = False      # True -> commit() sees the final output
    requires_sync: bool = False     # True -> the stage's state is anchored
                                    # to a synchronous server view; async
                                    # engines must reject it (declared by
                                    # the stage, not special-cased by name
                                    # in the engines)

    def init_state(self, params: Params, um: UnitMap):
        return None

    def encode(self, state, update: Params, key):
        return update, state, None

    def decode(self, state, encoded: Params) -> Params:
        return encoded

    def commit(self, state, injected: Params, final: Params):
        return state

    def price_per_unit(self, per_unit: np.ndarray, sizes: np.ndarray,
                       mask: np.ndarray, aux=None) -> np.ndarray:
        return per_unit

    def spec(self) -> str:
        """The spec string that reconstructs this stage (see registry),
        including the ``down:`` direction prefix."""
        body = self._spec()
        return f"down:{body}" if self.direction is Direction.DOWN else body

    def _spec(self) -> str:
        """The direction-free spec body (subclasses override this, not
        ``spec``, so the prefix logic lives in one place)."""
        return self.name

    def __repr__(self) -> str:
        return f"<codec {self.spec()}>"


class CodecPipeline:
    """An ordered stack of ``UpdateCodec`` stages.

    State is threaded per stage as a tuple (position-aligned with
    ``stages``), so the whole pipeline state is one jit-friendly pytree.
    ``needs_commit`` and ``front`` stages are hoisted to the front at
    construction (stable order otherwise) — see the module docstring.

    A pipeline is ONE direction: mixing UP and DOWN stages is an error
    (use ``registry.partition_codec_specs`` /
    ``rounds.build_codec_pipeline(cfg, direction=...)`` to split a mixed
    declaration into the per-link pipelines).
    """

    def __init__(self, stages: Sequence[UpdateCodec]):
        dirs = {s.direction for s in stages}
        if len(dirs) > 1:
            raise ValueError(
                f"a CodecPipeline is one direction, got mixed specs "
                f"{[s.spec() for s in stages]}; partition with "
                f"repro.compress.partition_codec_specs first")
        self.direction: Direction = dirs.pop() if dirs else Direction.UP
        front = [s for s in stages if s.needs_commit or s.front]
        rest = [s for s in stages if not (s.needs_commit or s.front)]
        self.stages: tuple[UpdateCodec, ...] = tuple(front + rest)

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return len(self.stages)

    def __bool__(self) -> bool:
        return bool(self.stages)

    @property
    def stateful(self) -> bool:
        return any(s.stateful for s in self.stages)

    def has(self, name: str) -> bool:
        return any(s.name == name for s in self.stages)

    def sync_only_specs(self) -> tuple[str, ...]:
        """Specs of stages that cannot run under async engines."""
        return tuple(s.spec() for s in self.stages if s.requires_sync)

    def specs(self) -> tuple[str, ...]:
        return tuple(s.spec() for s in self.stages)

    def aux_for(self, name: str, value) -> tuple:
        """An aux tuple carrying ``value`` at stage ``name`` (None at
        every other position) — how an engine hands host-side pricing
        evidence to one stage (the delta transport's chain price) without
        running ``encode``."""
        return tuple(value if s.name == name else None for s in self.stages)

    def __repr__(self) -> str:
        return f"CodecPipeline{self.specs()}"

    # -- device side --------------------------------------------------------

    def init_state(self, params: Params, um: UnitMap) -> tuple:
        return tuple(s.init_state(params, um) for s in self.stages)

    def encode(self, states: tuple, update: Params, key):
        """Run every stage in order; returns (encoded, states, auxes).

        Each stage gets an independent key (``fold_in`` of the round key
        by stage index).  ``needs_commit`` stages additionally observe
        the final pipeline output so they can close their feedback loop.
        """
        new_states = list(states)
        auxes = []
        injected = {}
        x = update
        for i, (stage, st) in enumerate(zip(self.stages, states)):
            x, st, aux = stage.encode(st, x, jax.random.fold_in(key, i))
            new_states[i] = st
            auxes.append(aux)
            if stage.needs_commit:
                injected[i] = x
        for i, v in injected.items():
            new_states[i] = self.stages[i].commit(new_states[i], v, x)
        return x, tuple(new_states), tuple(auxes)

    def decode(self, states: tuple, encoded: Params) -> Params:
        """Inverse map, last stage first (identity for value-domain
        stages — kept explicit so lossless round-trip properties are
        statable)."""
        x = encoded
        for stage, st in zip(reversed(self.stages), reversed(states)):
            x = stage.decode(st, x)
        return x

    # -- host side ----------------------------------------------------------

    def price_per_unit(self, sizes: np.ndarray, mask: np.ndarray,
                       auxes: tuple | None = None) -> np.ndarray:
        """ONE client's upload bytes PER UNIT (host-side float64).

        ``mask`` is the recycle mask the client DOWNLOADED at dispatch
        (units inside it are never serialized); DOWN pipelines pass an
        all-False mask — the broadcast carries every unit.  ``auxes`` is
        the tuple ``encode`` returned (or ``aux_for`` built), or None for
        the conservative nominal price (dispatch-time estimates, rejected
        payloads).
        """
        mask = np.asarray(mask, bool)
        sizes = np.asarray(sizes, np.float64)
        per_unit = np.where(mask, 0.0, sizes)
        for i, stage in enumerate(self.stages):
            aux = None if auxes is None else auxes[i]
            aux = None if aux is None else np.asarray(aux)
            per_unit = stage.price_per_unit(per_unit, sizes, mask, aux)
        return per_unit

    def price_bytes(self, sizes: np.ndarray, mask: np.ndarray,
                    auxes: tuple | None = None) -> float:
        return float(self.price_per_unit(sizes, mask, auxes).sum())
