"""Codec registry and the spec-string grammar.

Grammar (one stage per spec string):

    spec   ::= ["down:"] name [":" arg ("," arg)*]
    name   ::= registered codec name        (fedpaq | prune | dropout |
                                             lbgm | topk | ef | delta | ...)
    arg    ::= int | float                  (positional, passed to the
                                             codec constructor)

Examples: ``"fedpaq:4"``, ``"topk:0.1"``, ``"ef"``,
``("fedpaq:4", "topk:0.1", "ef")``.  A single string may also carry a
whole stack separated by ``+`` (CLI-friendly): ``"fedpaq:4+topk:0.1+ef"``.

The ``down:`` prefix declares a stage of the server->client broadcast
instead of the update upload (``Direction.DOWN``): ``"down:delta"`` is
the versioned delta-encoded model download, ``"down:fedpaq:8"``
quantizes the broadcast.  One ``FLConfig.codecs`` tuple declares both
links; ``partition_codec_specs`` splits it so each engine builds one
pipeline per direction.

``legacy_codec_specs`` is the deprecation shim: it maps the four retired
``FLConfig`` scalar flags onto the equivalent spec tuple, in the exact
order the old hard-coded stack applied them (fedpaq -> prune -> dropout
-> lbgm), so legacy configs run bit-for-bit through the pipeline.
"""
from __future__ import annotations
from collections.abc import Sequence

from repro.compress.codec import CodecPipeline, Direction, UpdateCodec
from repro.compress.codecs import (DeltaDownlink, DropoutAvg, ErrorFeedback,
                                   FedPAQ, LBGM, Prune, TopK)

CODECS: dict[str, type[UpdateCodec]] = {}

_DOWN_PREFIX = "down:"


def register_codec(cls: type[UpdateCodec]) -> type[UpdateCodec]:
    """Register a codec class under ``cls.name`` (usable as decorator)."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls!r} has no codec name")
    CODECS[cls.name] = cls
    return cls


for _cls in (FedPAQ, Prune, DropoutAvg, LBGM, TopK, ErrorFeedback,
             DeltaDownlink):
    register_codec(_cls)


def _parse_arg(tok: str) -> int | float:
    tok = tok.strip()
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            raise ValueError(f"codec arg {tok!r} is not a number") from None


def parse_codec(spec: str) -> UpdateCodec:
    """One spec string -> one codec instance (direction set from the
    ``down:`` prefix)."""
    body = spec.strip()
    direction = Direction.UP
    if body.startswith(_DOWN_PREFIX):
        direction = Direction.DOWN
        body = body[len(_DOWN_PREFIX):].strip()
    name, _, argstr = body.partition(":")
    name = name.strip()
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r} in spec {spec!r}; "
                         f"registered: {sorted(CODECS)}")
    args = [_parse_arg(a) for a in argstr.split(",") if a.strip()] if argstr else []
    codec = CODECS[name](*args)
    if codec.down_only and direction is not Direction.DOWN:
        raise ValueError(f"codec {name!r} only exists on the broadcast; "
                         f"spec it as {_DOWN_PREFIX}{body}")
    codec.direction = direction
    return codec


def split_codec_specs(specs: str | Sequence[str]) -> tuple[str, ...]:
    """Normalize a codec-stack declaration to a tuple of spec strings.

    Accepts either a sequence of per-stage specs or one '+'-joined
    string (the CLI form) — the ONE place the '+' grammar lives."""
    if isinstance(specs, str):
        specs = specs.split("+")
    return tuple(s.strip() for s in specs if s.strip())


def partition_codec_specs(specs: str | Sequence[str]
                          ) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Split one mixed codec declaration into ``(up_specs, down_specs)``
    by the ``down:`` direction prefix (each side keeps its listed order)."""
    specs = split_codec_specs(specs)
    up = tuple(s for s in specs if not s.startswith(_DOWN_PREFIX))
    down = tuple(s for s in specs if s.startswith(_DOWN_PREFIX))
    return up, down


def parse_codecs(specs: str | Sequence[str],
                 direction: Direction | None = None) -> CodecPipeline:
    """Spec strings -> a ``CodecPipeline`` (empty specs -> identity).

    ``direction`` filters a mixed declaration to one link's stages;
    without it the specs must already be single-direction (the pipeline
    constructor rejects a mixed stack)."""
    if direction is not None:
        up, down = partition_codec_specs(specs)
        specs = down if direction is Direction.DOWN else up
    return CodecPipeline([parse_codec(s) for s in split_codec_specs(specs)])


def legacy_codec_specs(fedpaq_bits: int = 0, prune_keep: float = 0.0,
                       dropout_rate: float = 0.0,
                       lbgm_threshold: float = 0.0) -> tuple[str, ...]:
    """The retired FLConfig scalar flags as an equivalent spec tuple."""
    out: list[str] = []
    if fedpaq_bits:
        out.append(f"fedpaq:{int(fedpaq_bits)}")
    if prune_keep:
        out.append(f"prune:{float(prune_keep):g}")
    if dropout_rate:
        out.append(f"dropout:{float(dropout_rate):g}")
    if lbgm_threshold:
        out.append(f"lbgm:{float(lbgm_threshold):g}")
    return tuple(out)
