"""Codec registry and the spec-string grammar.

Grammar (one stage per spec string):

    spec   ::= name [":" arg ("," arg)*]
    name   ::= registered codec name        (fedpaq | prune | dropout |
                                             lbgm | topk | ef | ...)
    arg    ::= int | float                  (positional, passed to the
                                             codec constructor)

Examples: ``"fedpaq:4"``, ``"topk:0.1"``, ``"ef"``,
``("fedpaq:4", "topk:0.1", "ef")``.  A single string may also carry a
whole stack separated by ``+`` (CLI-friendly): ``"fedpaq:4+topk:0.1+ef"``.

``legacy_codec_specs`` is the deprecation shim: it maps the four retired
``FLConfig`` scalar flags onto the equivalent spec tuple, in the exact
order the old hard-coded stack applied them (fedpaq -> prune -> dropout
-> lbgm), so legacy configs run bit-for-bit through the pipeline.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple, Type, Union

from repro.compress.codec import CodecPipeline, UpdateCodec
from repro.compress.codecs import (DropoutAvg, ErrorFeedback, FedPAQ, LBGM,
                                   Prune, TopK)

CODECS: Dict[str, Type[UpdateCodec]] = {}


def register_codec(cls: Type[UpdateCodec]) -> Type[UpdateCodec]:
    """Register a codec class under ``cls.name`` (usable as decorator)."""
    if not getattr(cls, "name", None):
        raise ValueError(f"{cls!r} has no codec name")
    CODECS[cls.name] = cls
    return cls


for _cls in (FedPAQ, Prune, DropoutAvg, LBGM, TopK, ErrorFeedback):
    register_codec(_cls)


def _parse_arg(tok: str) -> Union[int, float]:
    tok = tok.strip()
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            raise ValueError(f"codec arg {tok!r} is not a number") from None


def parse_codec(spec: str) -> UpdateCodec:
    """One spec string -> one codec instance."""
    name, _, argstr = spec.strip().partition(":")
    name = name.strip()
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r} in spec {spec!r}; "
                         f"registered: {sorted(CODECS)}")
    args = [_parse_arg(a) for a in argstr.split(",") if a.strip()] if argstr else []
    return CODECS[name](*args)


def split_codec_specs(specs: Union[str, Sequence[str]]) -> Tuple[str, ...]:
    """Normalize a codec-stack declaration to a tuple of spec strings.

    Accepts either a sequence of per-stage specs or one '+'-joined
    string (the CLI form) — the ONE place the '+' grammar lives."""
    if isinstance(specs, str):
        specs = specs.split("+")
    return tuple(s.strip() for s in specs if s.strip())


def parse_codecs(specs: Union[str, Sequence[str]]) -> CodecPipeline:
    """Spec strings -> a ``CodecPipeline`` (empty specs -> identity)."""
    return CodecPipeline([parse_codec(s) for s in split_codec_specs(specs)])


def legacy_codec_specs(fedpaq_bits: int = 0, prune_keep: float = 0.0,
                       dropout_rate: float = 0.0,
                       lbgm_threshold: float = 0.0) -> Tuple[str, ...]:
    """The retired FLConfig scalar flags as an equivalent spec tuple."""
    out: List[str] = []
    if fedpaq_bits:
        out.append(f"fedpaq:{int(fedpaq_bits)}")
    if prune_keep:
        out.append(f"prune:{float(prune_keep):g}")
    if dropout_rate:
        out.append(f"dropout:{float(dropout_rate):g}")
    if lbgm_threshold:
        out.append(f"lbgm:{float(lbgm_threshold):g}")
    return tuple(out)
