"""repro.compress — composable update-codec pipeline.

One protocol (``UpdateCodec``), one composition rule (``CodecPipeline``),
one declaration syntax (spec strings via the registry) for the whole
client->server compressor stack:

    from repro.compress import parse_codecs
    pipe = parse_codecs(("fedpaq:4", "topk:0.1", "ef"))
    state = pipe.init_state(params, um)
    update, state, aux = pipe.encode(state, update, key)      # jit-safe
    bytes_per_unit = pipe.price_per_unit(sizes, mask, aux)    # host f64
"""
from repro.compress.codec import CodecPipeline, Direction, UpdateCodec  # noqa: F401
from repro.compress.codecs import (DELTA_STEP_UNIT_BYTES, DeltaDownlink,  # noqa: F401
                                   DropoutAvg, ErrorFeedback, FedPAQ,
                                   LBGM, Prune, TopK, delta_step_price,
                                   snapshot_price, versioned_download_price)
from repro.compress.registry import (CODECS, legacy_codec_specs,  # noqa: F401
                                     parse_codec, parse_codecs,
                                     partition_codec_specs,
                                     register_codec, split_codec_specs)
