"""Concrete update codecs.

Ports of the four legacy compressor flags (FedPAQ quantization, PruneFL
magnitude pruning, FedDropoutAvg, LBGM look-back) onto the
``UpdateCodec`` protocol, plus two stages the old scalar flags could not
express:

  topk : GLOBAL top-k sparsification across the whole update tree (the
         legacy ``prune`` keeps a fraction per tensor; global selection
         lets dense layers outcompete near-zero ones).  Priced as values
         + 4-byte indices from the exact per-unit survivor counts the
         encode emits as aux.
  ef   : EF21-style error feedback — a per-client residual accumulates
         exactly what the downstream lossy stages destroyed and is
         re-injected next round.  Stateful, which is what forces the
         pipeline's state threading to be real.

plus the downlink-only stage of the versioned broadcast:

  down:delta : delta-encoded model download — a client at server version
         v receives the chain of per-version applied updates v->current
         instead of a full snapshot whenever the chain is complete (the
         server's DeltaLedger still holds every step) AND cheaper than
         the snapshot.  Transport is LOSSLESS: the chain entries are the
         exact addends the additive server applied, so replaying them
         reproduces the broadcast bit-for-bit.  The per-step wire price
         is fresh units at full bytes + recycled units at
         DELTA_STEP_UNIT_BYTES (LUAR re-applies prev_update to recycled
         units, which the chain follower already holds); the pricing
         helpers live here (``delta_step_price`` / ``snapshot_price`` /
         ``versioned_download_price``) so both sim engines and
         ``fl/rounds.run_fl`` price the same protocol.

The quantize/prune/dropout transforms delegate to ``repro.fl.baselines``
so the paper-baseline math stays in one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.codec import Direction, UpdateCodec
from repro.core.units import UnitMap
from repro.fl import baselines

_INDEX_BYTES = 4.0                  # int32 coordinate per surviving entry
_F32_BYTES = 4.0                    # update entries are float32 in this repo
_LBGM_SCALAR_BYTES = 4.0            # one projection coefficient
DELTA_STEP_UNIT_BYTES = 4.0         # delta downlink: per recycled unit per
                                    # step — its mask bit + the recycle
                                    # coefficient (conservative: LUAR applies
                                    # prev_update verbatim, but a real
                                    # transport still frames the unit)


def _require_um(codec) -> UnitMap:
    um = getattr(codec, "_um", None)
    if um is None:
        raise RuntimeError(
            f"{codec.spec()!r} needs the unit map: call "
            f"pipeline.init_state(params, um) before encode")
    return um


class FedPAQ(UpdateCodec):
    """QSGD-style stochastic uniform quantization (comm ~ bits/32)."""

    name = "fedpaq"

    def __init__(self, bits: int = 4):
        bits = int(bits)
        if not 1 <= bits <= 32:
            raise ValueError(f"fedpaq bits must be in [1, 32], got {bits}")
        self.bits = bits

    def encode(self, state, update, key):
        return baselines.fedpaq_quantize(update, key, self.bits), state, None

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        return per_unit * (self.bits / 32.0)

    def _spec(self):
        return f"fedpaq:{self.bits}"


class Prune(UpdateCodec):
    """PruneFL-flavoured magnitude sparsification, per tensor.

    Sparse upload ~ values + indices = 2 * keep_fraction (capped at
    dense)."""

    name = "prune"

    def __init__(self, keep: float = 0.25):
        keep = float(keep)
        if not 0.0 < keep <= 1.0:
            raise ValueError(f"prune keep fraction must be in (0, 1], got {keep}")
        self.keep = keep

    def encode(self, state, update, key):
        return baselines.magnitude_prune(update, self.keep), state, None

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        return per_unit * min(2.0 * self.keep, 1.0)

    def _spec(self):
        return f"prune:{self.keep:g}"


class DropoutAvg(UpdateCodec):
    """FedDropoutAvg: random entry dropout at rate fdr, rescaled."""

    name = "dropout"

    def __init__(self, rate: float = 0.5):
        rate = float(rate)
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"dropout rate must be in [0, 1), got {rate}")
        self.rate = rate

    def encode(self, state, update, key):
        return baselines.dropout_avg(update, key, self.rate), state, None

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        return per_unit * (1.0 - self.rate)

    def _spec(self):
        return f"dropout:{self.rate:g}"


class LBGM(UpdateCodec):
    """Look-Back Gradient Multiplier as a stateful codec.

    The anchor (last fully-transmitted update) lives in codec state;
    per-unit, a sufficiently collinear fresh update ships only the
    scalar projection coefficient.  aux is the sent-full mask; a
    suppressed unit prices at 4 bytes.  aux=None (dispatch-time nominal,
    straggler charges) conservatively prices every unit full."""

    name = "lbgm"
    stateful = True
    requires_sync = True            # the anchor is defined relative to a
                                    # synchronous server view; see the
                                    # fedbuff engine's rejection message

    def __init__(self, threshold: float = 0.95):
        threshold = float(threshold)
        if not 0.0 < threshold <= 1.0:
            raise ValueError(f"lbgm threshold must be in (0, 1], got {threshold}")
        self.threshold = threshold

    def init_state(self, params, um):
        self._um = um
        return baselines.lbgm_init(params, um)

    def encode(self, state, update, key):
        um = _require_um(self)
        applied, state, sent = baselines.lbgm_round(state, um, update,
                                                    self.threshold)
        return applied, state, sent

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        if aux is None:
            return per_unit
        sent = np.asarray(aux, bool)
        up = ~np.asarray(mask, bool)
        # capped at the upstream price: a unit already compressed below
        # 4 bytes ships verbatim rather than paying the scalar overhead
        return np.where(up & ~sent,
                        np.minimum(_LBGM_SCALAR_BYTES, per_unit), per_unit)

    def _spec(self):
        return f"lbgm:{self.threshold:g}"


class TopK(UpdateCodec):
    """Global top-k sparsification over the WHOLE update tree.

    Unlike per-tensor ``prune``, entries compete across layers, so a
    layer whose update is globally negligible ships (almost) nothing.
    aux = exact per-unit survivor counts; pricing is value + index bytes
    per survivor (capped at the dense upstream price — past keep ~ 1/2
    of an f32 stream, shipping dense is cheaper than coordinates).
    """

    name = "topk"

    def __init__(self, fraction: float = 0.1):
        fraction = float(fraction)
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"topk fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction

    def init_state(self, params, um):
        self._um = um
        return None

    def encode(self, state, update, key):
        um = _require_um(self)
        leaves, treedef = jax.tree.flatten(update)
        flat = jnp.concatenate([jnp.abs(x).reshape(-1).astype(jnp.float32)
                                for x in leaves])
        n = flat.shape[0]
        k = max(1, int(round(self.fraction * n)))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        kept = [jnp.abs(x) >= thresh for x in leaves]
        out = [jnp.where(m, x, jnp.zeros_like(x)) for m, x in zip(kept, leaves)]
        # exact survivors per layer unit (ties at the threshold included;
        # exact zeros never ship — when the k-th magnitude is 0 the >=
        # mask is vacuously true on zero entries, which a sparse encoding
        # does not serialize, so they must not be counted or priced)
        shipped = [m & (x != 0) for m, x in zip(kept, leaves)]
        acc = [jnp.zeros((), jnp.int32) for _ in um.names]
        for u, m in zip(um.leaf_unit, shipped):
            if isinstance(u, tuple):
                start, depth = u
                per_depth = jnp.sum(m.reshape(depth, -1), axis=1,
                                    dtype=jnp.int32)
                for i in range(depth):
                    acc[start + i] = acc[start + i] + per_depth[i]
            else:
                acc[u] = acc[u] + jnp.sum(m, dtype=jnp.int32)
        return jax.tree.unflatten(treedef, out), state, jnp.stack(acc)

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        n_entries = np.maximum(np.asarray(sizes, np.float64) / _F32_BYTES, 1.0)
        if aux is None:
            survivors = self.fraction * n_entries       # nominal expectation
        else:
            survivors = np.asarray(aux, np.float64)
        # upstream-compressed value bytes scale with the kept fraction;
        # coordinates are uncompressed int32 regardless of upstream stages
        sparse = per_unit * (survivors / n_entries) + survivors * _INDEX_BYTES
        up = ~np.asarray(mask, bool)
        return np.where(up, np.minimum(sparse, per_unit), 0.0)

    def _spec(self):
        return f"topk:{self.fraction:g}"


class ErrorFeedback(UpdateCodec):
    """EF21-style error feedback around the lossy stages.

    Per client, the residual e_t accumulates what the pipeline's lossy
    stages destroyed: the stage injects u_t + e_t, and after the full
    pipeline produces the transmitted value w_t the commit hook sets
    e_{t+1} = (u_t + e_t) - w_t.  Telescoping: the sum of transmitted
    updates equals the sum of raw updates minus the final residual, so
    compression error cannot accumulate as bias.  Adds no wire bytes
    (the residual is client-local).  The pipeline hoists this stage to
    the front — compensation is only well-defined BEFORE the stages it
    compensates (see codec.py).
    """

    name = "ef"
    stateful = True
    needs_commit = True

    def init_state(self, params, um):
        return jax.tree.map(jnp.zeros_like, params)

    def encode(self, state, update, key):
        injected = jax.tree.map(lambda u, e: u + e, update, state)
        return injected, state, None

    def commit(self, state, injected, final):
        return jax.tree.map(lambda v, w: v - w, injected, final)

    def _spec(self):
        return "ef"


# ---------------------------------------------------------------------------
# Versioned downlink: the delta transport stage + its host-side pricing
# ---------------------------------------------------------------------------


def delta_step_price(sizes: np.ndarray, step_mask: np.ndarray,
                     additive: bool = True) -> np.ndarray:
    """Per-unit wire bytes of ONE delta step (server version v -> v+1).

    ``step_mask`` is the recycle set the aggregation at v actually
    applied: fresh units ship their full update bytes; recycled units
    ship only ``DELTA_STEP_UNIT_BYTES`` (mask bit + recycle coefficient —
    the applied value is prev_update, which a chain follower already
    holds).  ``additive=False`` (server optimizers whose broadcast is not
    ``x + applied``: fedopt's Adam, fedacg's look-ahead) prices every
    unit dense — the client cannot derive the recycled part, so delta
    steps degenerate to full-model bytes and the snapshot always wins.
    """
    sizes = np.asarray(sizes, np.float64)
    if not additive:
        return sizes.copy()
    return np.where(np.asarray(step_mask, bool), DELTA_STEP_UNIT_BYTES, sizes)


def snapshot_price(sizes: np.ndarray, current_mask: np.ndarray,
                   seed_cache: bool = True) -> np.ndarray:
    """Per-unit wire bytes of a versioned FULL download at the current
    version.

    Besides the parameters themselves, a snapshot that starts a delta
    chain must seed the recycled-update cache for every unit in the
    CURRENT mask (the very next delta step re-applies prev_update to
    exactly those units, and any unit recycled later is refreshed by the
    chain first) — so those units cost double.  ``seed_cache=False``
    (LUAR drop mode, where recycled units apply zeros; or no delta stage
    declared at all) is the plain model-bytes broadcast."""
    sizes = np.asarray(sizes, np.float64)
    if not seed_cache:
        return sizes.copy()
    return sizes + np.where(np.asarray(current_mask, bool), sizes, 0.0)


def versioned_download_price(sizes: np.ndarray, current_mask: np.ndarray,
                             chain: "np.ndarray | None" = None, *,
                             seed_cache: bool = True):
    """Choose the cheaper downlink per unit: the delta chain (complete,
    summed per-step prices) vs the cache-seeding full snapshot.

    Returns ``(per_unit_bytes, used_chain)`` in host float64.  ``chain``
    is the per-unit chain price (``DeltaLedger.chain_price``) or None on
    a ledger miss / first contact — then the snapshot is forced."""
    snap = snapshot_price(sizes, current_mask, seed_cache)
    if chain is not None and float(chain.sum()) < float(snap.sum()):
        return np.asarray(chain, np.float64), True
    return snap, False


class DeltaDownlink(UpdateCodec):
    """The versioned-broadcast transport stage (``down:delta``).

    ``encode`` is the identity: the chain entries are the exact addend
    trees the additive server applied, so the transport is lossless and
    the simulator's broadcast values are already the decoded form.  All
    the protocol logic is host-side pricing: the engine computes the
    chain-vs-snapshot decision (``versioned_download_price``, fed by the
    server's ``DeltaLedger``) and hands the chosen per-unit price in as
    this stage's aux (``pipeline.aux_for("delta", price)``).  aux=None —
    no version history (first contact, nominal estimates) — prices the
    plain full snapshot.  Hoisted to the pipeline front so downstream
    lossy stages (``down:fedpaq:8``) scale whichever transport won.
    """

    name = "delta"
    direction = Direction.DOWN
    down_only = True
    front = True

    def price_per_unit(self, per_unit, sizes, mask, aux=None):
        if aux is None:
            return per_unit
        return np.asarray(aux, np.float64)
