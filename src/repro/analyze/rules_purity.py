"""Traced-scope rules: jit purity and jax.random key discipline.

``jit-purity`` walks the call graph from the jit roots (see
``callgraph``) and flags host-side escapes inside traced scope: numpy
calls (the host-f64 accounting layer must never leak into a traced
body), ``.item()`` / ``.tolist()`` materialization, ``float()`` /
``np.float64()`` coercions of non-constants, and Python branching on a
root's array arguments (a tracer in an ``if`` raises at trace time at
best, silently specializes at worst).

``rng-discipline`` flags (a) numpy RNG anywhere in traced scope —
systems randomness must stay in host streams, learning randomness in
jax keys — and (b) a ``jax.random`` key consumed twice without an
intervening ``split`` / ``fold_in`` rebind, the classic correlated-
samples bug.  Key tracking is a linear scan per function: ``split`` /
``fold_in`` derive (and rebinding resets), any other call that takes
the key consumes; ``if`` arms merge by max, loop bodies are unrolled
twice so consume-without-rebind-per-iteration is caught.
"""
from __future__ import annotations

import ast

from repro.analyze.callgraph import CallGraph, FuncInfo
from repro.analyze.core import (HOST_ONLY_DIRS, Finding, Project,
                                register_rule, resolve_call_origin,
                                import_aliases)

_MATERIALIZERS = frozenset({"item", "tolist"})
_KEY_DERIVERS = frozenset({"split", "fold_in"})
# numpy namespaces whose *calls* are host-side; attribute reads like
# np.float64 as a dtype argument are fine, calling them is not
_NUMPY = ("numpy.", "numpy")


def _is_numpy_origin(origin: str | None) -> bool:
    return origin is not None and (origin == "numpy"
                                   or origin.startswith("numpy."))


def _analyzed(info: FuncInfo) -> bool:
    return info.file.parts[0] not in HOST_ONLY_DIRS


def _walk_own(fn: ast.FunctionDef):
    """Walk a function body without descending into nested def/class
    bodies — those are indexed (and checked) as their own functions."""
    stack: list[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


@register_rule(
    "jit-purity",
    help="no host escapes (np.*, .item(), float(), tracer branching) in "
         "functions reachable from jax.jit / codec encode/decode roots")
def jit_purity(project: Project) -> list[Finding]:
    graph = CallGraph(project)
    out: list[Finding] = []
    for info in graph.traced_funcs().values():
        if not _analyzed(info):
            continue
        aliases = import_aliases(info.file.tree)
        fname = info.node.name
        for node in _walk_own(info.node):
            if not isinstance(node, ast.Call):
                continue
            origin = resolve_call_origin(node, aliases)
            if _is_numpy_origin(origin):
                if origin.startswith("numpy.random"):
                    continue          # rng-discipline owns that finding
                out.append(Finding(
                    "jit-purity", info.file.rel, node.lineno, node.col_offset,
                    f"host numpy call `{origin}` inside traced "
                    f"`{fname}` (reached from {info.root_reason or 'a jit root'})"))
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MATERIALIZERS
                    and not node.args and not node.keywords):
                out.append(Finding(
                    "jit-purity", info.file.rel, node.lineno, node.col_offset,
                    f"`.{node.func.attr}()` materializes a tracer to host "
                    f"inside traced `{fname}`"))
            elif (isinstance(node.func, ast.Name) and node.func.id == "float"
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)):
                out.append(Finding(
                    "jit-purity", info.file.rel, node.lineno, node.col_offset,
                    f"`float(...)` coerces a traced value to host inside "
                    f"traced `{fname}`"))
        if info.is_root:
            out.extend(_tracer_branches(info))
    return out


def _tracer_branches(info: FuncInfo) -> list[Finding]:
    """Python `if` on a bare positional parameter of a jit-root body.

    Only the root's own parameters are checked (downstream callees get
    config objects whose static branches are legitimate), and only bare
    names — `cfg.mode == ...` is a static branch, `if mask:` on an
    array argument is not.  `is (not) None` and `isinstance` tests are
    structural and excluded.
    """
    # kwonly args are excluded: in this codebase they are static config
    # bound by functools.partial before tracing (kernel `causal=` flags,
    # `interpret=`), never tracers
    params = {a.arg for a in (info.node.args.posonlyargs
                              + info.node.args.args)
              if a.arg not in ("self", "cls")}
    out: list[Finding] = []
    for node in _walk_own(info.node):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        test = node.test
        if _is_structural_test(test):
            continue
        for name in ast.walk(test):
            if isinstance(name, ast.Name) and name.id in params \
                    and isinstance(name.ctx, ast.Load) \
                    and not _inside_structural(name, test):
                out.append(Finding(
                    "jit-purity", info.file.rel, node.lineno,
                    node.col_offset,
                    f"Python branch on parameter `{name.id}` inside "
                    f"jit root `{info.node.name}` — a tracer in `if` "
                    f"fails or silently specializes"))
                break
    return out


def _is_structural_test(test: ast.AST) -> bool:
    if isinstance(test, ast.Compare) and any(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call) and isinstance(test.func, ast.Name) \
            and test.func.id in ("isinstance", "callable", "hasattr", "len"):
        return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_structural_test(test.operand)
    return False


def _inside_structural(name: ast.Name, test: ast.AST) -> bool:
    """True when `name` only appears under a structural sub-test of a
    BoolOp (e.g. ``x is None or y``)."""
    for sub in ast.walk(test):
        if isinstance(sub, (ast.Compare, ast.Call)) \
                and _is_structural_test(sub) \
                and any(n is name for n in ast.walk(sub)):
            return True
    return False


# ---------------------------------------------------------------------------
# rng-discipline
# ---------------------------------------------------------------------------


@register_rule(
    "rng-discipline",
    help="jax.random keys never consumed twice without split/fold_in; "
         "no numpy RNG inside traced scope")
def rng_discipline(project: Project) -> list[Finding]:
    graph = CallGraph(project)
    out: list[Finding] = []
    traced = graph.traced_funcs()
    for info in traced.values():
        if not _analyzed(info):
            continue
        aliases = import_aliases(info.file.tree)
        for node in _walk_own(info.node):
            if isinstance(node, ast.Call):
                origin = resolve_call_origin(node, aliases)
                if origin and origin.startswith("numpy.random"):
                    out.append(Finding(
                        "rng-discipline", info.file.rel, node.lineno,
                        node.col_offset,
                        f"numpy RNG `{origin}` inside traced "
                        f"`{info.node.name}` — host randomness must not "
                        f"enter traced scope"))
    # key-reuse: every function in src/ (host loops split keys too)
    seen_funcs: set[int] = set()
    for info in graph.funcs.values():
        if not _analyzed(info) or id(info.node) in seen_funcs:
            continue
        seen_funcs.add(id(info.node))
        aliases = import_aliases(info.file.tree)
        out.extend(_key_reuse(info, aliases))
    return out


def _jax_random_leaf(call: ast.Call, aliases: dict[str, str]) -> str | None:
    origin = resolve_call_origin(call, aliases)
    if origin and origin.startswith("jax.random."):
        return origin.rsplit(".", 1)[1]
    return None


def _terminates(body: list[ast.stmt]) -> bool:
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def _key_reuse(info: FuncInfo, aliases: dict[str, str]) -> list[Finding]:
    out: list[Finding] = []
    # counts[name] = consumptions since the last rebind; absent = untracked
    counts: dict[str, int] = {}
    for a in info.node.args.args + info.node.args.kwonlyargs:
        if a.arg in ("key", "rng_key"):
            counts[a.arg] = 0

    def flag(name: str, node: ast.AST) -> None:
        out.append(Finding(
            "rng-discipline", info.file.rel, node.lineno, node.col_offset,
            f"key `{name}` consumed twice without an intervening "
            f"split/fold_in in `{info.node.name}` — correlated samples"))

    def consume_expr(expr: ast.AST) -> None:
        # one pass over the expression: a tracked name consumes when it
        # sits inside at least one call, each occurrence counted once
        # (innermost attribution), with two carve-outs — a subtree under
        # split/fold_in derives rather than consumes, and IfExp arms are
        # exclusive so they merge by max
        def visit(node: ast.AST, in_call: bool) -> None:
            if isinstance(node, ast.Call):
                if _jax_random_leaf(node, aliases) in _KEY_DERIVERS:
                    return
                for child in ast.iter_child_nodes(node):
                    visit(child, True)
                return
            if isinstance(node, ast.IfExp):
                visit(node.test, in_call)
                snap = dict(counts)
                visit(node.body, in_call)
                after = dict(counts)
                counts.clear()
                counts.update(snap)
                visit(node.orelse, in_call)
                for name in set(after) & set(counts):
                    counts[name] = max(counts[name], after[name])
                return
            if isinstance(node, ast.Name) and in_call and node.id in counts:
                counts[node.id] += 1
                if counts[node.id] == 2:
                    flag(node.id, node)
                return
            for child in ast.iter_child_nodes(node):
                visit(child, in_call)

        visit(expr, False)

    def is_key_rhs(value: ast.AST) -> bool:
        if isinstance(value, ast.Call):
            leaf = _jax_random_leaf(value, aliases)
            if leaf in ("PRNGKey", "key", "split", "fold_in"):
                return True
        if isinstance(value, ast.Attribute) and value.attr in ("key",
                                                               "down_key"):
            return True
        return False

    def rebind(target: ast.AST, value: ast.AST) -> None:
        if isinstance(target, ast.Name):
            if is_key_rhs(value):
                counts[target.id] = 0
            elif target.id in counts:
                del counts[target.id]   # rebound to a non-key: untrack
        elif isinstance(target, (ast.Tuple, ast.List)):
            # key, sub = jax.random.split(key) — every target is a key
            if isinstance(value, ast.Call) \
                    and _jax_random_leaf(value, aliases) == "split":
                for t in target.elts:
                    if isinstance(t, ast.Name):
                        counts[t.id] = 0
            else:
                for t in target.elts:
                    if isinstance(t, ast.Name) and t.id in counts:
                        del counts[t.id]

    def run(body: list[ast.stmt]) -> None:
        for stmt in body:
            if isinstance(stmt, ast.Assign):
                consume_expr(stmt.value)
                for t in stmt.targets:
                    rebind(t, stmt.value)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                consume_expr(stmt.value)
                rebind(stmt.target, stmt.value)
            elif isinstance(stmt, ast.AugAssign):
                consume_expr(stmt.value)
            elif isinstance(stmt, (ast.Expr, ast.Return)):
                if getattr(stmt, "value", None) is not None:
                    consume_expr(stmt.value)
            elif isinstance(stmt, ast.If):
                consume_expr(stmt.test)
                snap = dict(counts)
                run(stmt.body)
                body_state, body_term = dict(counts), _terminates(stmt.body)
                counts.clear()
                counts.update(snap)
                run(stmt.orelse)
                orelse_term = bool(stmt.orelse) and _terminates(stmt.orelse)
                # merge only paths that fall through: a branch ending in
                # return/raise never reaches the code below, so a chain
                # of `if kind == ...: return use(key)` is one consumer
                states = []
                if not body_term:
                    states.append(body_state)
                if not orelse_term:
                    states.append(dict(counts))
                if not states:
                    states = [snap]       # both arms terminate
                merged = {}
                for name in set.intersection(*(set(s) for s in states)):
                    merged[name] = max(s[name] for s in states)
                counts.clear()
                counts.update(merged)
            elif isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    consume_expr(stmt.iter)
                    rebind(stmt.target, stmt.iter)
                else:
                    consume_expr(stmt.test)
                # unroll twice: consuming an outer key once per iteration
                # without rebinding is a reuse across iterations
                run(stmt.body)
                run(stmt.body)
                run(stmt.orelse)
            elif isinstance(stmt, ast.With):
                run(stmt.body)
            elif isinstance(stmt, ast.Try):
                run(stmt.body)
                for h in stmt.handlers:
                    run(h.body)
                run(stmt.orelse)
                run(stmt.finalbody)
            # nested defs get their own scan via the outer loop

    run(info.node.body)
    # deduplicate repeat flags of the same (name, line)
    seen: set[tuple[int, int, str]] = set()
    uniq = []
    for f in out:
        k = (f.line, f.col, f.message)
        if k not in seen:
            seen.add(k)
            uniq.append(f)
    return uniq
