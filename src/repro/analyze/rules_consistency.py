"""Cross-subsystem consistency rules.

``metric-consistency`` — every ``fl_*`` metric name an engine creates
must be a constant in the ``obs`` catalogue (``M_*`` in
``obs/metrics.py``): ad-hoc literals fork the namespace and break the
result-rederivation contract.  Additionally, one family name must keep
one instrument kind repo-wide (a counter in one engine and a gauge in
another shards the family), and explicit ``.labels(...)`` call sites of
the same family must agree on label names.

``spec-consistency`` — every codec / participation spec string literal
(``codecs=("fedpaq:4", ...)``, ``participation="powd:10"``, argparse
defaults for ``--codecs`` / ``--participation``) must parse under the
REAL registries.  This is the one rule that imports repo code: the
registries are the single source of truth for the grammar, and
re-implementing their parsers here would guarantee drift.
"""
from __future__ import annotations

import ast

from repro.analyze.core import (Finding, Project, SourceFile,
                                import_aliases, register_rule)

_INSTRUMENT_KINDS = ("counter", "gauge", "histogram")


def _catalogue(project: Project) -> dict[str, str]:
    """M_* constants of obs/metrics.py: metric value -> constant name."""
    f = next((f for f in project.files
              if f.rel.endswith("obs/metrics.py")), None)
    if f is None:
        return {}
    out: dict[str, str] = {}
    for node in f.tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id.startswith("M_")
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            out[node.value.value] = node.targets[0].id
    return out


def _metric_name(arg: ast.AST, aliases: dict[str, str],
                 consts: dict[str, str]) -> str | None:
    """Resolve the name argument of an instrument call to its string."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value
    if isinstance(arg, ast.Name):
        origin = aliases.get(arg.id, "")
        leaf = origin.rsplit(".", 1)[-1] if origin else arg.id
        for value, const in consts.items():
            if const == leaf:
                return value
    return None


@register_rule(
    "metric-consistency",
    help="fl_* metric names exist in the obs catalogue, keep one "
         "instrument kind, and agree on label names across call sites")
def metric_consistency(project: Project) -> list[Finding]:
    consts = _catalogue(project)
    if not consts:
        return []
    out: list[Finding] = []
    kinds: dict[str, tuple[str, str, int]] = {}     # name -> kind, file, line
    labels: dict[str, tuple[frozenset, str, int]] = {}
    # attr name -> metric name for `self.X = m.counter(NAME, ...)` sites,
    # so later `<recv>.X.labels(...)` calls attribute their label set
    attr_names: dict[str, str] = {}
    files = list(project.iter_files(
        lambda f: f.parts[0] != "tests"
        and not f.rel.endswith("obs/metrics.py")))

    def instrument_name(call: ast.AST, aliases) -> str | None:
        if (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Attribute)
                and call.func.attr in _INSTRUMENT_KINDS and call.args):
            return _metric_name(call.args[0], aliases, consts)
        return None

    for f in files:
        aliases = import_aliases(f.tree)
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                name = instrument_name(node.value, aliases)
                # unwrap `var = m.counter(...).labels()`
                if name is None and isinstance(node.value, ast.Call) \
                        and isinstance(node.value.func, ast.Attribute) \
                        and node.value.func.attr == "labels":
                    name = instrument_name(node.value.func.value, aliases)
                if name is not None and isinstance(node.targets[0],
                                                   ast.Attribute):
                    attr_names[node.targets[0].attr] = name
            if not isinstance(node, ast.Call):
                continue
            name = instrument_name(node, aliases)
            if name is not None:
                if name.startswith("fl_") and name not in consts:
                    out.append(Finding(
                        "metric-consistency", f.rel, node.lineno,
                        node.col_offset,
                        f"metric `{name}` is not in the obs catalogue "
                        f"(obs/metrics.py M_*) — ad-hoc fl_* names fork "
                        f"the namespace"))
                kind = node.func.attr
                prev = kinds.get(name)
                if prev is not None and prev[0] != kind:
                    out.append(Finding(
                        "metric-consistency", f.rel, node.lineno,
                        node.col_offset,
                        f"metric `{name}` created as {kind} here but as "
                        f"{prev[0]} at {prev[1]}:{prev[2]} — one family, "
                        f"one kind"))
                else:
                    kinds.setdefault(name, (kind, f.rel, node.lineno))

    # second pass: explicit .labels(...) sites, now that every bound
    # instrument attr is known
    for f in files:
        aliases = import_aliases(f.tree)
        local_bound: dict[str, str] = {}
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = instrument_name(node.value, aliases)
                if name is not None:
                    local_bound[node.targets[0].id] = name
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "labels"):
                continue
            recv = node.func.value
            name = instrument_name(recv, aliases)
            if name is None and isinstance(recv, ast.Name):
                name = local_bound.get(recv.id)
            if name is None and isinstance(recv, ast.Attribute):
                name = attr_names.get(recv.attr)
            if name is None:
                continue
            lset = frozenset(kw.arg for kw in node.keywords if kw.arg)
            prev = labels.get(name)
            if prev is not None and prev[0] != lset:
                out.append(Finding(
                    "metric-consistency", f.rel, node.lineno,
                    node.col_offset,
                    f"metric `{name}` labeled {sorted(lset)} here but "
                    f"{sorted(prev[0])} at {prev[1]}:{prev[2]} — label "
                    f"sets must agree"))
            else:
                labels.setdefault(name, (lset, f.rel, node.lineno))
    return out


# spec-literal collection ---------------------------------------------------

_SPEC_KWARGS = ("codecs", "participation")
_SPEC_FLAGS = ("--codecs", "--participation")


def _spec_strings(node: ast.AST) -> list[str] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, str)):
                return None
            vals.append(e.value)
        return vals
    return None


def _validate_codecs(specs: list[str]) -> str | None:
    from repro.compress import registry as creg
    try:
        # '+'-join replays the registry's own string normalization, so
        # both the tuple form and the CLI '+'-joined form validate the
        # way FLConfig would resolve them
        up, down = creg.partition_codec_specs("+".join(specs))
        for spec in up + down:
            creg.parse_codec(spec)
    except Exception as e:                      # noqa: BLE001 — message IS the finding
        return str(e)
    return None


def _validate_participation(spec: str) -> str | None:
    from repro.participate import registry as preg
    try:
        preg.parse_policy(spec)
    except Exception as e:                      # noqa: BLE001
        return str(e)
    return None


@register_rule(
    "spec-consistency",
    help="codec/participation spec string literals in configs, examples, "
         "benchmarks, and tests parse under the real registries")
def spec_consistency(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for f in project.files:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg in _SPEC_KWARGS:
                    out.extend(_check_literal(f, kw.arg, kw.value))
            # argparse defaults: add_argument("--codecs", default="...")
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "add_argument" and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value in _SPEC_FLAGS):
                flag = node.args[0].value.lstrip("-")
                for kw in node.keywords:
                    if kw.arg == "default":
                        out.extend(_check_literal(f, flag, kw.value))
    return out


def _check_literal(f: SourceFile, kind: str, value: ast.AST) -> list[Finding]:
    specs = _spec_strings(value)
    if specs is None:
        return []
    if kind == "codecs":
        err = _validate_codecs(specs)
    else:
        err = None
        for s in specs:
            err = _validate_participation(s)
            if err:
                break
    if err:
        return [Finding(
            "spec-consistency", f.rel, value.lineno, value.col_offset,
            f"{kind} spec {specs!r} rejected by the registry: {err}")]
    return []
