import sys

from repro.analyze.cli import main

if __name__ == "__main__":
    sys.exit(main())
