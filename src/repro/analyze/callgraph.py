"""Call-graph construction for the traced-purity rules.

The graph is deliberately conservative in what it *resolves* (bare
names through module scope and ``from``-imports, ``mod.f`` through
module aliases, ``self.m`` within a class) and conservative in what it
*roots*: a function is a jit root when it is

  * decorated with ``jax.jit`` (including ``partial(jax.jit, ...)``),
  * the direct argument of a ``jax.jit(...)`` call (through
    ``functools.partial`` wrappers),
  * the direct argument of a ``shard_map(...)`` call (the fleet wave
    kernels: the body is traced per shard exactly like a jit arg), or
  * a traced codec surface — an ``encode`` / ``decode`` / ``commit``
    method of a class under ``compress/`` (the ``UpdateCodec``
    protocol's contract is that those three run under trace).

Everything reachable from a root through resolved edges is "traced
scope" for the purity and RNG rules.  Unresolvable receivers are left
out of the graph rather than over-approximated — a static checker that
cries wolf gets deleted from CI.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analyze.core import (Project, SourceFile, dotted_name,
                                import_aliases)

# decorators that mark host-only helpers: results are computed once at
# trace time and cached, so host calls inside are deliberate
_HOST_CACHE_DECOS = frozenset({
    "functools.lru_cache", "lru_cache", "functools.cache", "cache"})

_CODEC_TRACED_METHODS = frozenset({"encode", "decode", "commit"})


@dataclass
class FuncInfo:
    """One function/method definition in the project."""

    qualname: str                 # "module:Class.method" or "module:func"
    module: str
    cls: str | None
    node: ast.FunctionDef
    file: SourceFile
    is_root: bool = False
    root_reason: str = ""
    host_cached: bool = False     # behind lru_cache: host by design
    calls: set[str] = field(default_factory=set)   # resolved callee qualnames


def _deco_origin(deco: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted origin of a decorator, unwrapping ``partial(...)`` and
    plain calls (``@jax.jit`` and ``@partial(jax.jit, ...)`` both
    resolve to ``jax.jit``)."""
    if isinstance(deco, ast.Call):
        origin = _deco_origin(deco.func, aliases)
        if origin in ("functools.partial", "partial") and deco.args:
            return _deco_origin(deco.args[0], aliases)
        return origin
    name = dotted_name(deco)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = aliases.get(head, head)
    return f"{origin}.{rest}" if rest else origin


# transform wrappers whose argument is traced whenever the wrapper is:
# jax.jit(jax.vmap(f)) traces f, so rooting must see through them
_TRACED_WRAPPERS = frozenset({
    "jax.vmap", "vmap", "jax.grad", "grad",
    "jax.value_and_grad", "value_and_grad"})


def _unwrap_partial(node: ast.AST, aliases: dict[str, str]) -> ast.AST:
    """``partial(f, ...)`` / ``vmap(f)`` / ``grad(f)`` -> ``f``
    (recursively)."""
    while isinstance(node, ast.Call):
        origin = _deco_origin(node.func, aliases)
        if (origin in ("functools.partial", "partial")
                or origin in _TRACED_WRAPPERS) and node.args:
            node = node.args[0]
        else:
            break
    return node


class CallGraph:
    def __init__(self, project: Project):
        self.project = project
        self.funcs: dict[str, FuncInfo] = {}
        # module -> {local name -> dotted origin}
        self._aliases: dict[str, dict[str, str]] = {}
        # module -> {top-level def/class names}
        self._module_defs: dict[str, set[str]] = {}
        # module -> {local var -> partial-unwrapped target node}
        self._local_partials: dict[str, dict[str, ast.AST]] = {}
        for f in project.files:
            self._index_file(f)
        for info in list(self.funcs.values()):
            self._collect_edges(info)
        self._mark_roots()

    # -- indexing -----------------------------------------------------------

    def _index_file(self, f: SourceFile) -> None:
        aliases = import_aliases(f.tree)
        self._aliases[f.module] = aliases
        defs: set[str] = set()
        self._module_defs[f.module] = defs

        def visit(body, cls: str | None):
            for node in body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if cls is None:
                        defs.add(node.name)
                    qual = (f"{f.module}:{cls}.{node.name}" if cls
                            else f"{f.module}:{node.name}")
                    decos = [_deco_origin(d, aliases)
                             for d in node.decorator_list]
                    info = FuncInfo(
                        qualname=qual, module=f.module, cls=cls,
                        node=node, file=f,
                        host_cached=any(d in _HOST_CACHE_DECOS
                                        for d in decos))
                    if "jax.jit" in decos:
                        info.is_root = True
                        info.root_reason = "@jax.jit"
                    self.funcs[qual] = info
                    # nested defs (make_round_step's inner round_step)
                    visit(node.body, cls)
                elif isinstance(node, ast.ClassDef):
                    defs.add(node.name)
                    visit(node.body, node.name)
                elif isinstance(node, (ast.If, ast.For, ast.While)):
                    # defs guarded by config flags (make_round_step's
                    # per-mode round bodies) still need indexing
                    visit(node.body, cls)
                    visit(node.orelse, cls)
                elif isinstance(node, ast.With):
                    visit(node.body, cls)
                elif isinstance(node, ast.Try):
                    visit(node.body, cls)
                    for h in node.handlers:
                        visit(h.body, cls)
                    visit(node.orelse, cls)
                    visit(node.finalbody, cls)

        visit(f.tree.body, None)
        # local partial bindings: `fn = partial(mod.f, ...)` — lets the
        # jax.jit(fn) / pallas_call(fn) call forms root the real target
        self._local_partials.setdefault(f.module, {})
        for node in ast.walk(f.tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                target = _unwrap_partial(node.value, aliases)
                if isinstance(target, (ast.Name, ast.Attribute)) \
                        and target is not node.value:
                    self._local_partials[f.module][node.targets[0].id] = target

    # -- edges --------------------------------------------------------------

    def _resolve_name(self, module: str, name: str,
                      cls: str | None) -> str | None:
        """A bare-name reference inside ``module`` -> qualname, through
        local defs, ``from``-imports, and package ``__init__``
        re-exports (``from repro.core import luar_round`` resolves to
        ``repro.core.recycle:luar_round``)."""
        if name in self._module_defs.get(module, ()):  # top-level def/sibling
            qual = f"{module}:{name}"
            if qual in self.funcs:
                return qual
        origin = self._aliases.get(module, {}).get(name)
        for _hop in range(4):                 # bounded re-export chase
            if not origin or "." not in origin:
                return None
            mod, _, leaf = origin.rpartition(".")
            qual = f"{mod}:{leaf}"
            if qual in self.funcs:
                return qual
            origin = self._aliases.get(mod, {}).get(leaf)
        return None

    def _resolve_call(self, call: ast.Call, info: FuncInfo) -> str | None:
        func = call.func
        if isinstance(func, ast.Name):
            # nested function in the same scope?
            for candidate in (f"{info.module}:{func.id}",
                              f"{info.module}:{info.cls}.{func.id}"
                              if info.cls else None):
                if candidate and candidate in self.funcs:
                    return candidate
            return self._resolve_name(info.module, func.id, info.cls)
        if isinstance(func, ast.Attribute):
            # self.m() within the same class
            if (isinstance(func.value, ast.Name) and func.value.id == "self"
                    and info.cls):
                qual = f"{info.module}:{info.cls}.{func.attr}"
                if qual in self.funcs:
                    return qual
            # mod.f() through a module alias
            base = dotted_name(func.value)
            if base:
                origin = self._aliases.get(info.module, {}).get(
                    base.partition(".")[0])
                if origin:
                    tail = base.partition(".")[2]
                    mod = f"{origin}.{tail}" if tail else origin
                    qual = f"{mod}:{func.attr}"
                    if qual in self.funcs:
                        return qual
        return None

    def _collect_edges(self, info: FuncInfo) -> None:
        for node in ast.walk(info.node):
            if isinstance(node, ast.Call):
                callee = self._resolve_call(node, info)
                if callee and callee != info.qualname:
                    info.calls.add(callee)

    # -- roots --------------------------------------------------------------

    def _mark_roots(self) -> None:
        # codec traced surfaces
        for info in self.funcs.values():
            if (info.cls and info.node.name in _CODEC_TRACED_METHODS
                    and "/compress/" in f"/{info.file.rel}"):
                info.is_root = True
                info.root_reason = info.root_reason or "codec traced surface"
        # jax.jit(f) / pallas_call(kernel) call forms, through
        # functools.partial wrappers and `fn = partial(...)` locals
        for f in self.project.files:
            aliases = self._aliases[f.module]
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                origin = _deco_origin(node.func, aliases)
                if origin == "jax.jit":
                    reason = "jax.jit(...)"
                elif origin is not None and origin.endswith("pallas_call"):
                    reason = "pallas kernel"
                elif origin is not None and origin.endswith("shard_map"):
                    # fleet wave kernels: shard_map(body, mesh=...) traces
                    # ``body`` per shard exactly like jit traces its arg
                    reason = "shard_map(...)"
                else:
                    continue
                self._root_target(f.module, node.args[0], reason)

    def _root_target(self, module: str, arg: ast.AST, reason: str) -> None:
        aliases = self._aliases.get(module, {})
        target = _unwrap_partial(arg, aliases)
        if isinstance(target, ast.Name):
            qual = self._resolve_name(module, target.id, None)
            if qual is None:
                # `fn = partial(mod.f, ...)` then jax.jit(fn)
                bound = self._local_partials.get(module, {}).get(target.id)
                if bound is not None and bound is not arg:
                    self._root_target(module, bound, reason)
                return
            self.funcs[qual].is_root = True
            self.funcs[qual].root_reason = (
                self.funcs[qual].root_reason or reason)
        elif isinstance(target, ast.Attribute):
            # jax.jit(mod.fn): resolve through the module alias
            base = dotted_name(target.value)
            if base:
                origin_mod = aliases.get(base.partition(".")[0])
                if origin_mod:
                    tail = base.partition(".")[2]
                    mod = f"{origin_mod}.{tail}" if tail else origin_mod
                    qual = f"{mod}:{target.attr}"
                    if qual in self.funcs:
                        self.funcs[qual].is_root = True
                        self.funcs[qual].root_reason = (
                            self.funcs[qual].root_reason or reason)

    # -- reachability -------------------------------------------------------

    def traced_funcs(self) -> dict[str, FuncInfo]:
        """Roots plus everything reachable from them, minus host-cached
        helpers (their bodies run once on the host by construction)."""
        seen: dict[str, FuncInfo] = {}
        stack = [q for q, i in self.funcs.items() if i.is_root]
        while stack:
            qual = stack.pop()
            if qual in seen:
                continue
            info = self.funcs[qual]
            if info.host_cached:
                continue
            seen[qual] = info
            stack.extend(info.calls)
        return seen
