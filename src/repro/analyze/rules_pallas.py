"""Pallas layout rule: static TPU-tiling sanity for every
``pl.pallas_call`` under ``kernels/``.

Checks, in decreasing order of how often they bite:

  * kernel arity — the kernel function's positional parameter count
    must equal ``num_scalar_prefetch + len(in_specs) + len(out_specs)
    + len(scratch_shapes)``; a mismatch is a guaranteed runtime error
    that interpret-mode tests on tiny shapes can still hit late;
  * index-map arity — every BlockSpec index lambda takes one argument
    per grid axis plus one per scalar-prefetch operand (the
    scalar-prefetch arg-ordering contract);
  * tile alignment — statically resolvable block dims must respect the
    (sublane, lane) = (8, 128) f32 tile (16 sublanes for bf16 outputs);
    dims of 1 are exempt (scalar accumulator blocks) and unresolvable
    dims are skipped rather than guessed;
  * VMEM footprint — a LOWER bound (unresolvable dims priced at 1,
    f32, double-buffered) on the per-step VMEM working set is compared
    to ``VMEM_BUDGET_BYTES``; only a lower bound can exceed the budget
    without false positives.

Everything is best-effort constant propagation (module constants plus
simple local assignments) — the rule never imports or traces the
kernel.
"""
from __future__ import annotations

import ast

from repro.analyze.core import (ConstEnv, Finding, Project, dotted_name,
                                import_aliases, register_rule,
                                resolve_call_origin)

_LANE = 128
_SUBLANE_F32 = 8
_SUBLANE_BF16 = 16
VMEM_BUDGET_BYTES = 16 * 1024 * 1024      # per-core VMEM on current TPUs


def _kwarg(call: ast.Call, name: str) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _spec_list(node: ast.AST | None) -> list[ast.AST] | None:
    """in/out_specs value -> list of BlockSpec-ish nodes (a bare spec
    counts as a one-element list)."""
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _is_smem_spec(spec: ast.AST) -> bool:
    if not isinstance(spec, ast.Call):
        return False
    ms = _kwarg(spec, "memory_space")
    if ms is None:
        return False
    name = dotted_name(ms) or ""
    return name.endswith("SMEM") or name.endswith("ANY")


def _block_shape(spec: ast.AST) -> ast.AST | None:
    """First positional arg of BlockSpec(...) when it is a tuple."""
    if isinstance(spec, ast.Call) and spec.args:
        shp = spec.args[0]
        if isinstance(shp, (ast.Tuple, ast.List)):
            return shp
    return None


def _index_map(spec: ast.AST) -> ast.Lambda | None:
    if isinstance(spec, ast.Call):
        for cand in list(spec.args[1:]) + [kw.value for kw in spec.keywords
                                           if kw.arg == "index_map"]:
            if isinstance(cand, ast.Lambda):
                return cand
    return None


class _CallSite:
    """One pl.pallas_call with its resolved grid spec pieces."""

    def __init__(self):
        self.kernel_name: str | None = None
        self.grid_len: int | None = None
        self.n_prefetch: int = 0
        self.in_specs: list[ast.AST] | None = None
        self.out_specs: list[ast.AST] | None = None
        self.n_out_shape: int | None = None
        self.n_scratch: int = 0
        self.out_dtypes: list[str | None] = []
        self.node: ast.Call | None = None


def _resolve_local(fn: ast.FunctionDef, name: str) -> ast.AST | None:
    """Last single-target assignment to ``name`` inside ``fn``."""
    found = None
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name):
            found = node.value
    return found


def _parse_site(call: ast.Call, fn: ast.FunctionDef,
                aliases: dict[str, str]) -> _CallSite:
    site = _CallSite()
    site.node = call
    # kernel: first positional arg, through functools.partial
    target = call.args[0] if call.args else None
    while isinstance(target, ast.Call):
        origin = resolve_call_origin(target, aliases)
        if origin in ("functools.partial", "partial") and target.args:
            target = target.args[0]
        else:
            break
    if isinstance(target, ast.Name):
        site.kernel_name = target.id

    grid_spec = _kwarg(call, "grid_spec")
    if isinstance(grid_spec, ast.Name):
        grid_spec = _resolve_local(fn, grid_spec.id)
    holder = grid_spec if isinstance(grid_spec, ast.Call) else call
    npf = _kwarg(holder, "num_scalar_prefetch")
    if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
        site.n_prefetch = npf.value
    grid = _kwarg(holder, "grid")
    if isinstance(grid, (ast.Tuple, ast.List)):
        site.grid_len = len(grid.elts)
    site.in_specs = _spec_list(_kwarg(holder, "in_specs"))
    site.out_specs = _spec_list(_kwarg(holder, "out_specs"))
    out_shape = _kwarg(call, "out_shape")
    if out_shape is not None:
        shapes = out_shape.elts if isinstance(
            out_shape, (ast.Tuple, ast.List)) else [out_shape]
        site.n_out_shape = len(shapes)
        for s in shapes:
            dt = None
            if isinstance(s, ast.Call) and len(s.args) >= 2:
                dt = dotted_name(s.args[1])
            site.out_dtypes.append(dt)
    scratch = _kwarg(call, "scratch_shapes")
    if isinstance(scratch, (ast.Tuple, ast.List)):
        site.n_scratch = len(scratch.elts)
    return site


@register_rule(
    "pallas-layout",
    help="kernel arity, index-map/scalar-prefetch ordering, (8,128) tile "
         "alignment, and a VMEM lower-bound budget for kernels/")
def pallas_layout(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for f in project.iter_files(lambda f: "kernels" in f.parts[:-1]):
        aliases = import_aliases(f.tree)
        menv = ConstEnv(f.tree)
        fn_defs = {n.name: n for n in ast.walk(f.tree)
                   if isinstance(n, ast.FunctionDef)}
        for fn in [n for n in ast.walk(f.tree)
                   if isinstance(n, ast.FunctionDef)]:
            env = menv.child(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                origin = resolve_call_origin(node, aliases)
                if origin is None or not origin.endswith("pallas_call"):
                    continue
                site = _parse_site(node, fn, aliases)
                out.extend(_check_site(site, f.rel, fn_defs, env))
    return out


def _check_site(site: _CallSite, rel: str,
                fn_defs: dict[str, ast.FunctionDef],
                env: ConstEnv) -> list[Finding]:
    out: list[Finding] = []
    node = site.node
    n_in = len(site.in_specs) if site.in_specs is not None else None
    n_out = (len(site.out_specs) if site.out_specs is not None
             else site.n_out_shape)

    # -- kernel arity -------------------------------------------------------
    kernel = fn_defs.get(site.kernel_name or "")
    if kernel is not None and n_in is not None and n_out is not None:
        expect = site.n_prefetch + n_in + n_out + site.n_scratch
        got = len(kernel.args.posonlyargs) + len(kernel.args.args)
        if got != expect:
            out.append(Finding(
                "pallas-layout", rel, kernel.lineno, kernel.col_offset,
                f"kernel `{kernel.name}` takes {got} positional refs but "
                f"pallas_call wires {expect} "
                f"({site.n_prefetch} scalar-prefetch + {n_in} in + "
                f"{n_out} out + {site.n_scratch} scratch)"))

    specs = [("in", s) for s in (site.in_specs or [])] \
        + [("out", s) for s in (site.out_specs or [])]

    # -- index-map arity (scalar-prefetch arg ordering) ---------------------
    if site.grid_len is not None:
        want = site.grid_len + site.n_prefetch
        for kind, spec in specs:
            lam = _index_map(spec)
            if lam is None:
                continue
            got = len(lam.args.args)
            if got != want:
                out.append(Finding(
                    "pallas-layout", rel, lam.lineno, lam.col_offset,
                    f"{kind}_spec index map takes {got} args; grid has "
                    f"{site.grid_len} axes + {site.n_prefetch} "
                    f"scalar-prefetch operands = {want}"))

    # -- tile alignment + VMEM lower bound ----------------------------------
    vmem_lb = 0
    for idx, (kind, spec) in enumerate(specs):
        if _is_smem_spec(spec):
            continue
        shp = _block_shape(spec)
        if shp is None:
            continue
        dims = [env.resolve(d) for d in shp.elts]
        sublane_req = _SUBLANE_F32
        if kind == "out":
            oi = idx - len(site.in_specs or [])
            if oi < len(site.out_dtypes) and site.out_dtypes[oi] \
                    and site.out_dtypes[oi].endswith("bfloat16"):
                sublane_req = _SUBLANE_BF16
        if dims:
            last = dims[-1]
            if last is not None and last != 1 and last % _LANE:
                out.append(Finding(
                    "pallas-layout", rel, shp.lineno, shp.col_offset,
                    f"{kind}_spec block lane dim {last} is not a "
                    f"multiple of {_LANE}"))
            if len(dims) >= 2:
                sub = dims[-2]
                if sub is not None and sub != 1 and sub % sublane_req:
                    out.append(Finding(
                        "pallas-layout", rel, shp.lineno, shp.col_offset,
                        f"{kind}_spec block sublane dim {sub} is not a "
                        f"multiple of {sublane_req}"))
        size = 1
        for d in dims:
            size *= d if d is not None else 1   # lower bound
        vmem_lb += size * 4 * 2                 # f32, double-buffered

    if vmem_lb > VMEM_BUDGET_BYTES and node is not None:
        out.append(Finding(
            "pallas-layout", rel, node.lineno, node.col_offset,
            f"VMEM working-set lower bound {vmem_lb / 2**20:.1f} MiB "
            f"exceeds the {VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget"))
    return out
