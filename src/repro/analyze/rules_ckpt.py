"""Checkpoint-coverage rule: the serve WAL's bitwise-recovery guarantee
dies silently the day a new piece of mutable server state misses the
snapshot/restore pair.  This rule proves, statically, that it can't:

  * every ``RoundServer`` attribute mutated outside ``__init__``
    (assignment, augmented assignment, subscript/del, or a mutating
    method call — list/dict/set/ledger/instrument/policy/RNG verbs)
    must be referenced in BOTH ``snapshot()`` and ``load_into()`` in
    ``serve/state.py``.  An attribute derived from another covered
    attribute in ``__init__`` (instrument handles built off
    ``self.telemetry``) is covered through its root;
  * every ``ServeConfig`` field must appear in ``_fingerprint`` — the
    config-drift refusal — unless listed in the operational exemptions
    below (knobs that change where the server runs, not what it
    computes);
  * the ``flatten_tree`` prefixes written by ``snapshot`` must equal
    the ``unflatten_like`` prefixes read by ``load_into``, and
    string-literal ``arrays[...]`` / ``meta[...]`` keys must be
    written-and-read symmetrically (a key written but never read is
    dead weight; read but never written is a restore-time KeyError).

Methods called on the ``server`` object inside state.py extend coverage
with the attrs they read (save side) or write (restore side) — that is
how ``uptime()`` / ``set_uptime()`` carry ``_t0`` across the WAL.
"""
from __future__ import annotations

import ast

from repro.analyze.core import Finding, Project, SourceFile, register_rule

# method names whose call mutates the receiver: containers, the version
# ledgers, metric instruments, participation policies, numpy Generators
_MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "setdefault", "update", "add", "discard",
    "record", "record_step", "import_state",
    "inc", "set", "observe",
    "select", "observe_dispatch", "observe_report",
    "integers", "choice", "shuffle", "permutation", "normal", "random",
})

# ServeConfig fields that deliberately stay out of the fingerprint:
# they relocate or re-pace the service without changing any computed
# trajectory, so a resume across them is safe by design
_FINGERPRINT_EXEMPT = frozenset({"ckpt_path", "ckpt_every", "host", "port"})

_SERVER_CLASS = "RoundServer"
_CONFIG_CLASS = "ServeConfig"


def _self_attr(node: ast.AST, owner: str = "self") -> str | None:
    """``self.X`` / ``server.X`` (possibly deeper chains) -> ``X``."""
    while isinstance(node, ast.Attribute) and isinstance(node.value,
                                                         ast.Attribute):
        node = node.value
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == owner:
        return node.attr
    return None


class _MethodSummary:
    def __init__(self):
        self.reads: set[str] = set()
        self.writes: set[str] = set()


def _summarize_method(fn: ast.FunctionDef) -> _MethodSummary:
    s = _MethodSummary()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            attr = _self_attr(node)
            if attr is None:
                continue
            if isinstance(node.ctx, (ast.Store, ast.Del)):
                s.writes.add(attr)
            else:
                s.reads.add(attr)
    return s


def _mutated_attrs(cls: ast.ClassDef) -> tuple[dict[str, int],
                                               dict[str, set[str]],
                                               dict[str, _MethodSummary]]:
    """-> (attr -> first mutation line outside __init__,
           attr -> derivation roots from __init__,
           method name -> read/write summary)."""
    mutated: dict[str, int] = {}
    roots: dict[str, set[str]] = {}
    methods: dict[str, _MethodSummary] = {}
    for item in cls.body:
        if not isinstance(item, ast.FunctionDef):
            continue
        methods[item.name] = _summarize_method(item)
        if item.name == "__init__":
            _derivation_roots(item, roots)
            continue
        for node in ast.walk(item):
            line = getattr(node, "lineno", item.lineno)
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target] if isinstance(node, ast.AugAssign)
                           else node.targets)
                for t in targets:
                    base = t
                    while isinstance(base, ast.Subscript):
                        base = base.value
                    attr = _self_attr(base)
                    if attr:
                        mutated.setdefault(attr, line)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATORS:
                attr = _self_attr(node.func.value)
                if attr:
                    mutated.setdefault(attr, line)
    return mutated, roots, methods


def _derivation_roots(init: ast.FunctionDef,
                      roots: dict[str, set[str]]) -> None:
    """self.X = <expr over self.Y / aliases of self.Y> -> X derives Y."""
    local_roots: dict[str, set[str]] = {}

    def expr_roots(value: ast.AST) -> set[str]:
        found: set[str] = set()
        for node in ast.walk(value):
            attr = _self_attr(node) if isinstance(node, ast.Attribute) else None
            if attr:
                found.add(attr)
            elif isinstance(node, ast.Name) and node.id in local_roots:
                found |= local_roots[node.id]
        return found

    for stmt in init.body:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        target = stmt.targets[0]
        rts = expr_roots(stmt.value)
        if isinstance(target, ast.Name) and rts:
            local_roots[target.id] = rts
        else:
            attr = _self_attr(target)
            if attr and rts:
                roots[attr] = rts - {attr}


def _server_accesses(fn: ast.FunctionDef, param: str) -> tuple[set[str],
                                                               set[str]]:
    """-> (attrs referenced on ``param``, methods called on ``param``)."""
    attrs: set[str] = set()
    called: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            m = _self_attr(node.func, owner=param)
            if m and isinstance(node.func.value, ast.Name):
                called.add(m)
        if isinstance(node, ast.Attribute):
            attr = _self_attr(node, owner=param)
            if attr:
                attrs.add(attr)
    return attrs, called


def _literal_keys(fn: ast.FunctionDef, var: str) -> set[str]:
    """String-literal keys of ``var[...]`` subscripts, ``var.get(...)``
    calls, and (for dict literals assigned to ``var``) the dict keys."""
    keys: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == var \
                and isinstance(node.slice, ast.Constant) \
                and isinstance(node.slice.value, str):
            keys.add(node.slice.value)
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "get" \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == var \
                and node.args and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            keys.add(node.args[0].value)
        elif isinstance(node, ast.Assign) \
                and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == var \
                and isinstance(node.value, ast.Dict):
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
    return keys


def _flatten_prefixes(fn: ast.FunctionDef, callee: str) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr == callee:
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                        and arg.value.endswith("/"):
                    out.add(arg.value)
    return out


def _find(project: Project, suffix: str) -> SourceFile | None:
    for f in project.files:
        if f.rel.endswith(suffix):
            return f
    return None


def _top_fn(tree: ast.Module, name: str) -> ast.FunctionDef | None:
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


@register_rule(
    "ckpt-coverage",
    help="every mutable RoundServer attr round-trips through "
         "snapshot/load_into; every ServeConfig field is fingerprinted "
         "or exempt; array/meta keys and tree prefixes are symmetric")
def ckpt_coverage(project: Project) -> list[Finding]:
    core_f = _find(project, "serve/core.py")
    state_f = _find(project, "serve/state.py")
    if core_f is None or state_f is None:
        return []
    out: list[Finding] = []

    cls = next((n for n in core_f.tree.body if isinstance(n, ast.ClassDef)
                and n.name == _SERVER_CLASS), None)
    snap = _top_fn(state_f.tree, "snapshot")
    load = _top_fn(state_f.tree, "load_into")

    if cls is not None and snap is not None and load is not None:
        mutated, roots, methods = _mutated_attrs(cls)
        # transitive closure of local state.py helpers called with server
        save_attrs, save_calls = _closure(state_f.tree, snap)
        load_attrs, load_calls = _closure(state_f.tree, load)
        for m in save_calls:
            if m in methods:
                save_attrs |= methods[m].reads
        for m in load_calls:
            if m in methods:
                load_attrs |= methods[m].writes | methods[m].reads
        for attr, line in sorted(mutated.items()):
            cov_roots = {attr} | roots.get(attr, set())
            if not cov_roots & save_attrs:
                out.append(Finding(
                    "ckpt-coverage", core_f.rel, line, 0,
                    f"mutable server attr `{attr}` is never saved by "
                    f"snapshot() — WAL recovery silently drops it"))
            if not cov_roots & load_attrs:
                out.append(Finding(
                    "ckpt-coverage", core_f.rel, line, 0,
                    f"mutable server attr `{attr}` is never restored by "
                    f"load_into() — WAL recovery silently drops it"))

    out.extend(_config_fingerprint(state_f))
    if snap is not None and load is not None:
        out.extend(_symmetry(state_f, snap, load))
    return out


def _closure(tree: ast.Module, fn: ast.FunctionDef) -> tuple[set[str],
                                                             set[str]]:
    """Server-attr accesses + server-method calls of ``fn``, plus those
    of local helpers it calls with the server argument."""
    param = fn.args.args[0].arg if fn.args.args else "server"
    attrs, called = _server_accesses(fn, param)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            passes_server = any(isinstance(a, ast.Name) and a.id == param
                                for a in node.args)
            helper = _top_fn(tree, node.func.id)
            if passes_server and helper is not None:
                hp = helper.args.args[0].arg if helper.args.args else "server"
                a2, c2 = _server_accesses(helper, hp)
                attrs |= a2
                called |= c2
    return attrs, called


def _config_fingerprint(state_f: SourceFile) -> list[Finding]:
    cls = next((n for n in state_f.tree.body if isinstance(n, ast.ClassDef)
                and n.name == _CONFIG_CLASS), None)
    fp = _top_fn(state_f.tree, "_fingerprint")
    if cls is None or fp is None:
        return []
    fields = [(n.target.id, n.lineno) for n in cls.body
              if isinstance(n, ast.AnnAssign) and isinstance(n.target,
                                                             ast.Name)]
    used: set[str] = set()
    for node in ast.walk(fp):
        if isinstance(node, ast.Attribute):
            used.add(node.attr)
    out = []
    for name, line in fields:
        if name not in used and name not in _FINGERPRINT_EXEMPT:
            out.append(Finding(
                "ckpt-coverage", state_f.rel, line, 0,
                f"ServeConfig field `{name}` is not part of _fingerprint — "
                f"a resume under a different {name} silently diverges "
                f"instead of being refused"))
    return out


def _symmetry(state_f: SourceFile, snap: ast.FunctionDef,
              load: ast.FunctionDef) -> list[Finding]:
    out = []
    for kind, saver, loader in (
            ("flatten prefix", _flatten_prefixes(snap, "flatten_tree"),
             _flatten_prefixes(load, "unflatten_like")),
            ("arrays key", _literal_keys(snap, "arrays"),
             _literal_keys(load, "arrays")),
            ("meta key", _literal_keys(snap, "meta"),
             _literal_keys(load, "meta"))):
        for key in sorted(saver - loader):
            out.append(Finding(
                "ckpt-coverage", state_f.rel, snap.lineno, 0,
                f"{kind} `{key}` is written by snapshot() but never read "
                f"by load_into()"))
        for key in sorted(loader - saver):
            out.append(Finding(
                "ckpt-coverage", state_f.rel, load.lineno, 0,
                f"{kind} `{key}` is read by load_into() but never written "
                f"by snapshot()"))
    return out
