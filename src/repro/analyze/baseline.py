"""Grandfathered findings.

The baseline file is a committed JSON document; every entry carries a
mandatory human-written ``reason`` so an exception is an *explained*
exception.  The loader enforces that end to end: an entry with an empty
reason OR the ``--write-baseline`` TODO placeholder is rejected — a
placeholder that loads is a placeholder nobody ever replaces, which
made the mandatory-reason rule decorative (the stamped file passed the
check forever).  Stamp real reasons at write time with ``--reason``, or
edit the file before the first load.  Matching is by fingerprint (rule
+ file + message, line-independent), so baselined findings survive
unrelated edits but die with the code they describe.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.analyze.core import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analyze_baseline.json"
TODO_REASON = "TODO: justify or fix"


def load_baseline(path: str | Path) -> set[str]:
    """-> the grandfathered fingerprint set (empty for a missing file)."""
    path = Path(path)
    if not path.is_file():
        return set()
    doc = json.loads(path.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: baseline version {doc.get('version')!r} "
                         f"!= {BASELINE_VERSION}")
    fps = set()
    for entry in doc.get("entries", []):
        reason = entry.get("reason", "").strip()
        if not reason:
            raise ValueError(f"{path}: baseline entry {entry.get('fingerprint')} "
                             f"({entry.get('path')}) has no reason — every "
                             f"grandfathered finding must be justified")
        if reason.upper().startswith("TODO"):
            raise ValueError(f"{path}: baseline entry {entry.get('fingerprint')} "
                             f"({entry.get('path')}) still carries the "
                             f"placeholder reason {reason!r} — replace it "
                             f"with the actual justification (or write the "
                             f"baseline with --reason)")
        fps.add(entry["fingerprint"])
    return fps


def write_baseline(path: str | Path, findings: list[Finding],
                   note: str = "", reason: str = "") -> None:
    """``reason`` stamps every entry; empty leaves the TODO placeholder,
    which ``load_baseline`` refuses — the written file is then inert
    until a human justifies (or deletes) each entry."""
    entries = [{**f.to_json(),
                "reason": reason.strip() or TODO_REASON} for f in findings]
    doc = {"version": BASELINE_VERSION,
           "note": note or ("Grandfathered repro.analyze findings. Every "
                            "entry needs a human-written reason; delete "
                            "entries as the code they cover is fixed."),
           "entries": entries}
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")
