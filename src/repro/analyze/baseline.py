"""Grandfathered findings.

The baseline file is a committed JSON document; every entry carries a
mandatory human-written ``reason`` so an exception is an *explained*
exception — ``--write-baseline`` stamps entries with a TODO reason that
review is expected to replace.  Matching is by fingerprint (rule + file
+ message, line-independent), so baselined findings survive unrelated
edits but die with the code they describe.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.analyze.core import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analyze_baseline.json"


def load_baseline(path: str | Path) -> set[str]:
    """-> the grandfathered fingerprint set (empty for a missing file)."""
    path = Path(path)
    if not path.is_file():
        return set()
    doc = json.loads(path.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: baseline version {doc.get('version')!r} "
                         f"!= {BASELINE_VERSION}")
    fps = set()
    for entry in doc.get("entries", []):
        if not entry.get("reason", "").strip():
            raise ValueError(f"{path}: baseline entry {entry.get('fingerprint')} "
                             f"({entry.get('path')}) has no reason — every "
                             f"grandfathered finding must be justified")
        fps.add(entry["fingerprint"])
    return fps


def write_baseline(path: str | Path, findings: list[Finding],
                   note: str = "") -> None:
    entries = [{**f.to_json(),
                "reason": "TODO: justify or fix"} for f in findings]
    doc = {"version": BASELINE_VERSION,
           "note": note or ("Grandfathered repro.analyze findings. Every "
                            "entry needs a human-written reason; delete "
                            "entries as the code they cover is fixed."),
           "entries": entries}
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")
