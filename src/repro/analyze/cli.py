"""``python -m repro.analyze`` — run the invariant checker.

Exit codes: 0 clean, 1 findings, 2 usage error.  ``--format=github``
emits workflow-command annotations so the CI job anchors findings to
PR lines; ``--baseline`` grandfathers the committed exception list
(``analyze_baseline.json`` at the root is picked up automatically).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analyze import baseline as bl
from repro.analyze.core import RULES, parse_rules, run_rules


def _detect_root(start: Path) -> Path:
    """Walk up to the checkout root (the dir holding pyproject.toml)."""
    cur = start.resolve()
    for cand in (cur, *cur.parents):
        if (cand / "pyproject.toml").is_file():
            return cand
    return cur


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-analyze",
        description="static invariant checker for the repro codebase")
    ap.add_argument("--root", default=None,
                    help="project root to analyze (default: auto-detect "
                         "from the working directory)")
    ap.add_argument("--rules", default="all",
                    help="comma-separated rule names (default: all)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON of grandfathered findings "
                         f"(default: <root>/{bl.DEFAULT_BASELINE} when "
                         f"present; pass '' to disable)")
    ap.add_argument("--write-baseline", metavar="PATH",
                    help="write current findings as a new baseline and exit")
    ap.add_argument("--reason", default="",
                    help="justification stamped on every --write-baseline "
                         "entry; omitted, entries get a TODO placeholder "
                         "the loader refuses until a human replaces it")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    try:
        args = ap.parse_args(argv)
        rules = parse_rules(args.rules)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except SystemExit as e:              # argparse's own usage errors
        return 0 if e.code in (0, None) else 2

    if args.list_rules:
        for rule in rules:
            print(f"{rule.name:20s} {rule.help}")
        return 0

    root = Path(args.root) if args.root else _detect_root(Path.cwd())

    if args.write_baseline:
        findings = run_rules(root, args.rules)
        bl.write_baseline(args.write_baseline, findings, reason=args.reason)
        tag = "" if args.reason.strip() else (
            " (placeholder reasons: edit them in before the baseline "
            "will load)")
        print(f"wrote {len(findings)} entries to {args.write_baseline}{tag}")
        return 0

    if args.baseline is None:
        default = root / bl.DEFAULT_BASELINE
        baseline_path = default if default.is_file() else None
    else:
        baseline_path = Path(args.baseline) if args.baseline else None
    try:
        fps = bl.load_baseline(baseline_path) if baseline_path else set()
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    findings = run_rules(root, args.rules, baseline=fps)

    if args.format == "json":
        print(json.dumps({"root": str(root),
                          "rules": [r.name for r in rules],
                          "findings": [f.to_json() for f in findings]},
                         indent=2))
    elif args.format == "github":
        for f in findings:
            print(f.format_github())
        if findings:
            print(f"::notice::repro.analyze: {len(findings)} finding(s)")
    else:
        for f in findings:
            print(f.format())
        suffix = f" ({len(fps)} baselined)" if fps else ""
        print(f"repro.analyze: {len(findings)} finding(s) across "
              f"{len(rules)} rule(s){suffix}")
    return 1 if findings else 0


__all__ = ["main", "RULES"]
