"""repro.analyze — AST-based static invariant checker.

The hard guarantees of this repro (bitwise trajectory replay, kill-9
WAL recovery, fused-kernel equivalence) rest on coding conventions no
single test run exercises end to end.  This package checks them
statically: jit purity, jax.random key discipline, Pallas tile layout,
checkpoint coverage, and metric/spec-registry consistency.

Use ``run_rules(root)`` programmatically (the fleet-scale refactor's
tests assert invariants through it), ``python -m repro.analyze`` or the
``repro-analyze`` console script from a shell/CI.
"""
from repro.analyze.baseline import load_baseline, write_baseline
from repro.analyze.cli import main
from repro.analyze.core import (RULES, Finding, Project, Rule,
                                _ensure_rules_loaded, parse_rules,
                                register_rule, run_rules)

_ensure_rules_loaded()          # importing the package exposes a full RULES

__all__ = [
    "Finding", "Project", "Rule", "RULES",
    "register_rule", "parse_rules", "run_rules",
    "load_baseline", "write_baseline", "main",
]
