"""Core of ``repro.analyze`` — findings, the rule registry, and the
parsed-project model every rule consumes.

The registry mirrors the ``repro.compress`` / ``repro.participate``
spec-grammar idiom: a module-level dict populated by a ``register_rule``
decorator, a ``parse_rules`` front door that turns the CLI's comma
string into concrete rule callables, and unknown names rejected with
the catalogue in the error message.

A rule is ``fn(project) -> list[Finding]``.  Rules are pure functions
of the parsed source tree — nothing here imports the modules under
analysis (the one deliberate exception: the spec-consistency rule
validates string literals against the real codec/participation
registries, which is an import of *this* package's siblings, not of the
code being analyzed).
"""
from __future__ import annotations

import ast
import hashlib
from collections.abc import Callable, Iterable, Iterator
from dataclasses import dataclass, field
from pathlib import Path

# directories (relative to the project root) that make up the analyzed
# source set; missing ones are skipped so the analyzer also runs on the
# minimal fixture trees under tests/analyze_fixtures/
SOURCE_ROOTS = ("src", "benchmarks", "examples", "tests", "configs")

# directory names whose files are host-side by construction — purity
# and RNG rules skip them (tests/benchmarks intentionally poke host
# APIs around traced calls)
HOST_ONLY_DIRS = frozenset({"tests", "benchmarks", "examples"})


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file:line span.

    The fingerprint deliberately excludes the line number so a baseline
    entry survives unrelated edits above the finding; it tracks the
    (rule, file, message) triple instead.
    """

    rule: str
    path: str               # posix path relative to the project root
    line: int
    col: int
    message: str

    @property
    def fingerprint(self) -> str:
        raw = f"{self.rule}|{self.path}|{self.message}"
        return hashlib.sha256(raw.encode()).hexdigest()[:12]

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def format_github(self) -> str:
        # GitHub Actions annotation syntax; newlines must be %0A-escaped
        msg = f"[{self.rule}] {self.message}".replace("\n", "%0A")
        return (f"::error file={self.path},line={self.line},"
                f"col={self.col}::{msg}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "fingerprint": self.fingerprint}


@dataclass(frozen=True)
class SourceFile:
    """A parsed module: absolute path, root-relative posix path, text,
    and its AST (parents pre-linked via ``parent_of``)."""

    path: Path
    rel: str
    text: str
    tree: ast.Module

    @property
    def parts(self) -> tuple[str, ...]:
        return tuple(self.rel.split("/"))

    @property
    def module(self) -> str:
        """Dotted module name (``src/repro/x/y.py`` -> ``repro.x.y``)."""
        parts = list(self.parts)
        if parts[0] == "src":
            parts = parts[1:]
        if parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)


class Project:
    """Every parsed source file under the analyzed roots, loaded once
    and shared by all rules."""

    def __init__(self, root: Path, files: list[SourceFile]):
        self.root = root
        self.files = files
        self._by_rel = {f.rel: f for f in files}

    @classmethod
    def load(cls, root: str | Path,
             roots: Iterable[str] = SOURCE_ROOTS) -> "Project":
        root = Path(root).resolve()
        files: list[SourceFile] = []
        for sub in roots:
            base = root / sub
            if not base.is_dir():
                continue
            for path in sorted(base.rglob("*.py")):
                rel = path.relative_to(root).as_posix()
                # fixture trees hold deliberate violations; they are
                # analyzed by pointing --root at them directly (the
                # check is against the root-RELATIVE parts so a fixture
                # tree used as the root still loads its own files)
                if {"__pycache__", "analyze_fixtures"} & set(rel.split("/")):
                    continue
                text = path.read_text()
                try:
                    tree = ast.parse(text, filename=rel)
                except SyntaxError:          # not ours to flag; ruff owns it
                    continue
                files.append(SourceFile(path=path, rel=rel, text=text,
                                        tree=tree))
        return cls(root, files)

    def get(self, rel: str) -> SourceFile | None:
        return self._by_rel.get(rel)

    def iter_files(self, pred: Callable[[SourceFile], bool] | None = None
                   ) -> Iterator[SourceFile]:
        for f in self.files:
            if pred is None or pred(f):
                yield f


# ---------------------------------------------------------------------------
# rule registry (mirrors compress/participate: dict + decorator + parser)
# ---------------------------------------------------------------------------

RuleFn = Callable[[Project], "list[Finding]"]


@dataclass(frozen=True)
class Rule:
    name: str
    help: str
    fn: RuleFn = field(repr=False)


RULES: dict[str, Rule] = {}


def register_rule(name: str, help: str = "") -> Callable[[RuleFn], RuleFn]:
    """Class decorator-style registration: ``@register_rule("jit-purity",
    help=...)`` over a ``fn(project) -> list[Finding]``."""

    def deco(fn: RuleFn) -> RuleFn:
        if name in RULES:
            raise ValueError(f"duplicate rule {name!r}")
        RULES[name] = Rule(name=name, help=help, fn=fn)
        return fn

    return deco


def parse_rules(spec: str | None) -> list[Rule]:
    """``"jit-purity,pallas-layout"`` -> concrete rules; ``None`` or
    ``"all"`` selects the whole catalogue (registration order)."""
    _ensure_rules_loaded()
    if spec is None or spec.strip() in ("", "all"):
        return list(RULES.values())
    out = []
    for name in (s.strip() for s in spec.split(",")):
        if not name:
            continue
        if name not in RULES:
            known = ", ".join(sorted(RULES))
            raise ValueError(f"unknown rule {name!r}; known rules: {known}")
        out.append(RULES[name])
    return out


def _ensure_rules_loaded() -> None:
    # rule modules self-register on import; keep imports here so `core`
    # stays importable from the rule modules without a cycle
    from repro.analyze import (rules_ckpt, rules_consistency,  # noqa: F401
                               rules_pallas, rules_purity)


def run_rules(root: str | Path, rules: str | Iterable[str] | None = None,
              baseline: "set[str] | None" = None) -> list[Finding]:
    """The importable API: run the selected rules over the tree at
    ``root`` and return findings not grandfathered by ``baseline``
    (a set of fingerprints), sorted by file then line."""
    if isinstance(rules, str) or rules is None:
        selected = parse_rules(rules)
    else:
        selected = parse_rules(",".join(rules))
    project = Project.load(root)
    findings: list[Finding] = []
    for rule in selected:
        findings.extend(rule.fn(project))
    if baseline:
        findings = [f for f in findings if f.fingerprint not in baseline]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain -> ``"a.b.c"``; None when the chain
    roots in anything but a plain name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """Map local name -> dotted origin for every top-level import.

    ``import numpy as np``          -> {"np": "numpy"}
    ``from jax import random``      -> {"random": "jax.random"}
    ``from repro.obs import M_X``   -> {"M_X": "repro.obs.M_X"}
    """
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call_origin(call: ast.Call, aliases: dict[str, str]) -> str | None:
    """Dotted origin of a call through the module's import aliases:
    with ``import jax.numpy as jnp``, ``jnp.sum(...)`` resolves to
    ``jax.numpy.sum``."""
    name = dotted_name(call.func)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    origin = aliases.get(head, head)
    return f"{origin}.{rest}" if rest else origin


class ConstEnv:
    """Module-level integer constants (``_LANES = 128``) plus simple
    arithmetic over them — enough to resolve Pallas block shapes
    statically without executing anything."""

    def __init__(self, tree: ast.Module):
        self.values: dict[str, int] = {}
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                v = self.resolve(node.value)
                if v is not None:
                    self.values[node.targets[0].id] = v

    def child(self, fn: ast.FunctionDef) -> "ConstEnv":
        """Extend with simple constant assignments local to ``fn``
        (single-target, resolvable at the time of the walk)."""
        env = ConstEnv.__new__(ConstEnv)
        env.values = dict(self.values)
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                v = env.resolve(node.value)
                if v is not None:
                    env.values[node.targets[0].id] = v
        return env

    def resolve(self, node: ast.AST) -> int | None:
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name):
            return self.values.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.resolve(node.operand)
            return -v if v is not None else None
        if isinstance(node, ast.BinOp):
            lhs, rhs = self.resolve(node.left), self.resolve(node.right)
            if lhs is None or rhs is None:
                return None
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.FloorDiv) and rhs:
                return lhs // rhs
            if isinstance(node.op, ast.Mod) and rhs:
                return lhs % rhs
        return None
