"""HTTP transport for the round server (stdlib only).

Endpoints (JSON bodies unless noted):

  POST /v1/dispatch   {"client": int}
                      -> versioned broadcast (base64 npz in "params"),
                         recycle mask, downlink pricing
  POST /v1/upload     {"client": int, "version": int, "update": b64 npz}
                      -> accepted/rejected, merge outcome, buffer fill
  GET  /v1/status     -> round/version/buffer/byte-ledger summary
  GET  /metrics       -> Prometheus text 0.0.4 (``obs.prom.CONTENT_TYPE``)

Service errors map to HTTP codes via ``ServeError.status`` (503 policy
refusal, 409 unknown dispatch / version mismatch / busy, 400 malformed).
``ThreadingHTTPServer`` + the core's lock give one-mutation-at-a-time
semantics under concurrent clients.

Standalone:

  PYTHONPATH=src python -m repro.serve.http --clients 16 --port 8080 \\
      --ckpt out/serve            # kill -9 it; then add --resume
"""
from __future__ import annotations

import argparse
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs import prom
from repro.serve import wire
from repro.serve.core import RoundServer, ServeError

JSON_TYPE = "application/json"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-serve/1"
    protocol_version = "HTTP/1.1"

    @property
    def rs(self) -> RoundServer:
        return self.server.round_server

    def log_message(self, fmt, *args):     # quiet by default
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _send(self, code: int, body: bytes, ctype: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _json(self, code: int, doc) -> None:
        self._send(code, (json.dumps(doc) + "\n").encode(), JSON_TYPE)

    def do_GET(self):
        if self.path == "/v1/status":
            self._json(200, self.rs.status())
        elif self.path == "/metrics":
            self._send(200, self.rs.metrics_text().encode(),
                       prom.CONTENT_TYPE)
        else:
            self._json(404, {"error": f"unknown path {self.path!r}"})

    def do_POST(self):
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            if self.path == "/v1/dispatch":
                out = self.rs.dispatch(int(body["client"]))
                out["params"] = wire.encode_tree(out.pop("broadcast"))
                self._json(200, out)
            elif self.path == "/v1/upload":
                update = wire.decode_tree(body["update"], self.rs.params)
                out = self.rs.upload(int(body["client"]), update,
                                     body.get("version"))
                self._json(200, out)
            else:
                self._json(404, {"error": f"unknown path {self.path!r}"})
        except ServeError as e:
            self._json(e.status, {"error": str(e),
                                  "kind": type(e).__name__})
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            self._json(400, {"error": f"malformed request: {e}"})


class ServeHTTP(ThreadingHTTPServer):
    daemon_threads = True

    def __init__(self, addr, round_server: RoundServer,
                 verbose: bool = False):
        super().__init__(addr, _Handler)
        self.round_server = round_server
        self.verbose = verbose
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.server_address[0]}:{self.port}"


def start(round_server: RoundServer, host: str | None = None,
          port: int | None = None, verbose: bool = False) -> ServeHTTP:
    """Bind + serve in a daemon thread; returns the server (``.url``)."""
    sc = round_server.serve_cfg
    httpd = ServeHTTP((host if host is not None else sc.host,
                       sc.port if port is None else port),
                      round_server, verbose)
    t = threading.Thread(target=httpd.serve_forever, daemon=True,
                         name="repro-serve-http")
    httpd._thread = t
    t.start()
    return httpd


def stop(httpd: ServeHTTP, checkpoint: bool = True) -> None:
    """Clean shutdown: stop accepting, join the loop, final snapshot."""
    httpd.shutdown()
    if httpd._thread is not None:
        httpd._thread.join(timeout=30)
    httpd.server_close()
    if checkpoint:
        httpd.round_server.checkpoint()


def main(argv=None) -> int:
    import jax

    from repro.core import LuarConfig
    from repro.fl.client import ClientConfig
    from repro.fl.rounds import FLConfig
    from repro.fl.server import ServerConfig
    from repro.models.cnn import mlp_init
    from repro.serve.state import ServeConfig

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--buffer", type=int, default=4)
    ap.add_argument("--delta", type=int, default=2, help="LUAR recycle count")
    ap.add_argument("--codecs", default="down:delta",
                    help="comma-joined codec specs ('' = none)")
    ap.add_argument("--participation", default="uniform")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--ckpt", default="", help="WAL snapshot prefix")
    ap.add_argument("--resume", action="store_true",
                    help="restore state from --ckpt before serving")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    params = mlp_init(jax.random.PRNGKey(args.seed), n_features=32,
                      n_classes=10)
    cfg = FLConfig(
        n_clients=args.clients, n_active=min(8, args.clients), tau=2,
        batch_size=16, rounds=10 ** 9, seed=args.seed,
        client=ClientConfig(lr=0.05), server=ServerConfig(),
        luar=LuarConfig(delta=args.delta),
        codecs=tuple(s for s in args.codecs.split(",") if s),
        participation=args.participation)
    sc = ServeConfig(buffer_size=args.buffer, ckpt_path=args.ckpt,
                     host=args.host, port=args.port)
    if args.resume:
        rs = RoundServer.resume(params, cfg, sc)
        print(f"# resumed at version {rs.version} "
              f"({rs.mutations} mutations)")
    else:
        rs = RoundServer(params, cfg, sc)
    httpd = start(rs, verbose=args.verbose)
    print(f"# serving on {httpd.url}  (model: mlp 32->10, "
          f"{rs.n_units} units; ctrl-c for clean shutdown)")
    try:
        httpd._thread.join()
    except KeyboardInterrupt:
        stop(httpd)
        print(f"# clean shutdown at version {rs.version}"
              + (f"; snapshot -> {args.ckpt}.npz" if args.ckpt else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
