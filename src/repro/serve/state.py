"""The round service's recoverable state — snapshot/restore layout.

Everything the fedbuff aggregation loop keeps between two HTTP requests
is packed into ONE atomic checkpoint (``checkpoint.ckpt.save_arrays``):

  arrays (npz)                       meta (json)
  ------------------------------     --------------------------------
  params/*        model pytree       schema, version, mutations
  luar/*          LuarState          buffer row scalars (staleness,
  server/*        ServerState         down_bytes, ht)
  down/*          downlink codec     inflight job scalars
  codec/<cid>/*   per-client codec   last_dl map, codec client ids
  buffer/<i>/*    buffered deltas    ledger version order + evictions
  job/<cid>/*     inflight masks     np/policy RNG bit-generator state
  maskledger/<v>  dispatched masks   policy scalar attributes
  deltaledger/<v> delta step prices  metrics registry state_dict
  rng/*           jax key streams    config fingerprint
  policy/*        policy arrays
  part_count      dispatches/client

The snapshot is written AFTER every state mutation (write-ahead with
respect to the next request: a ``kill -9`` between two uploads finds
either the pre- or post-mutation state on disk, never a torn one —
``save_arrays`` replaces tmp files atomically).  Restore rebuilds every
tree against the freshly initialized server's own structures as
templates, restores both numpy bit-generator states and the metrics
registry, and refuses a snapshot whose config fingerprint (population
size, buffer size, codec specs, participation spec) does not match the
server it is being loaded into.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt

STATE_SCHEMA = 1


@dataclass(frozen=True)
class ServeConfig:
    """Service-level knobs (the learning config stays ``FLConfig``)."""
    buffer_size: int = 4         # K uploads per LUAR merge (1 = FedAsync)
    staleness_alpha: float = 0.5  # discount (1+tau)^-alpha at merge
    ledger_capacity: int = 64    # mask/delta ring size (versions)
    ckpt_path: str = ""          # WAL snapshot prefix ("" = no persistence)
    ckpt_every: int = 1          # state mutations between WAL snapshots
    host: str = "127.0.0.1"      # HTTP bind address
    port: int = 0                # 0 = ephemeral


def _fingerprint(server) -> dict[str, Any]:
    cfg = server.cfg
    sc = server.serve_cfg
    # every ServeConfig field that changes what the server computes
    # belongs here: staleness_alpha reweights every merge, and
    # ledger_capacity bounds the ring the snapshot's version lists are
    # imported into — resuming across either silently diverges
    return {"n_clients": int(cfg.n_clients), "seed": int(cfg.seed),
            "codecs": list(cfg.codecs), "participation": cfg.participation,
            "buffer_size": int(sc.buffer_size),
            "staleness_alpha": float(sc.staleness_alpha),
            "ledger_capacity": int(sc.ledger_capacity),
            "luar_delta": int(cfg.luar.delta), "luar_mode": cfg.luar.mode}


def _policy_state(policy) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Split a policy's instance attrs into (arrays, json-able scalars);
    the policy's own RNG stream rides in the scalars as bit-gen state."""
    arrays: dict[str, np.ndarray] = {}
    scalars: dict[str, Any] = {}
    for k, v in vars(policy).items():
        if isinstance(v, np.ndarray):
            arrays[k] = v
        elif isinstance(v, (bool, int, float)):
            scalars[k] = v
        elif isinstance(v, np.random.Generator):
            scalars[k + "@rng"] = v.bit_generator.state
    return arrays, scalars


def _restore_policy(policy, arrays: dict[str, np.ndarray],
                    scalars: dict[str, Any]) -> None:
    for k, v in arrays.items():
        setattr(policy, k, v.copy())
    for k, v in scalars.items():
        if k.endswith("@rng"):
            gen = np.random.default_rng()
            gen.bit_generator.state = v
            setattr(policy, k[:-4], gen)
        else:
            setattr(policy, k, v)


def snapshot(server) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    """Pack a ``RoundServer``'s full mutable state (see module doc)."""
    arrays: dict[str, np.ndarray] = {}
    arrays.update(ckpt.flatten_tree(server.params, "params/"))
    arrays.update(ckpt.flatten_tree(server.luar_state, "luar/"))
    arrays.update(ckpt.flatten_tree(server.server_state, "server/"))
    if server.down_pipe:
        arrays.update(ckpt.flatten_tree(server.down_state, "down/"))
    arrays["rng/key"] = np.asarray(server.key)
    arrays["rng/down_key"] = np.asarray(server.down_key)
    arrays["part_count"] = server.part_count

    for cid, st in server.codec_states.items():
        arrays.update(ckpt.flatten_tree(st, f"codec/{cid}/"))
    buffer_meta = []
    for i, (delta, stal, valid, per_unit, down_bytes, ht) in enumerate(
            server.buffer):
        arrays.update(ckpt.flatten_tree(delta, f"buffer/{i}/delta/"))
        arrays[f"buffer/{i}/valid"] = np.asarray(valid, bool)
        arrays[f"buffer/{i}/per_unit"] = np.asarray(per_unit, np.float64)
        buffer_meta.append({"staleness": int(stal),
                            "down_bytes": float(down_bytes),
                            "ht": float(ht)})
    jobs_meta = {}
    for cid, job in server.jobs.items():
        arrays[f"job/{cid}/mask"] = np.asarray(job["mask"], bool)
        arrays[f"job/{cid}/per_unit"] = np.asarray(job["per_unit"],
                                                   np.float64)
        jobs_meta[str(cid)] = {"version": int(job["version"]),
                               "bytes": float(job["bytes"]),
                               "down_bytes": float(job["down_bytes"]),
                               "ht": float(job["ht"])}

    mask_entries, mask_ev = server.mask_ledger.export_state()
    for v, mask in mask_entries:
        arrays[f"maskledger/{v}"] = np.asarray(mask, bool)
    ledgers: dict[str, Any] = {
        "mask": {"versions": [int(v) for v, _ in mask_entries],
                 "evictions": int(mask_ev)}}
    if server.delta_ledger is not None:
        delta_entries, delta_ev = server.delta_ledger.export_state()
        for v, (price, _tree) in delta_entries:
            arrays[f"deltaledger/{v}"] = np.asarray(price, np.float64)
        ledgers["delta"] = {"versions": [int(v) for v, _ in delta_entries],
                            "evictions": int(delta_ev)}

    pol_arrays, pol_scalars = _policy_state(server.policy)
    for k, v in pol_arrays.items():
        arrays[f"policy/{k}"] = v

    meta = {
        "schema": STATE_SCHEMA,
        "version": int(server.version),
        "mutations": int(server.mutations),
        "uptime_s": float(server.uptime()),
        "buffer": buffer_meta,
        "jobs": jobs_meta,
        "last_dl": {str(c): int(v) for c, v in server.last_dl.items()},
        "codec_clients": sorted(server.codec_states),
        "ledgers": ledgers,
        "rng_np": server.rng.bit_generator.state,
        "policy_scalars": pol_scalars,
        "policy_arrays": sorted(pol_arrays),
        "metrics": server.telemetry.metrics.state_dict(),
        "config": _fingerprint(server),
    }
    return arrays, meta


def save(server) -> str:
    path = server.serve_cfg.ckpt_path
    arrays, meta = snapshot(server)
    ckpt.save_arrays(path, arrays, meta)
    return path


def load_into(server, path: str) -> None:
    """Restore a snapshot into a freshly constructed ``RoundServer`` —
    the fresh instance's own (deterministically initialized) structures
    are the unflatten templates."""
    arrays, meta = ckpt.load_arrays(path)
    if meta.get("schema") != STATE_SCHEMA:
        raise ValueError(f"{path}: serve state schema "
                         f"{meta.get('schema')!r} != {STATE_SCHEMA}")
    want = _fingerprint(server)
    got = meta.get("config", {})
    drift = {k: (got.get(k), v) for k, v in want.items()
             if got.get(k) != v}
    if drift:
        raise ValueError(
            f"{path}: snapshot was taken by a differently configured "
            f"server — mismatched (saved, expected): {drift}")

    lbl = path
    server.params = ckpt.unflatten_like(server.params, arrays, "params/", lbl)
    server.luar_state = ckpt.unflatten_like(server.luar_state, arrays,
                                            "luar/", lbl)
    server.server_state = ckpt.unflatten_like(server.server_state, arrays,
                                              "server/", lbl)
    if server.down_pipe:
        server.down_state = ckpt.unflatten_like(server.down_state, arrays,
                                                "down/", lbl)
    server.key = jnp.asarray(arrays["rng/key"])
    server.down_key = jnp.asarray(arrays["rng/down_key"])
    server.part_count = arrays["part_count"].copy()

    server.codec_states = {}
    for cid in meta["codec_clients"]:
        template = server.fresh_codec_state()
        server.codec_states[int(cid)] = ckpt.unflatten_like(
            template, arrays, f"codec/{cid}/", lbl)

    server.buffer = []
    for i, row in enumerate(meta["buffer"]):
        delta = ckpt.unflatten_like(server.params, arrays,
                                    f"buffer/{i}/delta/", lbl)
        server.buffer.append((delta, int(row["staleness"]),
                              arrays[f"buffer/{i}/valid"].copy(),
                              arrays[f"buffer/{i}/per_unit"].copy(),
                              float(row["down_bytes"]), float(row["ht"])))

    server.jobs = {}
    for cid_s, job in meta["jobs"].items():
        cid = int(cid_s)
        server.jobs[cid] = {
            "version": int(job["version"]),
            "mask": arrays[f"job/{cid}/mask"].copy(),
            "per_unit": arrays[f"job/{cid}/per_unit"].copy(),
            "bytes": float(job["bytes"]),
            "down_bytes": float(job["down_bytes"]),
            "ht": float(job["ht"]),
        }
    server.last_dl = {int(c): int(v)
                      for c, v in meta["last_dl"].items()}

    mk = meta["ledgers"]["mask"]
    server.mask_ledger.import_state(
        [(v, arrays[f"maskledger/{v}"].copy()) for v in mk["versions"]],
        mk["evictions"])
    if server.delta_ledger is not None:
        dl = meta["ledgers"].get("delta")
        if dl is None:
            raise ValueError(f"{path}: snapshot lacks the delta ledger this "
                             "server's downlink codecs require")
        server.delta_ledger.import_state(
            [(v, (arrays[f"deltaledger/{v}"].copy(), None))
             for v in dl["versions"]], dl["evictions"])

    server.rng = np.random.default_rng()
    server.rng.bit_generator.state = meta["rng_np"]
    _restore_policy(server.policy,
                    {k: arrays[f"policy/{k}"]
                     for k in meta["policy_arrays"]},
                    meta["policy_scalars"])

    server.telemetry.metrics.load_state_dict(meta["metrics"])
    server.version = int(meta["version"])
    server.mutations = int(meta["mutations"])
    server.set_uptime(float(meta["uptime_s"]))
