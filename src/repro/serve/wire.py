"""Wire format for the round-service HTTP API.

Pytrees cross the wire as base64-encoded npz archives inside JSON
bodies: the same "/"-joined key paths the checkpoint layer uses, so a
payload is decodable against any structure template (`decode_tree`)
and the encoding is exact — raw IEEE-754 bytes, no text round-trip of
float values.  This is a TRANSPORT encoding, not the compression
accounting: byte *pricing* still runs through the codec pipelines on
the server (the npz container would otherwise make the measured sizes
codec-dependent in uninteresting ways).
"""
from __future__ import annotations

import base64
import binascii
import io
from typing import Any

import numpy as np

from repro.checkpoint.ckpt import flatten_tree, unflatten_like


def encode_arrays(arrays: dict[str, np.ndarray]) -> str:
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return base64.b64encode(buf.getvalue()).decode("ascii")


def decode_arrays(b64: str) -> dict[str, np.ndarray]:
    try:
        raw = base64.b64decode(b64.encode("ascii"), validate=True)
        with np.load(io.BytesIO(raw)) as data:
            return dict(data)
    except (binascii.Error, EOFError, OSError, UnicodeError) as e:
        raise ValueError(f"undecodable wire payload: {e}") from None


def encode_tree(tree: Any) -> str:
    """Pytree -> base64 npz string (leaf paths as archive keys)."""
    return encode_arrays(flatten_tree(tree))


def decode_tree(b64: str, like: Any) -> Any:
    """Inverse of ``encode_tree`` against a structure template; raises
    ``ValueError`` listing missing/mismatched keys on a bad payload."""
    return unflatten_like(like, decode_arrays(b64), label="wire payload")
