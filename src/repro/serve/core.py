"""The round server — the fedbuff aggregation loop as a long-lived,
transport-agnostic service object.

``RoundServer`` is the exact server side of the simulator's fedbuff
loop (same ledgers, same codec pipelines, same jitted merge via
``sim.engine.make_buffer_agg_fn``) re-cut from event-loop-local state
into an object whose every mutation can be checkpointed:

    dispatch(c)   client pulls the versioned broadcast + recycle mask;
                  downlink priced through the ``down:`` pipeline with
                  DeltaLedger chain-vs-snapshot per the client's lag
    upload(c, d)  client submits its raw update; the server runs the
                  UP codec pipeline (per-client EF state lives server-
                  side), prices the masked payload, buffers it, and
                  merges every ``buffer_size`` arrivals (LUAR recycle +
                  staleness discount + HT weights — optionally the
                  fused Pallas kernel via ``LuarConfig.fused_agg``)
    status()      JSON summary (version, buffer, byte ledgers)
    metrics_text()  Prometheus exposition of the live registry

With ``ServeConfig.ckpt_path`` set, every mutation atomically persists
the full ``ServerState`` bundle (``serve.state``): a ``kill -9``
between two requests resumes losslessly via ``RoundServer.resume`` —
bitwise-identical params, ledgers and metrics versus a never-killed
server fed the same request sequence (tested).

Thread-safe: one re-entrant lock serializes mutations (the stdlib HTTP
layer in ``serve.http`` is threaded).
"""
from __future__ import annotations

import threading
import time
from typing import Any
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import (Direction, delta_step_price,
                            versioned_download_price)
from repro.core import luar_init
from repro.fl.rounds import (FLConfig, build_codec_pipeline,
                             server_broadcast_additive)
from repro.fl.server import broadcast_point, server_init
from repro.obs import (AGGREGATE, DISPATCH, EVICT, M_ACCEPTED, M_DISPATCHES,
                       M_DOWNLOAD_BYTES, M_DOWNLOADS_DELTA, M_DOWNLOADS_FULL,
                       M_LEDGER_EVICTIONS, M_LEDGER_MISSES,
                       M_SERVER_BUFFER_FILL, M_SERVER_INFLIGHT,
                       M_SERVER_VERSION, M_UPLOAD_BYTES, RUN_START,
                       Telemetry, UPLOAD)
from repro.obs import prom
from repro.participate import HT_CLIP, RoundContext, ht_weights, resolve_policy
from repro.serve import state as serve_state
from repro.serve.state import ServeConfig
from repro.sim.engine import (DeltaLedger, MaskLedger, _Instruments,
                              make_buffer_agg_fn)

STATUS_SCHEMA = 1


class ServeError(Exception):
    """Service-level request failure; ``status`` is the HTTP code."""
    status = 400


class ClientUnavailable(ServeError):
    """The participation policy refused the dispatch (e.g. flat
    battery, availability trough)."""
    status = 503


class ClientBusy(ServeError):
    """Client already holds an unanswered dispatch."""
    status = 409


class UnknownDispatch(ServeError):
    """Upload from a client the server has no inflight dispatch for."""
    status = 409


class VersionMismatch(ServeError):
    """Upload claims a different base version than its dispatch."""
    status = 409


class RoundServer:
    """See module docstring.  ``clock`` is injectable (monotonic seconds)
    so status/trace output is byte-stable in goldens."""

    def __init__(self, init_params: Any, cfg: FLConfig,
                 serve_cfg: ServeConfig | None = None,
                 telemetry: Telemetry | None = None,
                 clock: Callable[[], float] | None = None):
        self.cfg = cfg
        self.serve_cfg = serve_cfg or ServeConfig()
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._clock = clock if clock is not None else time.monotonic
        self._t0 = self._clock()
        self._lock = threading.RLock()
        self.init_params = init_params

        pipeline = build_codec_pipeline(cfg)
        down_pipe = build_codec_pipeline(cfg, Direction.DOWN)
        sync_only = pipeline.sync_only_specs() + down_pipe.sync_only_specs()
        if sync_only:
            raise NotImplementedError(
                f"codec stage(s) {list(sync_only)} need a synchronous "
                "server view the round service never holds; drop them "
                "from FLConfig.codecs")
        self.pipeline, self.down_pipe = pipeline, down_pipe

        # -- learning state (identical init to the fedbuff engine) ------
        self.rng = np.random.default_rng(cfg.seed)
        key = jax.random.PRNGKey(cfg.seed)
        self.key, k1, k2 = jax.random.split(key, 3)
        self.params = init_params
        self.luar_state, self.um = luar_init(init_params, cfg.luar, k1)
        self.server_state = server_init(init_params, cfg.server, k2)
        self.sizes = np.asarray(self.um.unit_bytes, np.float64)
        self.n_units = len(self.um.names)
        self.no_mask = np.zeros(self.n_units, bool)

        self.policy = resolve_policy(cfg.participation, cfg.n_clients,
                                     cfg.seed, None)
        self.part_count = np.zeros(cfg.n_clients, np.int64)

        additive = server_broadcast_additive(cfg)
        self.has_delta = down_pipe.has("delta") and additive
        self.seed_cache = self.has_delta and cfg.luar.mode == "recycle"
        self.down_state = down_pipe.init_state(init_params, self.um)
        self.down_key = jax.random.PRNGKey(np.uint32(cfg.seed ^ 0xD0FF))
        self.codec_states: dict[int, tuple] = {}
        self._codec_template = pipeline.init_state(init_params, self.um)

        # -- instruments: the engine catalogue + the fl_server_* gauges;
        # everything eagerly so family/child order is construction-order
        # deterministic (the metrics snapshot restores values in place)
        self.ins = _Instruments(self.telemetry)
        m = self.telemetry.metrics
        self.g_version = m.gauge(M_SERVER_VERSION,
                                 "current model version").labels()
        self.g_buffer = m.gauge(M_SERVER_BUFFER_FILL,
                                "uploads waiting in the merge "
                                "buffer").labels()
        self.g_inflight = m.gauge(M_SERVER_INFLIGHT,
                                  "dispatched, not yet uploaded").labels()
        self._tr = self.telemetry.trace

        def _evict_hook(which: str):
            child = self.ins.evictions.labels(ledger=which)

            def hook(version: int) -> None:
                child.inc()
                if self._tr:
                    self._tr.emit(EVICT, self.uptime(), ledger=which,
                                  version=version)
            return hook

        cap = self.serve_cfg.ledger_capacity
        self.delta_ledger = (DeltaLedger(cap, on_evict=_evict_hook("delta"))
                             if self.has_delta else None)
        self.mask_ledger = MaskLedger(cap, on_evict=_evict_hook("mask"))

        # -- mutable round state ----------------------------------------
        self.version = 0
        self.mutations = 0
        self.buffer: list[tuple] = []   # (delta, staleness, validity row,
                                        #  per_unit f64, down bytes, ht)
        self.jobs: dict[int, dict] = {}    # inflight dispatches
        self.last_dl: dict[int, int] = {}  # client -> last downloaded ver

        # -- jitted bodies (shared definitions with the sim engine) -----
        fedasync = self.serve_cfg.buffer_size == 1
        self.agg_fn = make_buffer_agg_fn(cfg, self.um, fedasync)
        self.encode_fn = jax.jit(
            lambda st, delta, qkey: pipeline.encode(st, delta, qkey))
        self.down_encode_fn = jax.jit(
            lambda st, tree, k: down_pipe.encode(st, tree, k))

        if self._tr:
            self._tr.emit(RUN_START, self.uptime(), engine="serve",
                          mode="fedbuff", n_clients=cfg.n_clients,
                          buffer_size=self.serve_cfg.buffer_size,
                          n_units=self.n_units, units=list(self.um.names))

    # -- lifecycle ------------------------------------------------------

    @classmethod
    def resume(cls, init_params: Any, cfg: FLConfig, serve_cfg: ServeConfig,
               telemetry: Telemetry | None = None,
               clock: Callable[[], float] | None = None) -> "RoundServer":
        """Rebuild a server from its WAL snapshot (``serve_cfg.ckpt_path``
        must point at one written by the same-configured server)."""
        if not serve_cfg.ckpt_path:
            raise ValueError("resume needs ServeConfig.ckpt_path")
        srv = cls(init_params, cfg, serve_cfg, telemetry=telemetry,
                  clock=clock)
        serve_state.load_into(srv, serve_cfg.ckpt_path)
        return srv

    def uptime(self) -> float:
        return self._clock() - self._t0

    def set_uptime(self, uptime_s: float) -> None:
        """Resume support: continue the killed server's uptime."""
        self._t0 = self._clock() - uptime_s

    def fresh_codec_state(self) -> tuple:
        return self.pipeline.init_state(self.init_params, self.um)

    def _codec_state_for(self, c: int) -> tuple:
        if not self.pipeline.stateful:
            return self._codec_template
        if c not in self.codec_states:
            self.codec_states[c] = self.fresh_codec_state()
        return self.codec_states[c]

    def _mutated(self) -> None:
        """WAL point: one state mutation finished; persist if configured."""
        self.mutations += 1
        sc = self.serve_cfg
        if sc.ckpt_path and self.mutations % max(sc.ckpt_every, 1) == 0:
            serve_state.save(self)

    def checkpoint(self) -> str | None:
        """Force a snapshot now (clean-shutdown path)."""
        with self._lock:
            if not self.serve_cfg.ckpt_path:
                return None
            return serve_state.save(self)

    # -- the endpoints --------------------------------------------------

    def dispatch(self, client: int) -> dict[str, Any]:
        """Hand ``client`` the current broadcast: admission through the
        participation policy, downlink priced chain-vs-snapshot, the
        dispatched recycle mask recorded in the MaskLedger."""
        with self._lock:
            c = int(client)
            if not 0 <= c < self.cfg.n_clients:
                raise ServeError(f"client id {c} outside population "
                                 f"[0, {self.cfg.n_clients})")
            if c in self.jobs:
                raise ClientBusy(f"client {c} already has an inflight "
                                 "dispatch; upload it first")
            now = self.uptime()
            sel = self.policy.select(RoundContext(
                rng=self.rng, n_clients=self.cfg.n_clients, cohort_size=1,
                candidates=np.asarray([c], np.int64), population=False,
                distinct=True, sim=False, round=self.version, now=now))
            if len(sel.cohort) == 0:
                raise ClientUnavailable(
                    f"participation policy {self.policy.spec()!r} refused "
                    f"client {c} at this time")
            ht = 1.0 if sel.uniform else float(ht_weights(sel)[0])
            self.part_count[c] += 1

            mask_now = np.asarray(self.luar_state.mask)
            self.mask_ledger.record(self.version, mask_now)
            per_unit = self.pipeline.price_per_unit(self.sizes, mask_now)
            if self.has_delta:
                chain = (self.delta_ledger.chain_price(
                    self.last_dl[c], self.version, self.n_units)
                    if c in self.last_dl else None)
                down_pu, used_chain = versioned_download_price(
                    self.sizes, mask_now, chain, seed_cache=self.seed_cache)
                down_aux = self.down_pipe.aux_for("delta", down_pu)
            else:
                down_aux, used_chain = None, False
            down_bytes = self.down_pipe.price_bytes(self.sizes, self.no_mask,
                                                    down_aux)
            self.ins.down.add(down_bytes)
            self.ins.dispatches.inc()
            if used_chain:
                self.ins.delta_dl.inc()
            else:
                self.ins.full_dl.inc()
            if self._tr:
                self._tr.emit(DISPATCH, now, client=c, version=self.version,
                              down_bytes=down_bytes, delta=bool(used_chain),
                              first=c not in self.last_dl)
            first_contact = c not in self.last_dl
            self.last_dl[c] = self.version
            broadcast = self._broadcast_for_dispatch()
            self.jobs[c] = {"version": self.version, "mask": mask_now,
                            "per_unit": per_unit,
                            "bytes": float(per_unit.sum()),
                            "down_bytes": down_bytes, "ht": ht}
            self.policy.observe_dispatch(c, now=now)
            self.g_inflight.set(len(self.jobs))
            self._mutated()
            return {"client": c, "version": self.version,
                    "mask": [bool(b) for b in mask_now],
                    "broadcast": broadcast,
                    "down_bytes": float(down_bytes),
                    "delta": bool(used_chain), "first": bool(first_contact)}

    def _broadcast_for_dispatch(self):
        start = broadcast_point(self.params, self.server_state,
                                self.cfg.server)
        if not self.down_pipe:
            return start
        self.down_key, sub = jax.random.split(self.down_key)
        enc, self.down_state, _ = self.down_encode_fn(self.down_state,
                                                      start, sub)
        return self.down_pipe.decode(self.down_state, enc)

    def upload(self, client: int, update: Any,
               version: int | None = None) -> dict[str, Any]:
        """Accept ``client``'s raw update tree: UP-pipeline encode (per-
        client EF state server-side), exact masked pricing, buffer, and
        the LUAR merge once ``buffer_size`` uploads are in."""
        with self._lock:
            c = int(client)
            job = self.jobs.get(c)
            if job is None:
                raise UnknownDispatch(f"no inflight dispatch for client {c}")
            if version is not None and int(version) != job["version"]:
                raise VersionMismatch(
                    f"client {c} uploads against version {version}, "
                    f"dispatched at {job['version']}")
            del self.jobs[c]
            now = self.uptime()
            mask_v = self.mask_ledger.get(job["version"])
            if mask_v is None:
                # dispatch mask evicted mid-flight: reject outright and
                # charge the whole round trip (engine semantics)
                self.ins.misses.inc()
                self.ins.up.add(job["bytes"])
                self.ins.uplinks.inc()
                self.ins.wasted_up.add(float(job["per_unit"].sum()))
                self.ins.wasted_down.add(job["down_bytes"])
                if self._tr:
                    self._tr.emit(UPLOAD, now, client=c,
                                  version=job["version"],
                                  lag=self.version - job["version"],
                                  bytes=job["bytes"], status="rejected")
                self.g_inflight.set(len(self.jobs))
                self._mutated()
                return {"status": "rejected", "reason": "ledger_miss",
                        "version": self.version, "merged": False,
                        "buffer_fill": len(self.buffer)}

            self.key, qkey = jax.random.split(self.key)
            cstate = self._codec_state_for(c)
            delta, cstate, aux = self.encode_fn(cstate, update, qkey)
            if self.pipeline.stateful:
                self.codec_states[c] = cstate
            per_unit = self.pipeline.price_per_unit(self.sizes, job["mask"],
                                                    aux)
            self.ins.up.add(float(per_unit.sum()))
            self.ins.uplinks.inc()
            stal = self.version - job["version"]
            self.ins.staleness.observe(stal)
            if self._tr:
                self._tr.emit(UPLOAD, now, client=c, version=job["version"],
                              lag=int(stal), bytes=float(per_unit.sum()),
                              status="accepted")
            self.buffer.append((delta, stal, ~mask_v, per_unit,
                                job["down_bytes"], job["ht"]))
            self.ins.accepted.inc()
            merged = False
            if len(self.buffer) >= self.serve_cfg.buffer_size:
                self._merge(now)
                merged = True
            self.g_buffer.set(len(self.buffer))
            self.g_inflight.set(len(self.jobs))
            self._mutated()
            return {"status": "accepted", "version": self.version,
                    "merged": merged, "staleness": int(stal),
                    "bytes": float(per_unit.sum()),
                    "buffer_fill": len(self.buffer)}

    def _merge(self, now: float) -> None:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[b[0] for b in self.buffer])
        stal_arr = jnp.asarray([b[1] for b in self.buffer], jnp.int32)
        valid_np = np.stack([b[2] for b in self.buffer])
        alpha_t = self.serve_cfg.staleness_alpha
        cur_mask = np.asarray(self.luar_state.mask)
        if self.policy.weighted:
            hts = np.asarray([b[5] for b in self.buffer], np.float64)
            hts = np.minimum(hts, HT_CLIP * hts.min())
            self.params, self.luar_state, self.server_state = self.agg_fn(
                self.params, self.luar_state, self.server_state, stacked,
                stal_arr, jnp.asarray(valid_np), jnp.float32(alpha_t),
                jnp.asarray(hts, jnp.float32))
        else:
            self.params, self.luar_state, self.server_state = self.agg_fn(
                self.params, self.luar_state, self.server_state, stacked,
                stal_arr, jnp.asarray(valid_np), jnp.float32(alpha_t))
        if self.has_delta:
            # price the delta step this aggregation created (same
            # eff-and-current rule as the engine: see _run_fedbuff)
            eff_mask = ~np.any(valid_np, axis=0)
            self.delta_ledger.record_step(
                self.version, delta_step_price(self.sizes,
                                               eff_mask & cur_mask))
        n_merged = len(self.buffer)
        self.buffer.clear()
        self.version += 1
        self.ins.rounds.inc()
        self.g_version.set(self.version)
        if self._tr:
            self._tr.emit(AGGREGATE, now, version=self.version, n=n_merged,
                          alpha=float(alpha_t),
                          recycled=[int(i) for i in
                                    np.flatnonzero(~np.any(valid_np,
                                                           axis=0))])

    # -- read-only views ------------------------------------------------

    def status(self) -> dict[str, Any]:
        with self._lock:
            val = self.telemetry.metrics.value
            return {
                "schema": STATUS_SCHEMA,
                "version": int(self.version),
                "rounds_done": int(self.version),
                "buffer_fill": len(self.buffer),
                "buffer_size": int(self.serve_cfg.buffer_size),
                "inflight": len(self.jobs),
                "clients_seen": len(self.last_dl),
                "accepted": int(val(M_ACCEPTED)),
                "rejected": int(val(M_LEDGER_MISSES)),
                "dispatches": int(val(M_DISPATCHES)),
                "uploaded_mb": val(M_UPLOAD_BYTES) / 1e6,
                "downloaded_mb": val(M_DOWNLOAD_BYTES) / 1e6,
                "downloads_full": int(val(M_DOWNLOADS_FULL)),
                "downloads_delta": int(val(M_DOWNLOADS_DELTA)),
                "ledger": {
                    "mask_entries": len(self.mask_ledger),
                    "delta_entries": (len(self.delta_ledger)
                                      if self.delta_ledger is not None
                                      else 0),
                    "evictions_mask": int(val(M_LEDGER_EVICTIONS,
                                              ledger="mask")),
                    "evictions_delta": int(val(M_LEDGER_EVICTIONS,
                                               ledger="delta")),
                },
                "uptime_s": round(self.uptime(), 3),
            }

    def metrics_text(self) -> str:
        with self._lock:
            return prom.exposition(self.telemetry.metrics)
