"""repro.serve — the FL round service.

The fedbuff aggregation loop as a long-lived HTTP service with
write-ahead crash recovery, live Prometheus telemetry, and a simulated
client load harness.  See ``serve.core`` (service object),
``serve.http`` (stdlib transport), ``serve.state`` (snapshot layout),
``serve.client`` (drivers + CI smoke), ``serve.wire`` (npz-over-JSON
payload codec).
"""
from repro.serve.core import (ClientBusy, ClientUnavailable, RoundServer,
                              ServeError, UnknownDispatch, VersionMismatch)
from repro.serve.http import ServeHTTP, start, stop
from repro.serve.state import ServeConfig
from repro.serve.wire import (decode_arrays, decode_tree, encode_arrays,
                              encode_tree)

__all__ = [
    "ClientBusy", "ClientUnavailable", "RoundServer", "ServeConfig",
    "ServeError", "ServeHTTP", "UnknownDispatch", "VersionMismatch",
    "decode_arrays", "decode_tree", "encode_arrays", "encode_tree",
    "start", "stop",
]
