"""Simulated FL clients + the load harness for the round service.

``ServeClient`` drives one client's full round trip — dispatch, local
training (``fl.client.local_update``, jitted), upload — against either
a ``RoundServer`` object (in-process; zero transport overhead, used by
the crash-recovery tests) or a base URL (the real HTTP wire via
urllib).  Link realism comes from ``launch.mesh.client_link_trace``:
each client is pinned to a measured link class and ``pace > 0`` sleeps
``pace * (down_bytes/down_bw + up_bytes/up_bw)`` per round trip, so a
paced run replays the measured bandwidth asymmetry as client-side
dwell time (``pace=1`` = full measured link time; the benchmark uses a
small fraction so quick mode stays quick).

The CLI is the CI smoke: boot an in-process HTTP server, run N clients
x R rounds, scrape ``/metrics`` + ``/v1/status``, assert a clean
shutdown.

  PYTHONPATH=src python -m repro.serve.client --clients 3 --rounds 2 \\
      --scrape
"""
from __future__ import annotations

import argparse
import json
import threading
import time
import urllib.error
import urllib.request
from typing import Any

import jax
import numpy as np

from repro.fl.client import local_update
from repro.launch.mesh import client_link_trace
from repro.serve import wire
from repro.serve.core import RoundServer, ServeError

Transport = RoundServer | str


class HTTPError(ServeError):
    """Non-2xx from the wire, carrying the server's error body."""


def _http_json(url: str, body: dict | None = None,
               timeout: float = 60.0) -> dict[str, Any]:
    data = None if body is None else json.dumps(body).encode()
    req = urllib.request.Request(
        url, data=data,
        headers={"Content-Type": "application/json"} if data else {})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return json.loads(resp.read())
    except urllib.error.HTTPError as e:
        payload = e.read().decode(errors="replace")
        err = HTTPError(f"{url} -> {e.code}: {payload.strip()}")
        err.status = e.code
        raise err from None


class ServeClient:
    """One simulated client bound to a server (in-proc or URL)."""

    def __init__(self, cid: int, transport: Transport, loss_fn,
                 template_params: Any, data: dict[str, np.ndarray],
                 part: np.ndarray, cfg, *, pace: float = 0.0,
                 link=None, seed: int = 0):
        self.cid = int(cid)
        self.transport = transport
        self.template = template_params
        self.data = data
        self.part = np.asarray(part)
        self.cfg = cfg
        self.pace = float(pace)
        self.link = link               # (class, up_bw, down_bw) or None
        self._rng = np.random.default_rng(
            np.random.SeedSequence([int(seed) & 0xFFFFFFFF, 0x5EC, cid]))
        self._local = jax.jit(
            lambda p, b: local_update(loss_fn, p, b, cfg.client))
        # nominal uplink payload for pacing: the dense f32 model
        self._up_bytes = float(sum(
            np.asarray(leaf).nbytes for leaf in
            jax.tree_util.tree_leaves(template_params)))

    # -- transport ------------------------------------------------------

    def _dispatch(self) -> dict[str, Any]:
        if isinstance(self.transport, str):
            out = _http_json(self.transport + "/v1/dispatch",
                             {"client": self.cid})
            out["broadcast"] = wire.decode_tree(out.pop("params"),
                                                self.template)
            return out
        return self.transport.dispatch(self.cid)

    def _upload(self, update: Any, version: int) -> dict[str, Any]:
        if isinstance(self.transport, str):
            return _http_json(self.transport + "/v1/upload",
                              {"client": self.cid, "version": int(version),
                               "update": wire.encode_tree(update)})
        return self.transport.upload(self.cid, update, version)

    # -- one round trip -------------------------------------------------

    def run_round(self) -> dict[str, Any]:
        t0 = time.perf_counter()
        d = self._dispatch()
        sel = self._rng.choice(self.part,
                               size=(self.cfg.tau, self.cfg.batch_size),
                               replace=True)
        batches = {k: jax.numpy.asarray(arr[sel])
                   for k, arr in self.data.items()}
        delta = self._local(d["broadcast"], batches)
        jax.block_until_ready(delta)
        if self.pace > 0.0 and self.link is not None:
            _, up_bw, down_bw = self.link
            time.sleep(self.pace * (float(d["down_bytes"]) / down_bw
                                    + self._up_bytes / up_bw))
        u = self._upload(delta, d["version"])
        u["latency_s"] = time.perf_counter() - t0
        u["down_bytes"] = float(d["down_bytes"])
        u["client"] = self.cid
        return u


def make_clients(n: int, transport: Transport, loss_fn, template_params,
                 data, parts, cfg, *, pace: float = 0.0,
                 seed: int = 0) -> list[ServeClient]:
    """N clients over the measured link trace (client i -> trace row i)."""
    trace = client_link_trace(n)
    return [ServeClient(c, transport, loss_fn, template_params, data,
                        parts[c], cfg, pace=pace, link=trace[c], seed=seed)
            for c in range(n)]


def run_harness(clients: list[ServeClient], rounds: int,
                concurrent: bool = False) -> list[dict[str, Any]]:
    """Drive every client through ``rounds`` round trips.

    Sequential round-robin by default (deterministic request order — the
    crash-recovery tests rely on it); ``concurrent`` runs one thread per
    client to actually contend on the server's lock."""
    results: list[dict[str, Any]] = []
    if not concurrent:
        for _ in range(rounds):
            for cl in clients:
                results.append(cl.run_round())
        return results
    lock = threading.Lock()

    def loop(cl: ServeClient):
        for _ in range(rounds):
            r = cl.run_round()
            with lock:
                results.append(r)

    threads = [threading.Thread(target=loop, args=(cl,)) for cl in clients]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def latency_quantiles(results: list[dict[str, Any]]) -> dict[str, float]:
    lat = np.asarray([r["latency_s"] for r in results], np.float64)
    if lat.size == 0:
        return {"p50_ms": 0.0, "p95_ms": 0.0, "max_ms": 0.0}
    return {"p50_ms": float(np.quantile(lat, 0.5) * 1e3),
            "p95_ms": float(np.quantile(lat, 0.95) * 1e3),
            "max_ms": float(lat.max() * 1e3)}


def _build_workload(n_clients: int, seed: int, buffer_size: int,
                    codecs: str, ckpt: str = ""):
    """Self-contained mixture-MLP workload (no benchmarks/ import)."""
    from repro.core import LuarConfig
    from repro.data.synthetic import gaussian_mixture
    from repro.fl.client import ClientConfig
    from repro.fl.partition import dirichlet_partition
    from repro.fl.rounds import FLConfig
    from repro.fl.server import ServerConfig
    from repro.models.cnn import mlp_apply, mlp_init, softmax_xent
    from repro.serve.state import ServeConfig

    x, y = gaussian_mixture(1500, n_classes=10, d=32, seed=seed)
    parts = dirichlet_partition(y, n_clients, alpha=0.5, seed=seed)
    params = mlp_init(jax.random.PRNGKey(seed), n_features=32, n_classes=10)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    cfg = FLConfig(
        n_clients=n_clients, n_active=min(8, n_clients), tau=2,
        batch_size=16, rounds=10 ** 9, seed=seed,
        client=ClientConfig(lr=0.05), server=ServerConfig(),
        luar=LuarConfig(delta=2),
        codecs=tuple(s for s in codecs.split(",") if s))
    sc = ServeConfig(buffer_size=buffer_size, ckpt_path=ckpt)
    return loss_fn, params, {"x": x, "y": y}, parts, cfg, sc


def main(argv=None) -> int:
    from repro.serve import http as serve_http

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--buffer", type=int, default=3)
    ap.add_argument("--codecs", default="down:delta")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pace", type=float, default=0.0,
                    help="fraction of measured link time to sleep per trip")
    ap.add_argument("--url", default="",
                    help="existing server URL (default: boot one in-proc)")
    ap.add_argument("--concurrent", action="store_true")
    ap.add_argument("--scrape", action="store_true",
                    help="print /metrics and /v1/status at the end")
    args = ap.parse_args(argv)

    loss_fn, params, data, parts, cfg, sc = _build_workload(
        args.clients, args.seed, args.buffer, args.codecs)
    httpd = None
    if args.url:
        url = args.url
    else:
        rs = RoundServer(params, cfg, sc)
        httpd = serve_http.start(rs)
        url = httpd.url
        print(f"# booted in-process server on {url}")

    clients = make_clients(args.clients, url, loss_fn, params, data, parts,
                           cfg, pace=args.pace, seed=args.seed)
    t0 = time.perf_counter()
    results = run_harness(clients, args.rounds, concurrent=args.concurrent)
    wall = time.perf_counter() - t0
    status = _http_json(url + "/v1/status")
    q = latency_quantiles(results)
    n_acc = sum(r["status"] == "accepted" for r in results)
    print(f"# {len(results)} round trips ({n_acc} accepted) in {wall:.2f}s "
          f"-> {status['rounds_done'] / max(wall, 1e-9):.2f} rounds/s; "
          f"p50 {q['p50_ms']:.1f}ms p95 {q['p95_ms']:.1f}ms; "
          f"server version {status['version']}; "
          f"up {status['uploaded_mb']:.3f}MB down "
          f"{status['downloaded_mb']:.3f}MB")
    if args.scrape:
        print(json.dumps(status, indent=2))
        metrics = urllib.request.urlopen(url + "/metrics",
                                         timeout=30).read().decode()
        print(metrics, end="")

    ok = n_acc == len(results) and status["version"] > 0
    if httpd is not None:
        serve_http.stop(httpd)
        print("# clean shutdown ok")
    if not ok:
        print("# FAILED: not every round trip accepted, or no aggregation "
              "happened")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
