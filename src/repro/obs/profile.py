"""Profiling timers — wall-time spans recorded as histogram metrics.

The engines wrap their hot-path stages in ``telemetry.span(name)``:
the first execution of a jitted callable is its XLA compile (labelled
``phase="compile"``), later ones are steady state (``phase="steady"``),
so compile overhead and steady-state throughput are separable in the
recorded distribution — the split every "measurably faster" claim needs.

Spans observed so far (per engine):

  run_fl / sync sim:  round_step (compile/steady), pricing, eval
  fedbuff sim:        client_step (local train + codec encode),
                      aggregate (compile/steady), pricing, eval

``Profiler.table()`` renders count/total/mean/min per (span, phase) for
the ``--profile`` CLI flag; the same data is scrapeable through the
registry as ``obs_span_seconds`` histograms.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.obs.metrics import Histogram, MetricsRegistry

SPAN_METRIC = "obs_span_seconds"


class Profiler:
    """Wall-time span recorder bound to a metrics registry."""

    def __init__(self, metrics: MetricsRegistry):
        self._fam = metrics.histogram(
            SPAN_METRIC, help="wall-time spans around engine hot paths",
            unit="seconds")
        self._seen: set = set()          # span names that already ran once

    def phase_of(self, name: str) -> str:
        """compile on a span's first execution, steady after — callers
        that wrap a jitted fn get the compile/steady split for free."""
        if name in self._seen:
            return "steady"
        self._seen.add(name)
        return "compile"

    @contextmanager
    def span(self, name: str, jitted: bool = False):
        phase = self.phase_of(name) if jitted else "steady"
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._fam.labels(span=name, phase=phase).observe(
                time.perf_counter() - t0)

    def table(self) -> list[tuple[str, str, int, float, float, float]]:
        """(span, phase, count, total_s, mean_s, min_s) rows, insertion
        order — the ``--profile`` render."""
        rows = []
        for child in self._fam.children():
            labels: dict[str, str] = dict(child.labels)
            if not isinstance(child, Histogram) or not child.samples:
                continue
            rows.append((labels.get("span", "?"), labels.get("phase", "?"),
                         child.count, child.sum, child.mean(),
                         min(child.samples)))
        return rows

    def render(self) -> str:
        lines = [f"{'span':<24}{'phase':<9}{'count':>7}{'total_s':>10}"
                 f"{'mean_ms':>10}{'min_ms':>10}"]
        for span, phase, n, total, mean, mn in self.table():
            lines.append(f"{span:<24}{phase:<9}{n:>7}{total:>10.3f}"
                         f"{mean * 1e3:>10.3f}{mn * 1e3:>10.3f}")
        return "\n".join(lines)
