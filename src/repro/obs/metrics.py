"""Metrics registry — counters, gauges and histograms with labels.

One host-side registry per run absorbs every ad-hoc ledger the engines
used to keep as loose locals and result-dataclass fields (uploaded /
downloaded bytes, waste, ledger misses, staleness observations,
participation / fairness counts).  The result dataclasses are now
RE-DERIVED from the registry at end of run — bit-for-bit, because a
``Counter.add`` is exactly the ``x += v`` float64 accumulation the
engines performed inline before.

Design constraints, in order:

  * bit-for-bit — instruments store plain Python floats (f64) and the
    engines add in the same order as the retired inline accumulators;
  * zero overhead when disabled — the ``NullSink`` hands out singleton
    no-op instruments, and every trace/profile hook in the engines is
    gated on a cheap ``if``;
  * scrapeable — ``repro.obs.prom`` renders any ``MetricsRegistry`` in
    Prometheus text exposition format (the ROADMAP round server's
    future /metrics endpoint).

Metric naming follows Prometheus conventions: ``fl_*_total`` counters,
``fl_*`` gauges, histograms with explicit unit suffixes.  The catalogue
the engines emit is documented in README ("Observability").
"""
from __future__ import annotations

import math
from typing import Protocol
from collections.abc import Iterable, Sequence

import numpy as np

LabelKV = tuple[tuple[str, str], ...]


def _label_kv(labels: dict[str, str] | None) -> LabelKV:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone float64 accumulator (one labelset of a family)."""

    __slots__ = ("labels", "value")

    def __init__(self, labels: LabelKV = ()):
        self.labels = labels
        self.value = 0.0

    def add(self, v: float) -> None:
        if v < 0:
            raise ValueError(f"counter add must be >= 0, got {v}")
        self.value += v

    def inc(self) -> None:
        self.value += 1.0


class Gauge:
    """Last-write-wins float64 value (one labelset of a family)."""

    __slots__ = ("labels", "value")

    def __init__(self, labels: LabelKV = ()):
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += v


# default span/staleness buckets: exponential, seconds-friendly
DEFAULT_BUCKETS = (1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
                   60.0, 300.0)


class Histogram:
    """Bucketed distribution that ALSO retains raw samples.

    The buckets feed Prometheus exposition; the raw samples feed the
    exact quantiles the result dataclasses always reported
    (``np.quantile`` over every observation — same values, same dtype,
    so ``SimResult.staleness_q`` derives bit-for-bit).
    """

    __slots__ = ("labels", "buckets", "counts", "sum", "samples")

    def __init__(self, labels: LabelKV = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.labels = labels
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +Inf bucket last
        self.sum = 0.0
        self.samples: list[float] = []

    @property
    def count(self) -> int:
        return len(self.samples)

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        self.sum += v
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> float:
        if not self.samples:
            return math.nan
        return float(np.quantile(np.asarray(self.samples, np.float64), q))

    def mean(self) -> float:
        return self.sum / len(self.samples) if self.samples else math.nan


class Family:
    """One named metric (counter/gauge/histogram) over its labelsets."""

    def __init__(self, name: str, kind: str, help: str = "", unit: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.kind = kind
        self.help = help
        self.unit = unit
        self._buckets = tuple(buckets)
        self._children: dict[LabelKV, object] = {}

    def labels(self, **labels):
        kv = _label_kv(labels)
        child = self._children.get(kv)
        if child is None:
            if self.kind == "counter":
                child = Counter(kv)
            elif self.kind == "gauge":
                child = Gauge(kv)
            else:
                child = Histogram(kv, self._buckets)
            self._children[kv] = child
        return child

    # scalar convenience: the no-label child
    def add(self, v: float) -> None:
        self.labels().add(v)

    def inc(self) -> None:
        self.labels().inc()

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)

    @property
    def value(self) -> float:
        return self.labels().value

    def children(self) -> Iterable:
        return self._children.values()


class MetricsSink(Protocol):
    """What the engines need from a telemetry backend: named instrument
    families.  ``MetricsRegistry`` is the real one; ``NullSink`` is the
    zero-overhead disabled path (every instrument a shared no-op)."""

    def counter(self, name: str, help: str = "", unit: str = "") -> Family:
        ...

    def gauge(self, name: str, help: str = "", unit: str = "") -> Family:
        ...

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        ...


class MetricsRegistry:
    """The real sink: an ordered catalogue of metric families."""

    def __init__(self):
        self._families: dict[str, Family] = {}

    def _get(self, name: str, kind: str, help: str, unit: str,
             buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        fam = self._families.get(name)
        if fam is None:
            fam = Family(name, kind, help, unit, buckets)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam.kind}, requested {kind}")
        return fam

    def counter(self, name: str, help: str = "", unit: str = "") -> Family:
        return self._get(name, "counter", help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Family:
        return self._get(name, "gauge", help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        return self._get(name, "histogram", help, unit, buckets)

    def families(self) -> Iterable[Family]:
        return self._families.values()

    def get(self, name: str) -> Family | None:
        return self._families.get(name)

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """The scalar value of one counter/gauge labelset (0 if absent —
        a run that never exercised a path never created its family)."""
        fam = self._families.get(name)
        if fam is None:
            return default
        kv = _label_kv(labels)
        child = fam._children.get(kv)
        return default if child is None else child.value

    # -- snapshot/restore (repro.serve crash recovery) ------------------
    # JSON round-trips Python floats exactly (repr-based), and histogram
    # restore RE-OBSERVES the raw samples in emission order, so counter
    # sums, bucket counts and f64 accumulation order all come back
    # bit-for-bit — the kill-and-resume equivalence test pins the full
    # Prometheus exposition byte-for-byte on this.

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of every family, child and sample
        (family/child insertion order preserved)."""
        fams = []
        for fam in self.families():
            children = []
            for child in fam.children():
                rec: dict = {"labels": [list(kv) for kv in child.labels]}
                if isinstance(child, Histogram):
                    rec["samples"] = list(child.samples)
                else:
                    rec["value"] = child.value
                children.append(rec)
            fams.append({"name": fam.name, "kind": fam.kind,
                         "help": fam.help, "unit": fam.unit,
                         "buckets": list(fam._buckets),
                         "children": children})
        return {"schema": 1, "families": fams}

    def load_state_dict(self, doc: dict) -> None:
        """Merge a ``state_dict`` snapshot back in.  Families/children
        already registered (e.g. by instrument construction on resume)
        are overwritten in place; unseen ones are created in snapshot
        order."""
        if doc.get("schema") != 1:
            raise ValueError(f"metrics snapshot schema {doc.get('schema')!r}"
                             " != 1")
        for f in doc["families"]:
            fam = self._get(f["name"], f["kind"], f["help"], f["unit"],
                            tuple(f["buckets"]))
            for rec in f["children"]:
                child = fam.labels(**{k: v for k, v in rec["labels"]})
                if isinstance(child, Histogram):
                    child.counts = [0] * (len(child.buckets) + 1)
                    child.sum = 0.0
                    child.samples = []
                    for s in rec["samples"]:
                        child.observe(s)
                else:
                    child.value = float(rec["value"])


class _NullInstrument:
    """Shared do-nothing counter/gauge/histogram AND family."""

    __slots__ = ()
    labels_kv: LabelKV = ()
    value = 0.0
    sum = 0.0
    count = 0

    def labels(self, **labels):
        return self

    def add(self, v: float) -> None:
        pass

    def inc(self) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return math.nan

    def children(self):
        return ()


_NULL_INSTRUMENT = _NullInstrument()


class NullSink:
    """MetricsSink that drops everything — the disabled path."""

    def counter(self, name: str, help: str = "", unit: str = ""):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, help: str = "", unit: str = ""):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS):
        return _NULL_INSTRUMENT


# ---------------------------------------------------------------------------
# the engine metric catalogue (README "Observability" documents each):
# counters are cumulative over one run; gauges are end-of-run (or
# latest) values; fl_staleness_rounds is a histogram over accepted
# arrivals.  Engines and the CLI report both import THESE names so the
# catalogue cannot drift between emission and rendering.
# ---------------------------------------------------------------------------

M_UPLOAD_BYTES = "fl_upload_bytes_total"            # client->server wire bytes
M_DOWNLOAD_BYTES = "fl_download_bytes_total"        # server->client wire bytes
M_UPLINKS = "fl_uplinks_total"                      # uploads spent
M_DISPATCHES = "fl_dispatches_total"                # downloads served
M_ACCEPTED = "fl_updates_accepted_total"            # merged client updates
M_ROUNDS = "fl_rounds_total"                        # aggregations applied
M_STRAGGLERS = "fl_stragglers_total"
M_DROPOUTS = "fl_dropouts_total"
M_LEDGER_MISSES = "fl_ledger_misses_total"          # rejected stale arrivals
M_LEDGER_EVICTIONS = "fl_ledger_evictions_total"    # labels: ledger=mask|delta
M_WASTED_UP = "fl_wasted_upload_bytes_total"
M_WASTED_DOWN = "fl_wasted_download_bytes_total"
M_DOWNLOADS_FULL = "fl_downloads_full_total"        # snapshot downlinks
M_DOWNLOADS_DELTA = "fl_downloads_delta_total"      # delta-chain downlinks
M_COMM_RATIO = "fl_comm_ratio"                      # gauge, uplink vs FedAvg
M_DOWN_RATIO = "fl_down_ratio"                      # gauge, vs full broadcast
M_SIM_TIME = "fl_sim_time_seconds"                  # gauge, virtual clock
M_FAIRNESS = "fl_participation_fairness"            # gauge, stat=min|median|max
M_INFLIGHT_END = "fl_inflight_end"                  # gauge
M_STRANDED_END = "fl_stranded_end"                  # gauge
M_STALENESS = "fl_staleness_rounds"                 # histogram, version lag

# the fl_server_* gauge group: live state of the repro.serve round
# service (the sim engines never set these — a scrape distinguishes a
# service from a replayed run by their presence)
M_SERVER_VERSION = "fl_server_version"              # gauge, current model
                                                    # version (aggregations
                                                    # applied since init)
M_SERVER_BUFFER_FILL = "fl_server_buffer_fill"      # gauge, uploads waiting
                                                    # in the merge buffer
M_SERVER_INFLIGHT = "fl_server_inflight_dispatches"  # gauge, dispatched but
                                                     # not yet uploaded

STALENESS_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def format_metrics(reg: MetricsRegistry) -> str:
    """Human-readable one-line-per-series render (the CLI summary's
    sibling; Prometheus exposition lives in ``repro.obs.prom``)."""
    lines = []
    for fam in reg.families():
        for child in fam.children():
            label = ",".join(f"{k}={v}" for k, v in child.labels)
            suffix = f"{{{label}}}" if label else ""
            if isinstance(child, Histogram):
                lines.append(
                    f"{fam.name}{suffix} count={child.count} "
                    f"sum={child.sum:.6g} mean={child.mean():.6g}")
            else:
                lines.append(f"{fam.name}{suffix} {child.value:.6g}")
    return "\n".join(lines)
