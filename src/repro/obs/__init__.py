"""repro.obs — unified telemetry: metrics, round traces, profiling.

The observability spine of the repo (ISSUE 6): a metrics registry with
Prometheus exposition (``repro.obs.prom``), a versioned JSONL round-
trace sink, and wall-time profiling spans, bundled as one ``Telemetry``
object the engines thread:

    from repro.obs import Telemetry, TraceSink
    tele = Telemetry.create(trace_path="trace.jsonl", profile=True)
    res = run_fl(..., telemetry=tele)
    print(prom.exposition(tele.metrics))

Passing no telemetry costs nothing: the engines build a private
metrics-only bundle (their byte/waste/staleness ledgers live in the
registry now and the result dataclasses derive from it bit-for-bit),
and every trace/profile hook is gated on a cheap ``if``.
"""
from repro.obs.metrics import (DEFAULT_BUCKETS, STALENESS_BUCKETS,  # noqa: F401
                               Counter, Family, Gauge, Histogram,
                               MetricsRegistry, MetricsSink, NullSink,
                               format_metrics,
                               M_ACCEPTED, M_COMM_RATIO, M_DISPATCHES,
                               M_DOWN_RATIO, M_DOWNLOAD_BYTES,
                               M_DOWNLOADS_DELTA, M_DOWNLOADS_FULL,
                               M_DROPOUTS, M_FAIRNESS, M_INFLIGHT_END,
                               M_LEDGER_EVICTIONS, M_LEDGER_MISSES,
                               M_ROUNDS, M_SERVER_BUFFER_FILL,
                               M_SERVER_INFLIGHT, M_SERVER_VERSION,
                               M_SIM_TIME, M_STALENESS,
                               M_STRAGGLERS, M_STRANDED_END, M_UPLINKS,
                               M_UPLOAD_BYTES, M_WASTED_DOWN, M_WASTED_UP)
from repro.obs.profile import SPAN_METRIC, Profiler  # noqa: F401
from repro.obs.report import fairness_from_metrics, run_summary  # noqa: F401
from repro.obs.telemetry import Telemetry  # noqa: F401
from repro.obs.trace import (AGGREGATE, DISPATCH, EVICT, EVENT_KINDS,  # noqa: F401
                             RUN_END, RUN_START, TRACE_SCHEMA, TraceSink,
                             UPLOAD, WAKE, read_trace)
