"""End-of-run rendering FROM the metrics registry.

``launch/train.py`` used to hand-roll its summary JSON from result
fields; now the registry is the single source and this module the single
formatting path — the numbers in the CLI summary, the Prometheus
exposition and the result dataclasses all read the same instruments.
"""
from __future__ import annotations
from typing import Any

from repro.obs.metrics import (M_COMM_RATIO, M_DOWN_RATIO, M_DOWNLOAD_BYTES,
                               M_FAIRNESS, M_UPLINKS, M_UPLOAD_BYTES,
                               MetricsRegistry)


def fairness_from_metrics(metrics: MetricsRegistry) -> dict[str, float]:
    return {stat: metrics.value(M_FAIRNESS, stat=stat)
            for stat in ("min", "median", "max")}


def run_summary(metrics: MetricsRegistry, **extra: Any) -> dict[str, Any]:
    """The CLI's end-of-run summary dict, derived from the registry
    (key order matches the retired hand-rolled block; ``extra`` fields
    append in call order)."""
    out: dict[str, Any] = {
        "comm_ratio": round(metrics.value(M_COMM_RATIO), 4),
        "uploaded_mb": round(metrics.value(M_UPLOAD_BYTES) / 1e6, 3),
        "n_uplinks_spent": int(metrics.value(M_UPLINKS)),
        "down_ratio": round(metrics.value(M_DOWN_RATIO), 4),
        "downloaded_mb": round(metrics.value(M_DOWNLOAD_BYTES) / 1e6, 3),
    }
    out.update(extra)
    return out
