"""Structured round traces — versioned JSONL event stream.

One line per event, schema version pinned in every line, insertion
key-order stable (``v``, ``event``, ``t_sim``, ``t_wall``, then the
event's own fields in emission order) so goldens can pin the exact
bytes.  The stream is consumable by ``benchmarks/`` and by the future
round server's live feed.

Event kinds the engines emit (see README "Observability" for the full
field tables):

  RUN_START   engine/mode, n_clients, rounds, unit names — the header
  DISPATCH    server hands a client (or a sync cohort) the model:
              cohort/client, model version, downlink bytes, delta-vs-full
  UPLOAD      a client update reaches the server: bytes, version lag,
              accepted / rejected / straggler / dropout status
  AGGREGATE   the server applies a merge: new version, cohort size,
              staleness alpha, per-unit recycle decisions (indices)
  EVICT       a version ledger evicted a record (mask or delta step)
  WAKE        the fedbuff scheduler advanced the clock to retry starved
              slots
  RUN_END     terminal summary ledger snapshot

``t_sim`` is the engine's virtual clock (the round index in ``run_fl``,
virtual seconds in ``repro.sim``); ``t_wall`` is host wall-clock seconds
since the sink was opened (injectable ``clock`` for deterministic
goldens).
"""
from __future__ import annotations

import io
import json
import time
from typing import Any
from collections.abc import Callable

import numpy as np

TRACE_SCHEMA = 1

# the canonical event kinds (engines may only emit these)
RUN_START = "RUN_START"
DISPATCH = "DISPATCH"
UPLOAD = "UPLOAD"
AGGREGATE = "AGGREGATE"
EVICT = "EVICT"
WAKE = "WAKE"
RUN_END = "RUN_END"

EVENT_KINDS = (RUN_START, DISPATCH, UPLOAD, AGGREGATE, EVICT, WAKE, RUN_END)


def _jsonify(v: Any) -> Any:
    """numpy scalars/arrays -> plain JSON types (stable repr)."""
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return [_jsonify(x) for x in v.tolist()]
    if isinstance(v, (list, tuple)):
        return [_jsonify(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonify(x) for k, x in v.items()}
    return v


class TraceSink:
    """JSONL round-trace writer (file path, file-like, or in-memory).

    ``clock`` defaults to wall time relative to sink creation; tests
    inject a fake clock so golden traces are byte-stable.  ``emit`` is
    cheap (one dict + one json.dumps) but the engines still gate every
    call on ``if trace:`` so the disabled path costs nothing.
    """

    def __init__(self, path: str | io.IOBase | None = None,
                 clock: Callable[[], float] | None = None):
        self._own = False
        if path is None:
            self._fh = None
        elif isinstance(path, (str,)):
            self._fh = open(path, "w")
            self._own = True
        else:
            self._fh = path
        self.events: list[dict[str, Any]] = []    # in-memory mode only
        self._t0 = time.time() if clock is None else None
        self._clock = clock
        self.n_emitted = 0

    def _now_wall(self) -> float:
        if self._clock is not None:
            return float(self._clock())
        return time.time() - self._t0

    def emit(self, event: str, t_sim: float, **fields: Any) -> None:
        if event not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {event!r}; "
                             f"schema v{TRACE_SCHEMA} kinds: {EVENT_KINDS}")
        rec: dict[str, Any] = {"v": TRACE_SCHEMA, "event": event,
                               "t_sim": float(t_sim),
                               "t_wall": round(self._now_wall(), 6)}
        for k, val in fields.items():
            rec[k] = _jsonify(val)
        self.n_emitted += 1
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
        else:
            self.events.append(rec)

    def lines(self) -> list[str]:
        """The emitted stream as JSONL lines (in-memory mode only)."""
        if self._fh is not None:
            raise RuntimeError("lines() is for in-memory sinks; the "
                               "file-backed sink already wrote to disk")
        return [json.dumps(rec) for rec in self.events]

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            if self._own:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "TraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_trace(path: str) -> list[dict[str, Any]]:
    """Parse a JSONL trace file back into event dicts (schema-checked)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("v") != TRACE_SCHEMA:
                raise ValueError(f"trace schema v{rec.get('v')} != "
                                 f"supported v{TRACE_SCHEMA}")
            out.append(rec)
    return out
