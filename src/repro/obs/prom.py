"""Prometheus text exposition for a ``MetricsRegistry``.

Renders the text format (version 0.0.4) a Prometheus scraper expects —
the ROADMAP round server mounts this on its /metrics endpoint:

    from repro.obs import MetricsRegistry, prom
    body = prom.exposition(reg)          # -> "# HELP ...\n# TYPE ...\n..."

Counters/gauges render one sample per labelset; histograms render the
cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``.  Names
and label values are escaped per the exposition spec.
"""
from __future__ import annotations

import math

from repro.obs.metrics import Histogram, MetricsRegistry

# the HTTP Content-Type a /metrics endpoint must serve this body under
# (Prometheus text exposition format, version 0.0.4)
CONTENT_TYPE = "text/plain; version=0.0.4"


def _escape_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_str(kv, extra=()) -> str:
    parts = [f'{k}="{_escape_label(str(v))}"' for k, v in (*kv, *extra)]
    return "{" + ",".join(parts) + "}" if parts else ""


def exposition(reg: MetricsRegistry) -> str:
    """The whole registry in Prometheus text exposition format."""
    lines: list[str] = []
    for fam in reg.families():
        kind = {"counter": "counter", "gauge": "gauge",
                "histogram": "histogram"}[fam.kind]
        if fam.help:
            lines.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
        lines.append(f"# TYPE {fam.name} {kind}")
        for child in fam.children():
            if isinstance(child, Histogram):
                cum = 0
                for b, c in zip(child.buckets, child.counts):
                    cum += c
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_labels_str(child.labels, (('le', _fmt(b)),))}"
                        f" {cum}")
                cum += child.counts[-1]
                lines.append(
                    f"{fam.name}_bucket"
                    f"{_labels_str(child.labels, (('le', '+Inf'),))} {cum}")
                lines.append(f"{fam.name}_sum{_labels_str(child.labels)}"
                             f" {_fmt(child.sum)}")
                lines.append(f"{fam.name}_count{_labels_str(child.labels)}"
                             f" {child.count}")
            else:
                lines.append(f"{fam.name}{_labels_str(child.labels)}"
                             f" {_fmt(child.value)}")
    return "\n".join(lines) + "\n"
