"""The ``Telemetry`` bundle the engines thread.

One object carries the three observability channels:

  * ``metrics``  — always a real ``MetricsRegistry``: the engines'
    byte/waste/staleness/participation ledgers LIVE here now, and the
    result dataclasses are derived from it at end of run (a counter add
    is the same f64 ``+=`` the old inline accumulators did, so the
    derivation is bit-for-bit);
  * ``trace``    — optional ``TraceSink`` (JSONL round events);
  * ``profiler`` — optional ``Profiler`` (wall-time span histograms).

``run_fl``/``run_sim`` take ``telemetry=None`` and build a private
bundle (metrics only) when the caller doesn't care — the disabled trace
and profiler paths are gated ``if`` checks, so default runs pay nothing
beyond the counter adds that replaced the old inline ``+=``.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from collections.abc import Iterator

from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profiler
from repro.obs.trace import TraceSink


@contextmanager
def _null_span() -> Iterator[None]:
    yield


@dataclass
class Telemetry:
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    trace: TraceSink | None = None
    profiler: Profiler | None = None

    @classmethod
    def create(cls, trace_path: str | None = None,
               profile: bool = False) -> "Telemetry":
        """The CLI constructor: file-backed trace and/or profiler."""
        metrics = MetricsRegistry()
        return cls(metrics=metrics,
                   trace=TraceSink(trace_path) if trace_path else None,
                   profiler=Profiler(metrics) if profile else None)

    def span(self, name: str, jitted: bool = False):
        """A profiling span ctx (no-op when profiling is off)."""
        if self.profiler is None:
            return _null_span()
        return self.profiler.span(name, jitted=jitted)

    def close(self) -> None:
        if self.trace is not None:
            self.trace.close()
