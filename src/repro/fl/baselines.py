"""Comparison communication-efficient FL methods (Table 2 / Table 3).

  fedpaq_quantize : QSGD-style per-tensor stochastic uniform quantization
                    with 2^bits levels (comm cost ~= bits/32).
  lbgm            : Look-Back Gradient Multiplier — per layer-unit, if the
                    fresh update is sufficiently collinear with the last
                    *transmitted* update, the client sends only the scalar
                    projection coefficient (cost ~= 4 bytes).
  dropping        : LuarConfig(mode="drop") in repro.core (Table 5).
  prunefl_mask    : magnitude pruning of the update (PruneFL-flavoured
                    upload sparsification with a kept-fraction).
  feddropoutavg   : random dropout of update entries with rate fdr.
"""
from __future__ import annotations
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.units import UnitMap, unit_sq_norms

Params = Any


# -- FedPAQ ------------------------------------------------------------------


def fedpaq_quantize(update: Params, key, bits: int = 4) -> Params:
    """Stochastic uniform quantization, per tensor, symmetric range."""
    levels = 2 ** bits - 1
    leaves, treedef = jax.tree.flatten(update)
    keys = jax.random.split(key, len(leaves))

    def q(x, k):
        scale = jnp.max(jnp.abs(x)) + 1e-12
        y = (x / scale + 1.0) / 2.0 * levels              # [0, levels]
        lo = jnp.floor(y)
        p = y - lo
        yq = lo + jax.random.bernoulli(k, p).astype(x.dtype)
        return (yq / levels * 2.0 - 1.0) * scale

    return jax.tree.unflatten(treedef, [q(x, k) for x, k in zip(leaves, keys)])


def fedpaq_comm_ratio(bits: int) -> float:
    return bits / 32.0


# -- LBGM --------------------------------------------------------------------


class LBGMState(NamedTuple):
    anchor: Params                  # last fully-transmitted update
    anchor_sq: jax.Array            # per-unit ||anchor||^2


def lbgm_init(params: Params, um: UnitMap) -> LBGMState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return LBGMState(zeros, jnp.zeros((len(um.names),), jnp.float32))


def lbgm_round(state: LBGMState, um: UnitMap, fresh: Params,
               threshold: float = 0.95) -> tuple[Params, LBGMState, jax.Array]:
    """Returns (applied_update, new_state, per-unit sent_full mask)."""
    fresh_sq = unit_sq_norms(um, fresh)
    # per-unit <fresh, anchor>
    dots = [jnp.zeros((), jnp.float32) for _ in um.names]
    for u, f, a in zip(um.leaf_unit, jax.tree.leaves(fresh), jax.tree.leaves(state.anchor)):
        dots[u] = dots[u] + jnp.sum(f.astype(jnp.float32) * a.astype(jnp.float32))
    dot = jnp.stack(dots)
    cos2 = jnp.square(dot) / jnp.clip(fresh_sq * state.anchor_sq, 1e-20)
    reuse = cos2 >= threshold ** 2                         # look-back OK
    coeff = dot / jnp.clip(state.anchor_sq, 1e-20)

    fresh_leaves = jax.tree.leaves(fresh)
    anchor_leaves = jax.tree.leaves(state.anchor)
    out, new_anchor = [], []
    for u, f, a in zip(um.leaf_unit, fresh_leaves, anchor_leaves):
        applied = jnp.where(reuse[u], coeff[u] * a, f)
        out.append(applied)
        new_anchor.append(jnp.where(reuse[u], a, f))
    applied = jax.tree.unflatten(um.treedef, out)
    anchor = jax.tree.unflatten(um.treedef, new_anchor)
    new_sq = jnp.where(reuse, state.anchor_sq, fresh_sq)
    return applied, LBGMState(anchor, new_sq), ~reuse


# -- PruneFL-flavoured magnitude sparsification -------------------------------


def magnitude_prune(update: Params, keep_fraction: float) -> Params:
    def prune(x):
        flat = jnp.abs(x.reshape(-1))
        k = max(1, int(keep_fraction * flat.shape[0]))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)
    return jax.tree.map(prune, update)


# -- FedDropoutAvg -------------------------------------------------------------


def dropout_avg(update: Params, key, fdr: float = 0.5) -> Params:
    leaves, treedef = jax.tree.flatten(update)
    keys = jax.random.split(key, len(leaves))
    out = [jnp.where(jax.random.bernoulli(k, 1.0 - fdr, x.shape), x, 0.0) / (1.0 - fdr)
           for x, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
