"""Label-skew Dirichlet partitioning (the paper's non-IID generator,
alpha=0.1 for CIFAR/FEMNIST-like, 0.5 for AG-News-like)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Returns per-client index arrays.  Highly skewed for small alpha."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client: list[list[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for client, chunk in enumerate(np.split(idx_c, cuts)):
                idx_per_client[client].extend(chunk.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
    return [np.array(sorted(ix)) for ix in idx_per_client]


def partition_stats(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    n_classes = int(labels.max()) + 1
    sizes = np.array([len(p) for p in parts])
    per_class = np.stack([np.bincount(labels[p], minlength=n_classes) for p in parts])
    frac = per_class / np.maximum(per_class.sum(1, keepdims=True), 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        entropy = -np.sum(np.where(frac > 0, frac * np.log(frac), 0.0), axis=1)
    return {"sizes": sizes, "mean_label_entropy": float(entropy.mean()),
            "max_label_entropy": float(np.log(n_classes))}
