"""Algorithm 2 — the FedLUAR round engine (simulation form).

One jitted ``round_step`` does: broadcast -> vmap'd client local training
(tau SGD steps each) -> cohort mean -> update-codec pipeline (the
declared compressor stack, ``repro.compress``) -> LUAR (Alg. 1) ->
server optimizer.  The host loop only samples cohorts and minibatch
indices (numpy RNG) and tracks communication bytes via the pipeline's
host-side pricing.

The compressor stack is declared as ``FLConfig.codecs`` spec strings
(e.g. ``("fedpaq:4", "topk:0.1", "ef")``); the retired scalar flags
(``fedpaq_bits``/``lbgm_threshold``/``prune_keep``/``dropout_rate``)
remain as a deprecation shim that builds the equivalent pipeline, so
legacy configs keep working bit-for-bit.  LBGM is just a stateful codec
stage now — there is no special-cased LBGM state in the round engine.

At pod scale the same algorithm runs through launch/steps.py with the
cohort mapped onto mesh axes; this module is the single-host simulator
used by tests, benchmarks and examples.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import (CodecPipeline, Direction, delta_step_price,
                            legacy_codec_specs, parse_codecs,
                            partition_codec_specs, snapshot_price,
                            split_codec_specs, versioned_download_price)
from repro.core import LuarConfig, luar_init, luar_round
from repro.fl.client import ClientConfig, batched_local_updates
from repro.fl.server import ServerConfig, server_init, apply_update, broadcast_point
from repro.obs import (AGGREGATE, DISPATCH, M_COMM_RATIO, M_DISPATCHES,
                       M_DOWN_RATIO, M_DOWNLOAD_BYTES, M_FAIRNESS, M_UPLINKS,
                       M_UPLOAD_BYTES, RUN_END, RUN_START, Telemetry, UPLOAD,
                       fairness_from_metrics)
from repro.participate import (HT_CLIP, RoundContext, fairness_summary,
                               ht_weights, make_policy)

Params = Any


@dataclass
class FLConfig:
    n_clients: int = 128
    n_active: int = 32
    tau: int = 20
    batch_size: int = 32
    rounds: int = 50
    seed: int = 0
    client: ClientConfig = field(default_factory=ClientConfig)
    server: ServerConfig = field(default_factory=ServerConfig)
    luar: LuarConfig = field(default_factory=LuarConfig)
    # the upload compressor stack (repro.compress): a tuple of codec spec
    # strings, or one '+'-joined string ("fedpaq:4+topk:0.1+ef")
    codecs: tuple[str, ...] = ()
    # who trains each round (repro.participate): one policy spec string —
    # "uniform" (the legacy sampler, bit-for-bit), "powd:8",
    # "importance:norm", "avail:diurnal", "avail:bernoulli:0.1",
    # "energy:20" — biased policies are HT-reweighted in aggregation
    participation: str = "uniform"
    # DEPRECATED scalar flags (Tables 2/3 composition): shimmed onto the
    # equivalent codec pipeline; mutually exclusive with ``codecs``
    fedpaq_bits: int = 0            # 0 = off  -> "fedpaq:<bits>"
    lbgm_threshold: float = 0.0     # 0 = off  -> "lbgm:<threshold>"
    prune_keep: float = 0.0         # 0 = off  -> "prune:<keep>"
    dropout_rate: float = 0.0       # 0 = off  -> "dropout:<rate>"
    eval_every: int = 5


@dataclass
class FLResult:
    history: list[dict[str, float]] = field(default_factory=list)
    comm_ratio: float = 1.0          # uplink bytes vs FedAvg (same rounds)
    uploaded: float = 0.0            # cumulative client->server bytes (f64)
    n_uplinks_spent: int = 0         # uploads that crossed the wire (the
                                     # comm_ratio denominator; SimResult
                                     # parity — run_fl has no stragglers,
                                     # so every cohort member spends one)
    downloaded: float = 0.0          # cumulative server->client bytes (f64)
    down_ratio: float = 1.0          # downlink bytes vs full-model broadcast
    participation_count: np.ndarray | None = None   # per-client rounds
                                     # trained (biased-policy telemetry)
    fairness: dict[str, float] | None = None        # min/median/max of it
    agg_count: np.ndarray | None = None
    unit_names: tuple | None = None
    params: Any = None
    luar_state: Any = None


def resolve_codec_specs(cfg: FLConfig) -> tuple[str, ...]:
    """The effective codec stack of a config.

    ``cfg.codecs`` wins; the legacy scalar flags are shimmed onto the
    equivalent spec tuple (with a DeprecationWarning) in the exact order
    the old hard-coded stack applied them.  Mixing both is an error —
    there would be no defined composition order."""
    legacy = legacy_codec_specs(cfg.fedpaq_bits, cfg.prune_keep,
                                cfg.dropout_rate, cfg.lbgm_threshold)
    codecs = split_codec_specs(cfg.codecs)   # tuple of specs OR one
    if codecs:                               # '+'-joined string, both fine
        if legacy:
            raise ValueError(
                f"FLConfig mixes codecs={codecs} with legacy "
                f"compressor flags (equivalent to {legacy}); declare the "
                f"whole stack in `codecs`")
        return codecs
    if legacy:
        warnings.warn(
            f"FLConfig compressor flags are deprecated; use "
            f"codecs={legacy}", DeprecationWarning, stacklevel=3)
    return legacy


def build_codec_pipeline(cfg: FLConfig,
                         direction: Direction = Direction.UP) -> CodecPipeline:
    """A fresh pipeline for ONE link of this config (bind with
    ``init_state`` before encoding; see repro.compress.codec).  The
    ``down:``-prefixed specs in ``cfg.codecs`` form the DOWN pipeline;
    everything else is the UP pipeline."""
    return parse_codecs(resolve_codec_specs(cfg), direction)


def server_broadcast_additive(cfg: FLConfig) -> bool:
    """True when the broadcast evolves as ``x <- x + applied`` (fedavg /
    fedmut) — the regime where a delta-chain follower can derive recycled
    units from its own history, so ``down:delta`` steps price recycled
    units at scalar bytes.  Non-additive servers (fedopt's Adam state,
    fedacg's look-ahead) price delta steps dense, which degrades the
    versioned downlink gracefully to always-snapshot."""
    return cfg.server.kind in ("fedavg", "fedmut")


@lru_cache(maxsize=128)
def _pricing_pipeline(specs: tuple[str, ...]) -> CodecPipeline:
    """Cached UPLINK pipelines for HOST-SIDE PRICING ONLY (never
    init_state'd or encoded with, so sharing across models is safe)."""
    return parse_codecs(partition_codec_specs(specs)[0])


def _stack_client_batches(data: dict[str, np.ndarray], parts: list[np.ndarray],
                          cohort: np.ndarray, tau: int, bs: int, rng) -> dict[str, jnp.ndarray]:
    """(a, tau, bs, ...) batches sampled with replacement per client."""
    out: dict[str, list] = {k: [] for k in data}
    for c in cohort:
        idx = parts[c]
        sel = rng.choice(idx, size=(tau, bs), replace=True)
        for k, arr in data.items():
            out[k].append(arr[sel])
    return {k: jnp.asarray(np.stack(v)) for k, v in out.items()}


def init_codec_states(params, um, pipeline: CodecPipeline,
                      down_pipeline: CodecPipeline | None = None):
    """The opaque codec state a ``make_round_step`` body threads: the UP
    pipeline state alone, or an ``(up, down)`` pair when a non-empty DOWN
    pipeline is declared (the pair shape is private to the closure — the
    callers just thread whatever this returns)."""
    state = pipeline.init_state(params, um)
    if down_pipeline is not None and down_pipeline:
        state = (state, down_pipeline.init_state(params, um))
    return state


_DOWN_KEY_TAG = 0x0D0               # fold_in tag for the broadcast encode
                                    # (pure: never advances the round key)


def make_round_step(loss_fn: Callable[[Params, dict], jax.Array],
                    cfg: FLConfig, um, pipeline: CodecPipeline | None = None,
                    down_pipeline: CodecPipeline | None = None,
                    weighted: bool = False, want_loss: bool = True,
                    want_norm: bool = True,
                    fused_agg: bool | None = None) -> Callable:
    """Build the jitted synchronous round body (Alg. 2 lines 5-12).

    Shared by ``run_fl`` and by ``repro.sim``'s deadline engine so the
    event-driven simulator reproduces this trajectory bit-for-bit when
    heterogeneity is disabled: both paths run the SAME traced computation
    on the same cohort batches.

    ``pipeline`` is the UPLINK codec stack (built from ``cfg`` if
    omitted); its state is threaded through ``round_step`` as one pytree,
    and the returned ``aux`` tuple is the pricing evidence for
    ``client_payload_bytes_per_unit``.  In this synchronous form the
    pipeline encodes the cohort MEAN (one "virtual client" upload,
    priced once per active client) — the per-client form lives in the
    fedbuff engine.

    ``down_pipeline`` (non-empty) additionally runs the DOWNLINK stack on
    the broadcast point before local training, so a lossy broadcast codec
    (``down:fedpaq:8``) changes the numerics it prices; its server-side
    state rides inside ``codec_state`` (build it with
    ``init_codec_states``).  An empty/None down pipeline leaves the
    traced body EXACTLY as before — the bit-for-bit regression path.
    ``down:delta`` encodes as the identity (lossless transport), so it
    perturbs nothing either.

    ``weighted=True`` builds the HT-reweighted variant for biased
    participation policies (``repro.participate``): the body takes an
    extra per-client ``weights`` array (inverse inclusion probabilities,
    self-normalized inside the trace) replacing the plain cohort mean,
    and additionally returns ``obs = (losses, norms)`` — each client's
    loss at the broadcast point on its first local minibatch and its
    update's global norm, the host-side signals loss-tracking
    (``powd``) and norm-proportional (``importance``) policies feed on.
    ``want_loss``/``want_norm`` (the policy's ``wants_*`` flags) gate
    each signal: an unwanted one is ``None`` in ``obs`` and its
    computation never enters the trace.  The default ``weighted=False``
    trace is UNTOUCHED — the bit-for-bit replay path for
    ``participation="uniform"``.

    ``fused_agg`` (None = follow ``cfg.luar.fused_agg``) overrides the
    server-aggregation path: True routes ``luar_round`` through the
    batched multi-unit Pallas kernel, False forces the per-leaf
    reference.  The flag changes only HOW the round is computed, not
    what (fused vs reference agree to f32 accumulation order)."""
    pipeline = build_codec_pipeline(cfg) if pipeline is None else pipeline
    down = down_pipeline if (down_pipeline is not None and down_pipeline) else None
    lcfg = (cfg.luar if fused_agg is None
            else cfg.luar._replace(fused_agg=fused_agg))

    if not weighted:
        @jax.jit
        def round_step(params, luar_state, server_state, codec_state, batches, qkey):
            if down is None:
                up_state = codec_state
            else:
                up_state, down_state = codec_state
            start = broadcast_point(params, server_state, cfg.server)
            if down is not None:
                enc, down_state, _ = down.encode(
                    down_state, start, jax.random.fold_in(qkey, _DOWN_KEY_TAG))
                start = down.decode(down_state, enc)
            deltas = batched_local_updates(loss_fn, start, batches, cfg.client)
            fresh = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
            fresh, up_state, aux = pipeline.encode(up_state, fresh, qkey)
            applied, luar_state = luar_round(luar_state, um, lcfg, fresh, params)
            params, server_state = apply_update(params, applied, server_state, cfg.server)
            codec_state = up_state if down is None else (up_state, down_state)
            return params, luar_state, server_state, codec_state, aux

        return round_step

    @jax.jit
    def round_step_w(params, luar_state, server_state, codec_state, batches,
                     weights, qkey):
        if down is None:
            up_state = codec_state
        else:
            up_state, down_state = codec_state
        start = broadcast_point(params, server_state, cfg.server)
        if down is not None:
            enc, down_state, _ = down.encode(
                down_state, start, jax.random.fold_in(qkey, _DOWN_KEY_TAG))
            start = down.decode(down_state, enc)
        deltas = batched_local_updates(loss_fn, start, batches, cfg.client)
        # Hajek self-normalized HT estimate of the population-mean update
        wb = weights / jnp.sum(weights)
        fresh = jax.tree.map(
            lambda d: jnp.sum(d * wb.reshape((-1,) + (1,) * (d.ndim - 1)),
                              axis=0), deltas)
        # per-client policy signals: loss at the broadcast point on each
        # client's FIRST local minibatch, and the update's global norm
        losses = (jax.vmap(lambda b: loss_fn(start, b))(
            {k: v[:, 0] for k, v in batches.items()}) if want_loss else None)
        norms = (jnp.sqrt(sum(
            jnp.sum(jnp.square(d).reshape(d.shape[0], -1), axis=1)
            for d in jax.tree.leaves(deltas))) if want_norm else None)
        fresh, up_state, aux = pipeline.encode(up_state, fresh, qkey)
        applied, luar_state = luar_round(luar_state, um, lcfg, fresh, params)
        params, server_state = apply_update(params, applied, server_state, cfg.server)
        codec_state = up_state if down is None else (up_state, down_state)
        return params, luar_state, server_state, codec_state, aux, (losses, norms)

    return round_step_w


def client_payload_bytes_per_unit(sizes: np.ndarray, mask: np.ndarray,
                                  cfg: FLConfig,
                                  aux: tuple | None = None,
                                  pipeline: CodecPipeline | None = None
                                  ) -> np.ndarray:
    """ONE client's upload bytes this round, PER UNIT (host-side float64).

    ``mask`` must be the recycle mask the client actually DOWNLOADED at
    dispatch — under buffered async that can be several versions older
    than the server's current mask, and pricing against the current one
    would misattribute bytes (the wasted-upload ledger in ``repro.sim``
    is built on this distinction).  ``aux`` is the per-stage evidence
    tuple an ``encode`` pass returned (LBGM sent masks, top-k survivor
    counts); ``aux=None`` prices the conservative nominal."""
    if pipeline is None:
        pipeline = _pricing_pipeline(resolve_codec_specs(cfg))
    return pipeline.price_per_unit(sizes, mask, aux)


def client_payload_bytes(sizes: np.ndarray, mask: np.ndarray, cfg: FLConfig,
                         aux: tuple | None = None,
                         pipeline: CodecPipeline | None = None) -> float:
    """ONE client's upload bytes this round: units outside R_t, priced by
    the codec pipeline (host-side float64)."""
    return float(client_payload_bytes_per_unit(sizes, mask, cfg, aux,
                                               pipeline).sum())


def run_fl(loss_fn: Callable[[Params, dict], jax.Array],
           init_params: Params,
           data: dict[str, np.ndarray],
           parts: list[np.ndarray],
           cfg: FLConfig,
           eval_fn: Callable[[Params], dict[str, float]] | None = None,
           telemetry: Telemetry | None = None) -> FLResult:
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    pipeline = build_codec_pipeline(cfg)
    down_pipe = build_codec_pipeline(cfg, Direction.DOWN)
    codec_state = init_codec_states(params, um, pipeline, down_pipe)
    round_step = make_round_step(loss_fn, cfg, um, pipeline, down_pipe)
    step_w = None                    # HT-weighted variant, built on demand

    # telemetry (repro.obs): the byte ledgers LIVE in the registry now
    # (a Counter.add is the same host-f64 ``+=`` the retired inline
    # accumulators performed, so every derived field is bit-for-bit);
    # trace/profile channels are optional and gated
    tele = telemetry if telemetry is not None else Telemetry()
    m, tr = tele.metrics, tele.trace
    c_up = m.counter(M_UPLOAD_BYTES, "client->server wire bytes",
                     "bytes").labels()
    c_down = m.counter(M_DOWNLOAD_BYTES, "server->client wire bytes",
                       "bytes").labels()
    c_uplinks = m.counter(M_UPLINKS, "uploads that crossed the wire").labels()
    c_dispatches = m.counter(M_DISPATCHES, "downloads served").labels()

    # who trains each round is a policy decision (repro.participate); the
    # uniform policy consumes the learning rng exactly like the retired
    # hard-coded rng.choice, so the default replays bit-for-bit
    policy = make_policy(cfg.participation, cfg.n_clients, cfg.seed)
    all_ids = np.arange(cfg.n_clients)
    part_count = np.zeros(cfg.n_clients, np.int64)

    result = FLResult()
    sizes = np.asarray(um.unit_bytes, np.float64)
    n_units = len(um.names)
    total_bytes = sizes.sum()
    # uplinks spent == downloads served here: run_fl has no stragglers
    # or dropouts — both ledgers are registry counters now
    if tr:
        tr.emit(RUN_START, 0.0, engine="run_fl", n_clients=cfg.n_clients,
                rounds=cfg.rounds, n_units=n_units, units=list(um.names))

    def emit_eval(t: int) -> None:
        """One eval-cadence history row (shared by trained AND empty
        rounds, so the schema can never drift between them)."""
        if eval_fn is not None and ((t + 1) % cfg.eval_every == 0
                                    or t == cfg.rounds - 1):
            with tele.span("eval"):
                metrics = dict(eval_fn(params))
            metrics.update(round=t + 1, up_mb=c_up.value / 1e6,
                           comm_ratio=c_up.value / max(
                               total_bytes * c_uplinks.value, 1.0),
                           down_ratio=c_down.value / max(
                               total_bytes * c_dispatches.value, 1.0))
            result.history.append(metrics)
    # downlink versioning (down:delta): a cohort member that has been
    # dispatched before is exactly ONE version behind (every round's
    # broadcast reaches the subscribed population, so its cache stays
    # warm) and pays the single delta step t-1 -> t against the mask
    # that step applied; a FIRST CONTACT holds no base snapshot and pays
    # the cache-seeding full download.  Non-additive servers cannot let
    # clients derive recycled units, so versioning disables itself and
    # every download is the plain (unseeded) snapshot.
    additive = server_broadcast_additive(cfg)
    has_delta = down_pipe.has("delta") and additive
    seed_cache = has_delta and cfg.luar.mode == "recycle"
    no_mask = np.zeros(n_units, bool)
    prev_mask: np.ndarray | None = None
    seen: set = set()                # clients holding a base snapshot

    for t in range(cfg.rounds):
        sel = policy.select(RoundContext(
            rng=rng, n_clients=cfg.n_clients, cohort_size=cfg.n_active,
            candidates=all_ids, population=True, round=t, now=float(t),
            # run_fl has no clock: "now" is the round index, so the
            # diurnal phase lock defaults to ONE full cycle per run
            # (availability actually rotates) instead of the 600-virtual-
            # second scenario period that would freeze it here
            bw_period=float(max(cfg.rounds, 1))))
        cohort = np.asarray(sel.cohort, np.int64)
        np.add.at(part_count, cohort, 1)   # duplicates are separate draws
        for c in cohort:                   # energy depletion (unit cost:
            policy.observe_dispatch(int(c), now=float(t))  # no clock here)
        if len(cohort) == 0:
            # the policy found nobody eligible (e.g. the population's
            # batteries are flat): the model is unchanged this round, but
            # the eval cadence still reports
            emit_eval(t)
            continue
        batches = _stack_client_batches(data, parts, cohort, cfg.tau,
                                        cfg.batch_size, rng)
        key, qkey = jax.random.split(key)
        # upload accounting uses the CURRENT R_t (pre-round mask)
        mask_now = np.asarray(luar_state.mask)
        # downlink happens BEFORE local training: price this round's
        # broadcast per member (first contact vs one-step chain)
        with tele.span("pricing"):
            if has_delta:
                snap_pu = snapshot_price(sizes, mask_now, seed_cache)
                snap_bytes = down_pipe.price_bytes(
                    sizes, no_mask, down_pipe.aux_for("delta", snap_pu))
                chain = (delta_step_price(sizes, prev_mask)
                         if prev_mask is not None else None)
                chain_pu, _ = versioned_download_price(sizes, mask_now, chain,
                                                       seed_cache=seed_cache)
                chain_bytes = down_pipe.price_bytes(
                    sizes, no_mask, down_pipe.aux_for("delta", chain_pu))
                n_new = 0
                for c in cohort:
                    if int(c) not in seen:
                        n_new += 1
                        seen.add(int(c))
                down_round = (snap_bytes * n_new
                              + chain_bytes * (len(cohort) - n_new))
            else:
                n_new = 0
                down_round = down_pipe.price_bytes(sizes, no_mask,
                                                   None) * len(cohort)
        c_down.add(down_round)
        c_dispatches.add(len(cohort))
        if tr:
            tr.emit(DISPATCH, float(t), round=t, version=t,
                    cohort=[int(c) for c in cohort],
                    down_bytes=down_round, first_contacts=n_new)
        with tele.span("round_step", jitted=True):
            if sel.uniform:
                # equal weights: the exact (unweighted-mean) legacy trace
                params, luar_state, server_state, codec_state, aux = round_step(
                    params, luar_state, server_state, codec_state, batches, qkey)
                obs = None
            else:
                if step_w is None:
                    step_w = make_round_step(loss_fn, cfg, um, pipeline,
                                             down_pipe, weighted=True,
                                             want_loss=policy.wants_loss,
                                             want_norm=policy.wants_update_norm)
                w = jnp.asarray(ht_weights(sel, clip=HT_CLIP), jnp.float32)
                (params, luar_state, server_state, codec_state, aux,
                 obs) = step_w(params, luar_state, server_state, codec_state,
                               batches, w, qkey)
        with tele.span("pricing"):
            up_client = client_payload_bytes(sizes, mask_now, cfg, aux,
                                             pipeline)
        c_up.add(up_client * len(cohort))
        c_uplinks.add(len(cohort))
        if tr:
            tr.emit(UPLOAD, float(t), round=t, n=len(cohort),
                    bytes_per_client=up_client, lag=0, status="accepted")
            tr.emit(AGGREGATE, float(t), round=t, version=t + 1,
                    n=len(cohort),
                    recycled=[int(i) for i in np.flatnonzero(mask_now)])
        prev_mask = mask_now
        if obs is not None:
            losses, norms = (None if o is None else np.asarray(o, np.float64)
                             for o in obs)
            policy.observe_round(cohort, losses, norms, now=float(t))

        emit_eval(t)

    # result fields derive FROM the registry (same f64 accumulation order
    # as the retired inline ledgers — bit-for-bit, tested)
    m.gauge(M_COMM_RATIO, "uplink bytes vs FedAvg same-uplinks").set(
        c_up.value / max(total_bytes * c_uplinks.value, 1.0))
    m.gauge(M_DOWN_RATIO, "downlink bytes vs full-model broadcast").set(
        c_down.value / max(total_bytes * c_dispatches.value, 1.0))
    fair = fairness_summary(part_count)
    g_fair = m.gauge(M_FAIRNESS, "participation spread across clients")
    for stat, v in fair.items():
        g_fair.labels(stat=stat).set(v)
    result.comm_ratio = m.value(M_COMM_RATIO)
    result.uploaded = c_up.value
    result.n_uplinks_spent = int(c_uplinks.value)
    result.downloaded = c_down.value
    result.down_ratio = m.value(M_DOWN_RATIO)
    result.participation_count = part_count
    result.fairness = fairness_from_metrics(m)
    result.agg_count = np.asarray(luar_state.agg_count)
    result.unit_names = um.names
    result.params = params
    result.luar_state = luar_state
    if tr:
        tr.emit(RUN_END, float(cfg.rounds), uploaded=c_up.value,
                downloaded=c_down.value, comm_ratio=result.comm_ratio,
                down_ratio=result.down_ratio,
                n_uplinks=int(c_uplinks.value))
    return result
