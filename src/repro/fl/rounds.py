"""Algorithm 2 — the FedLUAR round engine (simulation form).

One jitted ``round_step`` does: broadcast -> vmap'd client local training
(tau SGD steps each) -> cohort mean -> LUAR (Alg. 1) -> server optimizer.
The host loop only samples cohorts and minibatch indices (numpy RNG) and
tracks communication bytes.

At pod scale the same algorithm runs through launch/steps.py with the
cohort mapped onto mesh axes; this module is the single-host simulator
used by tests, benchmarks and examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (LuarConfig, luar_init, luar_round, payload_scale)
from repro.fl import baselines
from repro.fl.client import ClientConfig, batched_local_updates
from repro.fl.server import ServerConfig, server_init, apply_update, broadcast_point, mutate

Params = Any


@dataclass
class FLConfig:
    n_clients: int = 128
    n_active: int = 32
    tau: int = 20
    batch_size: int = 32
    rounds: int = 50
    seed: int = 0
    client: ClientConfig = field(default_factory=ClientConfig)
    server: ServerConfig = field(default_factory=ServerConfig)
    luar: LuarConfig = field(default_factory=LuarConfig)
    # extra baselines composable with LUAR (Tables 2/3)
    fedpaq_bits: int = 0            # 0 = off
    lbgm_threshold: float = 0.0     # 0 = off
    prune_keep: float = 0.0         # PruneFL-style magnitude keep-fraction
    dropout_rate: float = 0.0       # FedDropoutAvg fdr
    eval_every: int = 5


@dataclass
class FLResult:
    history: List[Dict[str, float]] = field(default_factory=list)
    comm_ratio: float = 1.0
    agg_count: Optional[np.ndarray] = None
    unit_names: Optional[tuple] = None
    params: Any = None
    luar_state: Any = None


def _stack_client_batches(data: Dict[str, np.ndarray], parts: List[np.ndarray],
                          cohort: np.ndarray, tau: int, bs: int, rng) -> Dict[str, jnp.ndarray]:
    """(a, tau, bs, ...) batches sampled with replacement per client."""
    out: Dict[str, list] = {k: [] for k in data}
    for c in cohort:
        idx = parts[c]
        sel = rng.choice(idx, size=(tau, bs), replace=True)
        for k, arr in data.items():
            out[k].append(arr[sel])
    return {k: jnp.asarray(np.stack(v)) for k, v in out.items()}


def apply_compressors(update: Params, qkey, cfg: FLConfig) -> Params:
    """The orthogonal upload-compressor stack (FedPAQ/PruneFL/DropoutAvg),
    applied identically on the synchronous and buffered-async paths —
    ``payload_scale`` prices exactly this sequence."""
    if cfg.fedpaq_bits:
        update = baselines.fedpaq_quantize(update, qkey, cfg.fedpaq_bits)
    if cfg.prune_keep:
        update = baselines.magnitude_prune(update, cfg.prune_keep)
    if cfg.dropout_rate:
        update = baselines.dropout_avg(update, qkey, cfg.dropout_rate)
    return update


def make_round_step(loss_fn: Callable[[Params, Dict], jax.Array],
                    cfg: FLConfig, um) -> Callable:
    """Build the jitted synchronous round body (Alg. 2 lines 5-12).

    Shared by ``run_fl`` and by ``repro.sim``'s deadline engine so the
    event-driven simulator reproduces this trajectory bit-for-bit when
    heterogeneity is disabled: both paths run the SAME traced computation
    on the same cohort batches."""

    @jax.jit
    def round_step(params, luar_state, server_state, lbgm_state, batches, qkey):
        start = broadcast_point(params, server_state, cfg.server)
        deltas = batched_local_updates(loss_fn, start, batches, cfg.client)
        fresh = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        fresh = apply_compressors(fresh, qkey, cfg)
        lbgm_sent = None
        if cfg.lbgm_threshold:
            fresh, lbgm_state, lbgm_sent = baselines.lbgm_round(
                lbgm_state, um, fresh, cfg.lbgm_threshold)
        applied, luar_state = luar_round(luar_state, um, cfg.luar, fresh, params)
        params, server_state = apply_update(params, applied, server_state, cfg.server)
        return params, luar_state, server_state, lbgm_state, lbgm_sent

    return round_step


def client_payload_bytes_per_unit(sizes: np.ndarray, mask: np.ndarray,
                                  cfg: FLConfig,
                                  lbgm_sent: Optional[np.ndarray] = None) -> np.ndarray:
    """ONE client's upload bytes this round, PER UNIT (host-side float64).

    ``mask`` must be the recycle mask the client actually DOWNLOADED at
    dispatch — under buffered async that can be several versions older
    than the server's current mask, and pricing against the current one
    would misattribute bytes (the wasted-upload ledger in ``repro.sim``
    is built on this distinction).  LBGM units that only ship a scalar
    coefficient cost 4 bytes."""
    up = ~np.asarray(mask, bool)
    scale = payload_scale(cfg.fedpaq_bits, cfg.prune_keep, cfg.dropout_rate)
    per_unit = np.where(up, np.asarray(sizes, np.float64) * scale, 0.0)
    if lbgm_sent is not None:
        sent = np.asarray(lbgm_sent, bool)
        per_unit = np.where(up & ~sent, 4.0, per_unit)
    return per_unit


def client_payload_bytes(sizes: np.ndarray, mask: np.ndarray, cfg: FLConfig,
                         lbgm_sent: Optional[np.ndarray] = None) -> float:
    """ONE client's upload bytes this round: units outside R_t, shrunk by
    the orthogonal compressor stack (host-side float64)."""
    return float(client_payload_bytes_per_unit(sizes, mask, cfg, lbgm_sent).sum())


def run_fl(loss_fn: Callable[[Params, Dict], jax.Array],
           init_params: Params,
           data: Dict[str, np.ndarray],
           parts: List[np.ndarray],
           cfg: FLConfig,
           eval_fn: Optional[Callable[[Params], Dict[str, float]]] = None) -> FLResult:
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    key, k1, k2 = jax.random.split(key, 3)

    params = init_params
    luar_state, um = luar_init(params, cfg.luar, k1)
    server_state = server_init(params, cfg.server, k2)
    lbgm_state = baselines.lbgm_init(params, um) if cfg.lbgm_threshold else None
    round_step = make_round_step(loss_fn, cfg, um)

    result = FLResult()
    sizes = np.asarray(um.unit_bytes, np.float64)
    total_bytes = sizes.sum()
    uploaded = 0.0
    full_per_round = total_bytes * cfg.n_active

    for t in range(cfg.rounds):
        cohort = rng.choice(cfg.n_clients, size=cfg.n_active, replace=False)
        batches = _stack_client_batches(data, parts, cohort, cfg.tau,
                                        cfg.batch_size, rng)
        key, qkey = jax.random.split(key)
        # upload accounting uses the CURRENT R_t (pre-round mask)
        mask_now = np.asarray(luar_state.mask)
        params, luar_state, server_state, lbgm_state, lbgm_sent = round_step(
            params, luar_state, server_state, lbgm_state, batches, qkey)
        uploaded += client_payload_bytes(sizes, mask_now, cfg,
                                         lbgm_sent) * cfg.n_active

        if eval_fn is not None and ((t + 1) % cfg.eval_every == 0 or t == cfg.rounds - 1):
            metrics = dict(eval_fn(params))
            metrics.update(round=t + 1,
                           comm_ratio=uploaded / (full_per_round * (t + 1)))
            result.history.append(metrics)

    result.comm_ratio = uploaded / (full_per_round * cfg.rounds)
    result.agg_count = np.asarray(luar_state.agg_count)
    result.unit_names = um.names
    result.params = params
    result.luar_state = luar_state
    return result
