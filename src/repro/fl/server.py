"""Server-side federated optimizers applied to the LUAR-aggregated global
update \\hat{Delta}_t (Section 4.2 — LUAR is agnostic to the optimizer):

  fedavg : x <- x + Delta-hat
  fedopt : server Adam on the pseudo-gradient -Delta-hat (Reddi et al.)
  fedacg : global-momentum acceleration; the server broadcasts the
           look-ahead point x + lam*m and accumulates m <- lam*m + Delta.
  fedmut : after the update, per-cohort mutation seeds are derived by
           adding +/- alpha * Delta-hat with random per-layer signs.
"""
from __future__ import annotations
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import optim

Params = Any


class ServerConfig(NamedTuple):
    kind: str = "fedavg"            # fedavg | fedopt | fedacg | fedmut
    lr: float = 1.0                 # server learning rate (fedopt)
    acg_lambda: float = 0.7         # FedACG momentum
    mut_alpha: float = 0.5          # FedMut mutation scale


class ServerState(NamedTuple):
    adam: optim.AdamState | None
    momentum: Params | None
    key: jax.Array


def server_init(params: Params, cfg: ServerConfig, key) -> ServerState:
    adam = optim.adam_init(params) if cfg.kind == "fedopt" else None
    mom = (jax.tree.map(jnp.zeros_like, params)
           if cfg.kind in ("fedacg",) else None)
    return ServerState(adam, mom, key)


def broadcast_point(params: Params, state: ServerState, cfg: ServerConfig) -> Params:
    """What the server sends to clients (FedACG sends a look-ahead)."""
    if cfg.kind == "fedacg":
        return jax.tree.map(lambda p, m: p + cfg.acg_lambda * m, params, state.momentum)
    return params


def apply_update(params: Params, applied: Params, state: ServerState,
                 cfg: ServerConfig) -> tuple[Params, ServerState]:
    """x_{t+1} = server_opt(x_t, Delta-hat_t)   (Alg. 2 line 12)."""
    key, sub = jax.random.split(state.key)
    if cfg.kind == "fedavg" or cfg.kind == "fedmut":
        new_p = jax.tree.map(lambda p, d: p + d, params, applied)
        return new_p, state._replace(key=key)
    if cfg.kind == "fedopt":
        pseudo_grad = jax.tree.map(lambda d: -d, applied)
        new_p, adam = optim.adam_update(params, pseudo_grad, state.adam, lr=cfg.lr)
        return new_p, ServerState(adam, state.momentum, key)
    if cfg.kind == "fedacg":
        mom = jax.tree.map(lambda m, d: cfg.acg_lambda * m + d,
                           state.momentum, applied)
        new_p = jax.tree.map(lambda p, m: p + m, params, mom)
        return new_p, ServerState(state.adam, mom, key)
    raise ValueError(f"unknown server optimizer {cfg.kind!r}")


def mutate(params: Params, applied: Params, key, alpha: float) -> Params:
    """FedMut-style mutation of the broadcast model (simplified: one
    mutated seed; the sign flips per parameter tensor)."""
    leaves, treedef = jax.tree.flatten(params)
    d_leaves = jax.tree.leaves(applied)
    keys = jax.random.split(key, len(leaves))
    out = [p + alpha * jnp.where(jax.random.bernoulli(k), 1.0, -1.0) * d
           for p, d, k in zip(leaves, d_leaves, keys)]
    return jax.tree.unflatten(treedef, out)
