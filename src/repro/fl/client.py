"""Client-side local training: tau mini-batch SGD(+momentum) steps
(Alg. 2 lines 6-10), returning the accumulated update
Delta_t^i = x_{t,tau}^i - x_{t,0}^i.

Supports the FedProx proximal term and MOON-free advanced-optimizer
hooks (the server side lives in fl/server.py).
"""
from __future__ import annotations
from typing import Any, NamedTuple
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro import optim

Params = Any


class ClientConfig(NamedTuple):
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0
    prox_mu: float = 0.0            # FedProx


def local_update(loss_fn: Callable[[Params, dict], jax.Array],
                 params: Params, batches: dict[str, jax.Array],
                 cfg: ClientConfig) -> Params:
    """Run tau local steps.  ``batches`` arrays are (tau, ...) stacked.

    Returns Delta^i (same pytree as params)."""
    x0 = params

    def loss_with_prox(p, batch):
        loss = loss_fn(p, batch)
        if cfg.prox_mu:
            sq = sum(jnp.sum(jnp.square(a - b)) for a, b in
                     zip(jax.tree.leaves(p), jax.tree.leaves(x0)))
            loss = loss + 0.5 * cfg.prox_mu * sq
        return loss

    grad_fn = jax.grad(loss_with_prox)

    def step(carry, batch):
        p, opt = carry
        g = grad_fn(p, batch)
        p, opt = optim.sgd_update(p, g, opt, lr=cfg.lr, momentum=cfg.momentum,
                                  weight_decay=cfg.weight_decay)
        return (p, opt), None

    (p_final, _), _ = jax.lax.scan(step, (params, optim.sgd_init(params)), batches)
    return jax.tree.map(lambda a, b: a - b, p_final, x0)


def batched_local_updates(loss_fn, params: Params,
                          client_batches: dict[str, jax.Array],
                          cfg: ClientConfig) -> Params:
    """vmap over the active cohort.  client_batches arrays: (a, tau, ...).
    Returns stacked Delta^i with leading axis a."""
    fn = lambda b: local_update(loss_fn, params, b, cfg)
    return jax.vmap(fn)(client_batches)
