"""Jitted public wrappers for the Pallas kernels.

On this CPU container ``interpret=True`` (set via ``REPRO_INTERPRET=1``
or the explicit argument) executes the kernel bodies in Python for
validation; on a real TPU the same calls lower to Mosaic.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import luar_agg as _la
from repro.kernels import ssd_scan as _ss


def _default_interpret() -> bool:
    if os.environ.get("REPRO_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    fn = partial(_fa.flash_attention, causal=causal, window=window,
                 block_q=block_q, block_k=block_k, interpret=interpret)
    return jax.jit(fn)(q, k, v)


def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    fn = partial(_ss.ssd_scan, chunk=chunk, interpret=interpret)
    return jax.jit(fn)(x, dt, A, Bm, Cm, D)


def luar_agg(delta, x, recycled, use_recycled, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    fn = partial(_la.luar_agg, interpret=interpret)
    return jax.jit(fn)(delta, x, recycled, use_recycled)


def luar_agg_batched(delta_leaves, x_leaves, prev_leaves, leaf_unit, *,
                     wn, a_prev, a_fresh, block_rows=64, interpret=None):
    """Whole-round fused LUAR aggregation (all units, one Pallas pass).

    Takes the plain ``UnitMap.leaf_unit`` tuple (not the UnitMap itself,
    so the kernel layer stays import-independent of ``repro.core``).
    Jit-compatible: callers inside a trace call it directly; this
    wrapper exists for standalone use."""
    interpret = _default_interpret() if interpret is None else interpret
    fn = partial(_la.luar_agg_batched, leaf_unit=tuple(leaf_unit),
                 block_rows=block_rows, interpret=interpret)
    return jax.jit(fn)(delta_leaves, x_leaves, prev_leaves,
                       wn=wn, a_prev=a_prev, a_fresh=a_fresh)
