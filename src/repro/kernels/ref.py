"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, window: int = 0) -> jax.Array:
    """q (B,H,Sq,hd), k/v (B,K,Skv,hd).  GQA-aware naive attention."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    dpos = jnp.arange(Sq)[:, None] - jnp.arange(Skv)[None, :] + (Skv - Sq)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= dpos >= 0
    if window > 0:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[None, None, None, :, None], p, 0.0)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, D, initial_state=None):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x (B,S,nh,P), dt (B,S,nh), A (nh,), Bm/Cm (B,S,N), D (nh,).
    Returns (y (B,S,nh,P), final_state (B,nh,P,N))."""
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    s0 = (jnp.zeros((Bsz, nh, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (B,nh,P),(B,nh),(B,N),(B,N)
        a = jnp.exp(dtt * A)                        # (B,nh)
        state = a[..., None, None] * state + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct) + D[None, :, None] * xt
        return state, y

    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bm.astype(f32), 1, 0), jnp.moveaxis(Cm.astype(f32), 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def luar_agg_ref(delta: jax.Array, x: jax.Array, recycled: jax.Array,
                 use_recycled: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused server-side LUAR op for one layer: select the applied update
    and produce the squared norms for Eq. (1)'s s_{t,l}.

    delta/x/recycled: same shape.  use_recycled: scalar bool/float.
    Returns (applied_update, ||applied||^2, ||x||^2)."""
    applied = jnp.where(use_recycled > 0, recycled, delta)
    d2 = jnp.sum(jnp.square(applied.astype(jnp.float32)))
    x2 = jnp.sum(jnp.square(x.astype(jnp.float32)))
    return applied, d2, x2


def luar_agg_batched_ref(delta_leaves, x_leaves, prev_leaves, leaf_unit, *,
                         wn, a_prev, a_fresh):
    """Oracle for ``luar_agg_batched``: the whole-round merge+select+norms.

    Per unit u:  applied_u = a_prev[u] * prev_u + a_fresh[u] * sum_k
    wn[k,u] * delta_ku, plus ||applied_u||^2 and ||x_u||^2.  delta
    leaves carry a leading K axis; ``leaf_unit`` accepts plain ints and
    (start, L) stacked entries like ``UnitMap.leaf_unit``.  All math in
    f32; applied leaves are cast back to the x-leaf dtypes (matching the
    kernel's pack/unpack round trip)."""
    f32 = jnp.float32
    n = 0
    for u in leaf_unit:
        n = max(n, u[0] + u[1] if isinstance(u, tuple) else u + 1)
    wn = wn.astype(f32)
    a_prev = a_prev.astype(f32)
    a_fresh = a_fresh.astype(f32)
    d2 = [jnp.zeros((), f32) for _ in range(n)]
    x2 = [jnp.zeros((), f32) for _ in range(n)]
    out = []
    for u, d, x, p in zip(leaf_unit, delta_leaves, x_leaves, prev_leaves):
        d, p, xf = d.astype(f32), p.astype(f32), x.astype(f32)
        if isinstance(u, tuple):
            start, L = u
            tail = (1,) * (d.ndim - 2)
            wb = wn[:, start:start + L].reshape((-1, L) + tail)
            merged = jnp.sum(d * wb, axis=0)
            ap = a_prev[start:start + L].reshape((L,) + tail)
            af = a_fresh[start:start + L].reshape((L,) + tail)
            applied = ap * p + af * merged
            dd = jnp.sum(jnp.square(applied).reshape(L, -1), axis=1)
            xx = jnp.sum(jnp.square(xf).reshape(L, -1), axis=1)
            for i in range(L):
                d2[start + i] = d2[start + i] + dd[i]
                x2[start + i] = x2[start + i] + xx[i]
        else:
            wb = wn[:, u].reshape((-1,) + (1,) * (d.ndim - 1))
            merged = jnp.sum(d * wb, axis=0)
            applied = a_prev[u] * p + a_fresh[u] * merged
            d2[u] = d2[u] + jnp.sum(jnp.square(applied))
            x2[u] = x2[u] + jnp.sum(jnp.square(xf))
        out.append(applied.astype(x.dtype))
    return out, jnp.stack(d2), jnp.stack(x2)
