"""Flash attention Pallas TPU kernel (prefill/train hot spot).

Grid is (B*H, Sq/bq, Skv/bk); the KV axis is innermost and carries the
online-softmax accumulators in VMEM scratch across grid steps.  GQA is
handled in the K/V index maps (no materialised head broadcast).  Block
shapes are (8,128)-aligned for the MXU/VREG layout; the TPU is the
target — on this CPU container the kernel runs under interpret=True and
is validated against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, nkv: int, causal: bool, window: int,
            scale: float):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                       # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = alpha[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == nkv - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,H,Sq,hd), k/v (B,K,Skv,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "block must divide sequence"
    nq, nkv = Sq // bq, Skv // bk

    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * K, Skv, hd)
    vf = v.reshape(B * K, Skv, hd)

    def kv_idx(bh, qi, kj):
        return ((bh // H) * K + (bh % H) // G, kj, 0)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nkv=nkv, causal=causal, window=window,
        scale=1.0 / math.sqrt(hd))
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_idx),
            pl.BlockSpec((1, bk, hd), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
