"""Mamba2 SSD chunk-scan Pallas TPU kernel.

Grid is (B*nh, S/T): the chunk axis is innermost/sequential and the
(P, N) state lives in VMEM scratch across chunks.  Within a chunk the
quadratic dual form runs on the MXU ((T,T) and (T,P)x(P,N) matmuls); the
inter-chunk recurrence is one rank-T update.  B/C projections are shared
across heads (n_groups=1) via the index map.  Validated under
interpret=True against ref.ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams


def _kernel(x_ref, dt_ref, a_ref, d_ref, b_ref, c_ref,
            y_ref, state_ref, s_scr, *, T: int, nc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)                      # (T, P)
    dt = dt_ref[0].astype(jnp.float32)                    # (T,)
    A = a_ref[0].astype(jnp.float32)                      # ()
    D = d_ref[0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)                     # (T, N)
    Cm = c_ref[0].astype(jnp.float32)

    a = dt * A                                            # (T,) <= 0
    cum = jnp.cumsum(a)
    seg = cum[:, None] - cum[None, :]                     # (T, T)
    ii = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    W = scores * L * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    state = s_scr[...]                                    # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y + D * x
    y_ref[0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum) * dt               # (T,)
    upd = jax.lax.dot_general(x * decay_end[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    s_scr[...] = jnp.exp(cum[-1]) * state + upd

    @pl.when(c == nc - 1)
    def _flush():
        state_ref[0] = s_scr[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array, D: jax.Array,
             *, chunk: int = 128, interpret: bool = False):
    """x (B,S,nh,P), dt (B,S,nh), A/D (nh,), Bm/Cm (B,S,N).

    Returns (y (B,S,nh,P), final_state (B,nh,P,N))."""
    B, S, nh, P = x.shape
    N = Bm.shape[-1]
    T = min(chunk, S)
    assert S % T == 0, "chunk must divide sequence"
    nc = S // T

    xf = jnp.moveaxis(x, 2, 1).reshape(B * nh, S, P)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(B * nh, S)
    Af = jnp.broadcast_to(A[None], (B, nh)).reshape(B * nh)
    Df = jnp.broadcast_to(D[None], (B, nh)).reshape(B * nh)

    y, state = pl.pallas_call(
        functools.partial(_kernel, T=T, nc=nc),
        grid=(B * nh, nc),
        in_specs=[
            pl.BlockSpec((1, T, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, T), lambda h, c: (h, c)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1, T, N), lambda h, c: (h // nh, c, 0)),
            pl.BlockSpec((1, T, N), lambda h, c: (h // nh, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nh, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * nh, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, dtf, Af, Df, Bm, Cm)
    y = jnp.moveaxis(y.reshape(B, nh, S, P), 1, 2)
    return y, state.reshape(B, nh, P, N)
