"""Fused LUAR server-side aggregation kernels (the paper's hot spot).

Single-layer form (``luar_agg``): per layer and per round the server
needs three HBM sweeps over the layer's update: (a) select
recycled-vs-fresh update, (b) ||applied||^2 and (c) ||x||^2 for the
Eq. (1) metric s_{t,l}.  The kernel fuses them into ONE pass: each
(8,128)-aligned tile is read once, the select is written, and the two
squared norms accumulate in SMEM across the grid.

Batched multi-unit form (``luar_agg_batched``): the whole server round
in ONE Pallas sweep instead of one call per leaf.  All units' flattened
leaves are packed into a single (8,128)-aligned f32 buffer — each unit
owns a contiguous block-aligned row range — and a scalar-prefetched
per-grid-step segment map tells every block which unit it belongs to
(so the per-unit output index maps can read it).  Per block the kernel

  * reduces the K buffered client deltas with per-(client, unit) merge
    weights ``wn`` — the staleness-discount x HT x validity
    normalization is O(K x n_units) scalars, precomputed host-side and
    held in SMEM;
  * forms  applied = a_prev[u] * prev + a_fresh[u] * merged, two
    per-unit scalars that express every recycled / fresh / fallback /
    drop-mode / FedAsync-eta combination (see core/recycle.py);
  * accumulates the per-unit ||applied||^2 and ||x||^2 for Eq. (1) into
    (n_units, 1) outputs whose block index follows the segment map.
    Units are row-contiguous, so each output block is revisited only by
    CONSECUTIVE grid steps — the legal Pallas accumulation pattern —
    and a per-step ``first`` flag zero-initializes each unit's
    accumulator when its first block arrives.

One read of every operand and one write of the applied update replace
the 4+ separate passes of the per-leaf reference path (merge select,
s-metric and grad-norm tree_maps each sweep the full model through HBM).

All math happens in f32 regardless of storage dtype, but STORAGE is
dtype-bucketed: leaves whose delta/x/prev are all bf16 pack into a
separate bf16 buffer ((16, 128) tiles — bf16's minimum sublane is 16)
while everything else upcasts into the f32 buffer as before.  Each
bucket runs its own sweep over the FULL unit-id space (a unit absent
from a bucket gets one zero block, contributing 0 to its norms) and the
per-unit ||applied||^2 / ||x||^2 accumulators are summed across
buckets.  bf16 -> f32 is exact, so bucketing changes no numerics — only
HBM bytes: a bf16 model moves half the traffic the old always-f32 pack
did.  An all-f32 model takes the single-bucket path, bit-identical to
the pre-bucket kernel.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, NamedTuple
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams

_LANES = 128
_ROWS = 8
_BF16_ROWS = 16                 # bf16 minimum sublane tile is (16, 128)

LeafUnit = int | tuple[int, int]


def _block_rows_for(pad_rows: int, block_rows: int) -> int:
    """Largest (8-row aligned) block height that divides ``pad_rows``.

    The old ``while pad_rows % bt: bt //= 2`` shrink was broken at edge
    shapes: an odd ``block_rows`` (or repeated halving) could leave a bt
    that is not a multiple of the 8-row sublane tile — or 0 — and Mosaic
    rejects (or worse, mispads) such blocks.  pad_rows is always a
    multiple of 8, so stepping DOWN by 8 from the aligned candidate
    always terminates at a legal divisor (worst case bt = 8).
    """
    bt = min(block_rows, pad_rows)
    bt -= bt % _ROWS                    # align to the (8, 128) tile
    bt = max(bt, _ROWS)
    while pad_rows % bt:
        bt -= _ROWS
    return bt


def _kernel(mask_ref, d_ref, x_ref, r_ref, o_ref, d2_ref, x2_ref, acc_scr):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    use_recycled = mask_ref[0] > 0
    d = d_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    applied = jnp.where(use_recycled, r, d)
    o_ref[...] = applied.astype(o_ref.dtype)
    acc_scr[0, 0] += jnp.sum(applied * applied)
    acc_scr[0, 1] += jnp.sum(x * x)

    @pl.when(i == n - 1)
    def _flush():
        d2_ref[0, 0] = acc_scr[0, 0]
        x2_ref[0, 0] = acc_scr[0, 1]


def luar_agg(delta: jax.Array, x: jax.Array, recycled: jax.Array,
             use_recycled: jax.Array, *, block_rows: int = 256,
             interpret: bool = False):
    """Flat-or-any-shape single-layer LUAR aggregation.

    Returns (applied_update (same shape), ||applied||^2, ||x||^2)."""
    shape, dtype = delta.shape, delta.dtype
    flat = delta.reshape(-1)
    n = flat.shape[0]
    width = _LANES
    rows = -(-n // width)
    pad_rows = -(-rows // _ROWS) * _ROWS
    bt = _block_rows_for(pad_rows, block_rows)
    grid = pad_rows // bt

    def prep(a):
        f = a.reshape(-1).astype(jnp.float32)
        f = jnp.pad(f, (0, pad_rows * width - n))
        return f.reshape(pad_rows, width)

    mask = (use_recycled > 0).astype(jnp.int32).reshape(1)
    out, d2, x2 = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pad_rows, width), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 2), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(mask, prep(delta), prep(x), prep(recycled))
    applied = out.reshape(-1)[:n].reshape(shape).astype(dtype)
    return applied, d2[0, 0], x2[0, 0]


# ---------------------------------------------------------------------------
# Batched multi-unit fused round
# ---------------------------------------------------------------------------


class PackLayout(NamedTuple):
    """Static packing plan for one (leaf_unit, shapes, block_rows) triple.

    Segment-packed layout: the flat f32 buffer is (total_rows, 128) with
    each unit occupying ``unit_rows[u]`` CONTIGUOUS rows starting at
    ``unit_row_start[u]`` (rows per unit are a multiple of the kernel
    block height, so no block straddles two units).  A leaf that maps to
    several units (stacked "depth" leaves) is scattered across its
    units' regions; ``leaf_parts`` records the flat-element offsets to
    gather it back.
    """
    n_units: int
    block_rows: int
    total_rows: int
    grid: int
    unit_rows: tuple[int, ...]
    unit_row_start: tuple[int, ...]
    # per unit: ((leaf_idx, depth_idx|None, size), ...) in pack order
    unit_pieces: tuple[tuple[tuple[int, int | None, int], ...], ...]
    # per leaf: ((depth_idx|None, flat_elem_offset, size), ...)
    leaf_parts: tuple[tuple[tuple[int | None, int, int], ...], ...]
    seg: tuple[int, ...]                # grid step -> unit id
    first: tuple[int, ...]              # 1 on a unit's first grid step


def leaf_unit_count(leaf_unit: Sequence[LeafUnit]) -> int:
    n = 0
    for u in leaf_unit:
        n = max(n, u[0] + u[1] if isinstance(u, tuple) else u + 1)
    return n


@lru_cache(maxsize=128)
def build_pack_layout(leaf_unit: tuple[LeafUnit, ...],
                      shapes: tuple[tuple[int, ...], ...],
                      block_rows: int = 64, n_units: int | None = None,
                      sublane: int = _ROWS) -> PackLayout:
    """Plan the segment-packed buffer (cached: pure shape metadata).

    ``n_units`` forces the unit-id space (a dtype bucket holding only
    SOME leaves must still emit per-unit norm rows for every unit so the
    buckets' accumulators align — absent units get one zero block).
    ``sublane`` is the dtype's minimum sublane tile: 8 for f32 packs,
    16 for bf16.
    """
    if block_rows % sublane:
        block_rows = max(sublane, block_rows - block_rows % sublane)
    n = leaf_unit_count(leaf_unit) if n_units is None else n_units
    pieces: list[list[tuple[int, int | None, int]]] = [[] for _ in range(n)]
    for li, (u, shape) in enumerate(zip(leaf_unit, shapes)):
        size = int(np.prod(shape)) if shape else 1
        if isinstance(u, tuple):
            start, L = u
            per = size // L
            for i in range(L):
                pieces[start + i].append((li, i, per))
        else:
            pieces[u].append((li, None, size))
    unit_rows: list[int] = []
    unit_row_start: list[int] = []
    leaf_parts: list[list[tuple[int | None, int, int]]] = \
        [[] for _ in leaf_unit]
    seg: list[int] = []
    first: list[int] = []
    row = 0
    for u in range(n):
        elems = sum(sz for _, _, sz in pieces[u])
        # every unit is padded to a whole number of kernel blocks so the
        # (1,1) per-unit norm accumulators are revisited consecutively
        blocks = max(1, -(-elems // (block_rows * _LANES)))
        unit_row_start.append(row)
        unit_rows.append(blocks * block_rows)
        off = row * _LANES
        for li, di, sz in pieces[u]:
            leaf_parts[li].append((di, off, sz))
            off += sz
        seg.extend([u] * blocks)
        first.extend([1] + [0] * (blocks - 1))
        row += blocks * block_rows
    return PackLayout(
        n_units=n, block_rows=block_rows, total_rows=row,
        grid=len(seg),
        unit_rows=tuple(unit_rows), unit_row_start=tuple(unit_row_start),
        unit_pieces=tuple(tuple(p) for p in pieces),
        leaf_parts=tuple(tuple(p) for p in leaf_parts),
        seg=tuple(seg), first=tuple(first))


def pack_leaves(leaves: Sequence[jax.Array], layout: PackLayout,
                lead: int = 0, dtype: Any = jnp.float32) -> jax.Array:
    """Gather leaves into the (… , total_rows, 128) packed buffer.

    ``lead`` leading axes (the K client axis) are preserved; zero padding
    between a unit's payload and its block boundary is what makes the
    kernel's norm accumulation exact (0 contributes nothing).  ``dtype``
    is the bucket's storage dtype — the kernel upcasts to f32 on read
    either way, so bf16 storage of bf16 leaves is lossless.
    """
    lead_shape = leaves[0].shape[:lead]
    bufs = []
    for u in range(layout.n_units):
        parts = []
        for li, di, size in layout.unit_pieces[u]:
            a = leaves[li].astype(dtype)
            if di is None:
                parts.append(a.reshape(lead_shape + (size,)))
            else:
                L = a.shape[lead]
                parts.append(a.reshape(lead_shape + (L, size))[..., di, :])
        if not parts:
            # unit absent from this dtype bucket: one all-zero block so
            # the per-unit norm accumulators stay aligned across buckets
            bufs.append(jnp.zeros(
                lead_shape + (layout.unit_rows[u] * _LANES,), dtype))
            continue
        buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)
        pad = layout.unit_rows[u] * _LANES - buf.shape[-1]
        if pad:
            buf = jnp.pad(buf, [(0, 0)] * lead + [(0, pad)])
        bufs.append(buf)
    flat = bufs[0] if len(bufs) == 1 else jnp.concatenate(bufs, axis=-1)
    return flat.reshape(lead_shape + (layout.total_rows, _LANES))


def unpack_applied(flat: jax.Array, layout: PackLayout,
                   shapes: Sequence[tuple[int, ...]],
                   dtypes: Sequence[Any]) -> list[jax.Array]:
    """Scatter the packed applied-update buffer back into leaves."""
    v = flat.reshape(-1)
    out = []
    for li, (shape, dtype) in enumerate(zip(shapes, dtypes)):
        parts = [jax.lax.slice(v, (off,), (off + size,))
                 for _, off, size in layout.leaf_parts[li]]
        leaf = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        out.append(leaf.reshape(shape).astype(dtype))
    return out


def _batched_kernel(seg_ref, first_ref, wn_ref, ap_ref, af_ref,
                    d_ref, prev_ref, x_ref, o_ref, d2_ref, x2_ref):
    i = pl.program_id(0)
    u = seg_ref[i]

    @pl.when(first_ref[i] == 1)
    def _init():
        d2_ref[0, 0] = 0.0
        x2_ref[0, 0] = 0.0

    prev = prev_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    K = d_ref.shape[0]
    merged = wn_ref[0, u] * d_ref[0].astype(jnp.float32)
    for k in range(1, K):                   # K is static (buffer size)
        merged = merged + wn_ref[k, u] * d_ref[k].astype(jnp.float32)
    applied = ap_ref[u] * prev + af_ref[u] * merged
    o_ref[...] = applied.astype(o_ref.dtype)
    d2_ref[0, 0] += jnp.sum(applied * applied)
    x2_ref[0, 0] += jnp.sum(x * x)


def luar_agg_batched(delta_leaves: Sequence[jax.Array],
                     x_leaves: Sequence[jax.Array],
                     prev_leaves: Sequence[jax.Array],
                     leaf_unit: Sequence[LeafUnit], *,
                     wn: jax.Array, a_prev: jax.Array, a_fresh: jax.Array,
                     block_rows: int = 64, interpret: bool = False):
    """Whole-round fused aggregation over ALL units in one Pallas pass.

    delta_leaves: model leaves with a leading K axis (K buffered client
    deltas; K=1 for the synchronous round).  x_leaves: current params
    (Eq. (1) denominator).  prev_leaves: \\hat{Delta}_{t-1} (the
    recycled direction).  leaf_unit: ``UnitMap.leaf_unit`` — plain ints
    and (start, L) stacked entries both supported.

    wn (K, n_units) f32: normalized per-(client, unit) merge weights.
    a_prev / a_fresh (n_units,) f32: the two coefficients of
    ``applied_u = a_prev[u] * prev_u + a_fresh[u] * merge_u``.

    Returns (applied_leaves (x dtypes), ||applied||^2 per unit,
    ||x||^2 per unit).

    Leaves whose delta, x AND prev are all bf16 are packed (and their
    applied updates written) in a bf16 bucket; everything else upcasts
    into the f32 bucket.  Each bucket sweeps once; the per-unit norms
    are summed across buckets.  Numerics are unchanged (the kernel
    computes in f32 and the final cast to the leaf dtype happens either
    way) — only the packed buffers' HBM bytes shrink.
    """
    shapes = tuple(tuple(x.shape) for x in x_leaves)
    dtypes = [x.dtype for x in x_leaves]
    n_units = leaf_unit_count(leaf_unit)
    K = delta_leaves[0].shape[0]
    wn = wn.astype(jnp.float32)
    a_prev = a_prev.astype(jnp.float32)
    a_fresh = a_fresh.astype(jnp.float32)

    bf16 = jnp.bfloat16
    in_bf16 = [delta_leaves[i].dtype == bf16 and x_leaves[i].dtype == bf16
               and prev_leaves[i].dtype == bf16 for i in range(len(shapes))]
    idx_f32 = tuple(i for i, b in enumerate(in_bf16) if not b)
    idx_bf16 = tuple(i for i, b in enumerate(in_bf16) if b)
    buckets = [(idx, dt, sub) for idx, dt, sub in
               ((idx_f32, jnp.float32, _ROWS), (idx_bf16, bf16, _BF16_ROWS))
               if idx]

    applied: list[jax.Array | None] = [None] * len(shapes)
    d2 = jnp.zeros((n_units, 1), jnp.float32)
    x2 = jnp.zeros((n_units, 1), jnp.float32)
    for idx, pack_dtype, sublane in buckets:
        lu = tuple(leaf_unit[i] for i in idx)
        shp = tuple(shapes[i] for i in idx)
        layout = build_pack_layout(lu, shp, int(block_rows),
                                   n_units=n_units, sublane=sublane)
        d = pack_leaves([delta_leaves[i] for i in idx], layout, lead=1,
                        dtype=pack_dtype)
        prev = pack_leaves([prev_leaves[i] for i in idx], layout,
                           dtype=pack_dtype)
        x = pack_leaves([x_leaves[i] for i in idx], layout,
                        dtype=pack_dtype)
        seg = jnp.asarray(layout.seg, jnp.int32)
        first = jnp.asarray(layout.first, jnp.int32)
        bt = layout.block_rows
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,          # seg, first drive the index maps
            grid=(layout.grid,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),              # wn
                pl.BlockSpec(memory_space=pltpu.SMEM),              # a_prev
                pl.BlockSpec(memory_space=pltpu.SMEM),              # a_fresh
                pl.BlockSpec((K, bt, _LANES),
                             lambda i, seg, first: (0, i, 0)),
                pl.BlockSpec((bt, _LANES), lambda i, seg, first: (i, 0)),
                pl.BlockSpec((bt, _LANES), lambda i, seg, first: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bt, _LANES), lambda i, seg, first: (i, 0)),
                pl.BlockSpec((1, 1), lambda i, seg, first: (seg[i], 0)),
                pl.BlockSpec((1, 1), lambda i, seg, first: (seg[i], 0)),
            ],
        )
        out, d2_b, x2_b = pl.pallas_call(
            _batched_kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((layout.total_rows, _LANES),
                                     pack_dtype),
                jax.ShapeDtypeStruct((n_units, 1), jnp.float32),
                jax.ShapeDtypeStruct((n_units, 1), jnp.float32),
            ],
            compiler_params=_CompilerParams(
                dimension_semantics=("arbitrary",)),
            interpret=interpret,
        )(seg, first, wn, a_prev, a_fresh, d, prev, x)
        bucket_applied = unpack_applied(
            out, layout, shp, [dtypes[i] for i in idx])
        for j, i in enumerate(idx):
            applied[i] = bucket_applied[j]
        d2 = d2 + d2_b
        x2 = x2 + x2_b
    return applied, d2[:, 0], x2[:, 0]
