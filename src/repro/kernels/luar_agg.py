"""Fused LUAR server-side aggregation kernel (the paper's hot spot).

Per layer and per round the server needs three HBM sweeps over the
layer's update: (a) select recycled-vs-fresh update, (b) ||applied||^2
and (c) ||x||^2 for the Eq. (1) metric s_{t,l}.  This kernel fuses them
into ONE pass: each (8,128)-aligned tile is read once, the select is
written, and the two squared norms accumulate in SMEM across the grid.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _CompilerParams

_LANES = 128
_ROWS = 8


def _kernel(mask_ref, d_ref, x_ref, r_ref, o_ref, d2_ref, x2_ref, acc_scr):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    use_recycled = mask_ref[0] > 0
    d = d_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    applied = jnp.where(use_recycled, r, d)
    o_ref[...] = applied.astype(o_ref.dtype)
    acc_scr[0, 0] += jnp.sum(applied * applied)
    acc_scr[0, 1] += jnp.sum(x * x)

    @pl.when(i == n - 1)
    def _flush():
        d2_ref[0, 0] = acc_scr[0, 0]
        x2_ref[0, 0] = acc_scr[0, 1]


def luar_agg(delta: jax.Array, x: jax.Array, recycled: jax.Array,
             use_recycled: jax.Array, *, block_rows: int = 256,
             interpret: bool = False):
    """Flat-or-any-shape single-layer LUAR aggregation.

    Returns (applied_update (same shape), ||applied||^2, ||x||^2)."""
    shape, dtype = delta.shape, delta.dtype
    flat = delta.reshape(-1)
    n = flat.shape[0]
    width = _LANES
    rows = -(-n // width)
    pad_rows = -(-rows // _ROWS) * _ROWS
    bt = min(block_rows, pad_rows)
    while pad_rows % bt:
        bt //= 2
    grid = pad_rows // bt

    def prep(a):
        f = a.reshape(-1).astype(jnp.float32)
        f = jnp.pad(f, (0, pad_rows * width - n))
        return f.reshape(pad_rows, width)

    mask = (use_recycled > 0).astype(jnp.int32).reshape(1)
    out, d2, x2 = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, width), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pad_rows, width), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.SMEM((1, 2), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(mask, prep(delta), prep(x), prep(recycled))
    applied = out.reshape(-1)[:n].reshape(shape).astype(dtype)
    return applied, d2[0, 0], x2[0, 0]
