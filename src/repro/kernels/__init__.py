# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams in newer releases; take
# whichever this jax ships (shared by all kernels in this package)
_CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
