"""Synthetic datasets with paper-matching statistics knobs (offline
container — CIFAR/FEMNIST/AG-News are replaced by learnable synthetic
tasks; the Dirichlet non-IIDness, client counts, and activation ratios
are identical to the paper's settings).
"""
from __future__ import annotations

import numpy as np


def gaussian_mixture(n: int, n_classes: int = 10, d: int = 64,
                     sep: float = 3.0, seed: int = 0,
                     means_seed: int = 1234) -> tuple[np.ndarray, np.ndarray]:
    """Linearly-separable-ish class clusters (MLP-learnable).  The class
    means are drawn from ``means_seed`` so train/test splits with
    different ``seed`` share the same task."""
    means_rng = np.random.default_rng(means_seed)
    means = means_rng.normal(0, sep, (n_classes, d)).astype(np.float32)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n)
    x = means[labels] + rng.normal(0, 1.0, (n, d)).astype(np.float32)
    return x.astype(np.float32), labels.astype(np.int32)


def synthetic_images(n: int, n_classes: int = 62, size: int = 28,
                     seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """FEMNIST-like: class-specific low-frequency pattern + pixel noise."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    patterns = np.stack([
        np.sin(2 * np.pi * ((c % 7 + 1) * xx + (c // 7 + 1) * yy + c / n_classes))
        for c in range(n_classes)
    ])
    labels = rng.integers(0, n_classes, n)
    imgs = patterns[labels] + rng.normal(0, 0.4, (n, size, size)).astype(np.float32)
    return imgs[..., None].astype(np.float32), labels.astype(np.int32)


def synthetic_tokens(n_seqs: int, seq_len: int = 64, vocab: int = 512,
                     n_classes: int = 4, seed: int = 0) -> dict[str, np.ndarray]:
    """AG-News-like: class-conditioned token distributions for sequence
    classification, plus next-token LM targets."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_seqs)
    # each class prefers a band of the vocabulary
    band = vocab // n_classes
    toks = np.empty((n_seqs, seq_len), np.int32)
    for i, c in enumerate(labels):
        base = rng.integers(c * band, (c + 1) * band, seq_len)
        noise = rng.integers(0, vocab, seq_len)
        toks[i] = np.where(rng.random(seq_len) < 0.7, base, noise)
    return {"tokens": toks, "labels": labels.astype(np.int32)}


def lm_batch(tokens: np.ndarray) -> dict[str, np.ndarray]:
    """Next-token prediction batch from raw token sequences."""
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32)}
