"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, SWA 4096.  [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
    window=4096,
    rope_theta=1e6,
    source="arXiv:2401.04088",
)
