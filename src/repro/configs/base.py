"""Model/config system: one ModelConfig covers all 6 architecture families.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact assigned full-scale config) built from this
dataclass.  ``reduced()`` produces the CPU smoke-test variant of the same
family (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int       # sequence length (KV-cache length for decode)
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0              # 0 -> = n_heads (MHA)
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention flavour
    qk_norm: bool = False
    gated_mlp: bool = True           # SwiGLU (3 mats) vs plain GELU (2 mats)
    rope_theta: float = 1e4
    window: int = 0                  # sliding-window size; 0 = full attention
    local_global_period: int = 0     # e.g. 6 -> every 6th layer is global (gemma3)
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25
    first_layer_dense: bool = False  # deepseek: layer 0 uses dense FFN
    # MLA (deepseek)
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    attn_every: int = 0              # hybrid: shared attn block every k blocks
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend frame count
    # VLM
    n_vis_tokens: int = 0
    # numerics
    dtype: Any = jnp.bfloat16
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode (500k) is feasible: constant-state
        SSM/hybrid, or dense with sliding-window locality on (almost) all
        layers."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0  # SWA / local-global patterns

    def layer_window(self, layer_idx: int) -> int:
        """Effective attention window for a layer (0 = full)."""
        if self.window == 0:
            return 0
        if self.local_global_period and (layer_idx + 1) % self.local_global_period == 0:
            return 0  # global layer in a local:global pattern
        return self.window

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- reduced smoke variant ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant: 2 layers, d_model<=256, <=4 experts."""
        kw: dict[str, Any] = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            dtype=jnp.float32,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2), d_ff_expert=64,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.kv_lora_rank:
            kw.update(kv_lora_rank=32, rope_head_dim=16, head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.n_enc_layers:
            kw.update(n_enc_layers=2, enc_seq=16)
        if self.n_vis_tokens:
            kw.update(n_vis_tokens=8)
        if self.window:
            kw.update(window=16)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Parameter counting (for roofline MODEL_FLOPS = 6 N D)
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> dict[str, float]:
    """Analytic total and *active* parameter counts (active differs for MoE)."""
    d, L = cfg.d_model, cfg.n_layers
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    embed = cfg.vocab_size * d
    total = embed
    active = embed

    def attn_params() -> int:
        return d * H * hd + 2 * d * K * hd + H * hd * d

    def mla_params() -> int:
        r, rp = cfg.kv_lora_rank, cfg.rope_head_dim
        return (d * H * (hd + rp)                    # q (nope+rope)
                + d * (r + rp)                       # kv down + k_pe
                + r * H * (hd + hd)                  # k_nope up + v up
                + H * hd * d)                        # o

    def dense_ffn(ff: int) -> int:
        return (3 if cfg.gated_mlp else 2) * d * ff

    if cfg.family in ("dense", "vlm"):
        per = attn_params() + dense_ffn(cfg.d_ff) + 2 * d
        total += L * per
        active += L * per
    elif cfg.family == "moe":
        attn = mla_params() if cfg.kv_lora_rank else attn_params()
        ffe = cfg.d_ff_expert or cfg.d_ff
        router = d * cfg.n_experts
        shared = cfg.n_shared_experts * dense_ffn(ffe)
        moe_total = cfg.n_experts * dense_ffn(ffe) + router + shared
        moe_active = cfg.top_k * dense_ffn(ffe) + router + shared
        n_moe = L - (1 if cfg.first_layer_dense else 0)
        n_dense = L - n_moe
        total += L * (attn + 2 * d) + n_moe * moe_total + n_dense * dense_ffn(cfg.d_ff)
        active += L * (attn + 2 * d) + n_moe * moe_active + n_dense * dense_ffn(cfg.d_ff)
    elif cfg.family == "ssm":
        di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = (d * (2 * di + 2 * N + nh)   # in_proj (x,z) + B,C + dt
               + cfg.conv_width * (di + 2 * N)
               + di * d + 2 * d)
        total += L * per
        active += L * per
    elif cfg.family == "hybrid":
        di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per = (d * (2 * di + 2 * N + nh) + cfg.conv_width * (di + 2 * N)
               + di * d + 2 * d)
        shared = attn_params() + dense_ffn(cfg.d_ff) + 2 * d
        total += L * per + shared
        active += L * per + shared
    elif cfg.family == "encdec":
        enc_per = attn_params() + dense_ffn(cfg.d_ff) + 2 * d
        dec_per = 2 * attn_params() + dense_ffn(cfg.d_ff) + 3 * d
        total += cfg.n_enc_layers * enc_per + L * dec_per + cfg.enc_seq * d
        active = total
    return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a step.

    train   -> {tokens, labels, (vis_embeds | enc_frames)}
    prefill -> {tokens, (vis_embeds | enc_frames)}
    decode  -> {token, pos, (enc_frames)}  (cache specs built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out: dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["labels"] = _sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode
        out["token"] = _sds((B, 1), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["vis_embeds"] = _sds((B, cfg.n_vis_tokens, d), cfg.dtype)
    if cfg.family == "encdec":
        out["enc_frames"] = _sds((B, cfg.enc_seq, d), cfg.dtype)
    return out


# ---------------------------------------------------------------------------
# Federated-systems heterogeneity scenarios (repro.sim)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimScenario:
    """Population-level distribution of client compute/bandwidth resources.

    ``repro.sim.profiles.sample_resources`` draws one ``ClientResources``
    per client from this spec.  Means are per-client EXPECTED values; the
    ``kind`` decides how individual clients scatter around them:

      uniform   — every client identical (heterogeneity disabled; the
                  regime where the event simulator must reproduce the
                  synchronous ``fl/rounds.py`` trajectory bit-for-bit)
      lognormal — multiplicative scatter with spread ``sigma`` on compute
                  and both links (WAN-style long tail)
      bimodal   — "mobile vs datacenter": a ``fast_fraction`` of clients
                  gets ``fast_speedup``x compute and ``fast_bw_scale``x
                  bandwidth; the rest are the slow mobile mode
      diurnal   — identical clients whose LINK bandwidth varies over
                  VIRTUAL TIME on a sinusoidal day/night cycle
                  (``bw_period`` seconds, ``bw_amplitude`` relative
                  swing); the engines look the multiplier up per
                  dispatch via ``repro.sim.profiles.bandwidth_multiplier``
      measured  — per-link bandwidths come from the MEASURED link table
                  in ``launch/mesh.py`` (``client_link_trace``: the same
                  wan/metro/dcn/ici mix that paces the serve load
                  harness), so the simulators and the round service price
                  the same fleet.  ``up_bw``/``down_bw`` means are ignored;
                  ``step_time``/``dropout`` still apply, and a nonzero
                  ``bw_amplitude`` layers the diurnal cycle on top

    A nonzero ``bw_amplitude`` activates the day/night cycle for ANY
    kind (the cycle multiplies whatever per-client links the kind drew).
    """
    name: str = "uniform"
    kind: str = "uniform"            # uniform | lognormal | bimodal | diurnal | measured
    step_time: float = 0.02          # mean seconds per local SGD step
    up_bw: float = 1.0e6             # mean uplink bytes/s (mobile-grade)
    down_bw: float = 8.0e6           # mean downlink bytes/s (asymmetric link)
    sigma: float = 0.5               # lognormal log-space spread
    fast_fraction: float = 0.2       # bimodal: datacenter share
    fast_speedup: float = 20.0       # bimodal: compute multiple
    fast_bw_scale: float = 50.0      # bimodal: bandwidth multiple
    dropout: float = 0.0             # per-dispatch client-vanish probability
    # diurnal cycle (kind="diurnal"): bw(t) = mean * (1 + A sin(2pi t/P + phi))
    bw_period: float = 600.0         # P, virtual seconds per cycle
    bw_amplitude: float = 0.0        # A in [0, 1); 0 = constant bandwidth
    bw_phase: float = 0.0            # phi, radians (0 = cycle starts at mean)

    def replace(self, **kw) -> "SimScenario":
        return dataclasses.replace(self, **kw)


SIM_SCENARIOS: dict[str, SimScenario] = {
    "uniform": SimScenario("uniform", "uniform"),
    "lognormal": SimScenario("lognormal", "lognormal", sigma=0.6),
    "bimodal": SimScenario("bimodal", "bimodal", step_time=0.04,
                           up_bw=4.0e5, down_bw=6.0e6),
    # bimodal + flaky mobile devices (straggler/dropout stress)
    "bimodal_flaky": SimScenario("bimodal_flaky", "bimodal", step_time=0.04,
                                 up_bw=4.0e5, down_bw=6.0e6, dropout=0.1),
    # day/night link-quality cycle: +-60% bandwidth swing every 600 virtual
    # seconds (time-varying-bandwidth open item; the codec pipeline prices
    # the payload, the cycle prices the seconds per byte)
    "diurnal": SimScenario("diurnal", "diurnal", bw_period=600.0,
                           bw_amplitude=0.6),
    # measured per-link bandwidths (launch/mesh.py client_link_trace):
    # the 80/15/4/1 wan/metro/dcn/ici mix the serve load harness paces
    # with — sim rows and serve rows price the same fleet.  step_time
    # stays mobile-grade; the link table carries all bandwidth scatter
    "measured": SimScenario("measured", "measured", step_time=0.05),
}


def validate_scenario(sc: SimScenario) -> SimScenario:
    """Reject malformed scenarios ONCE, at resolution time.

    The diurnal parameters used to be checked inside the per-dispatch
    ``bandwidth_multiplier`` hot path — and skipped entirely whenever
    ``bw_amplitude == 0.0``, so a bad ``bw_period`` (or an amplitude a
    later ``replace`` pushed out of range) only raised mid-run, if ever.
    Every resolution goes through here instead; the hot path trusts it."""
    if sc.kind == "diurnal" or sc.bw_amplitude != 0.0:
        # the day/night cycle can ride on any kind (e.g. measured links
        # with a diurnal swing), so its parameters are validated whenever
        # the amplitude is live — and always for the diurnal kind itself
        if not 0.0 <= sc.bw_amplitude < 1.0:
            raise ValueError(f"scenario {sc.name!r}: bw_amplitude must be "
                             f"in [0, 1), got {sc.bw_amplitude}")
        if sc.bw_period <= 0.0:
            raise ValueError(f"scenario {sc.name!r}: bw_period must be "
                             f"positive, got {sc.bw_period}")
    return sc


def get_scenario(name_or_spec) -> SimScenario:
    if isinstance(name_or_spec, SimScenario):
        return validate_scenario(name_or_spec)
    try:
        return validate_scenario(SIM_SCENARIOS[name_or_spec])
    except KeyError:
        raise KeyError(f"unknown sim scenario {name_or_spec!r}; "
                       f"have {sorted(SIM_SCENARIOS)}") from None


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict[str, Any]:
    """ShapeDtypeStruct tree for the decode cache of ``cfg``."""
    L, K, hd = cfg.n_layers, cfg.kv_heads, cfg.hd
    dt = cfg.dtype
    out: dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "encdec"):
        out["k"] = _sds((L, batch, seq_len, K, hd), dt)
        out["v"] = _sds((L, batch, seq_len, K, hd), dt)
        if cfg.family == "encdec":
            out["enc_out"] = _sds((batch, cfg.enc_seq, cfg.d_model), dt)
    elif cfg.family == "moe":
        if cfg.kv_lora_rank:
            out["c_kv"] = _sds((L, batch, seq_len, cfg.kv_lora_rank), dt)
            out["k_pe"] = _sds((L, batch, seq_len, cfg.rope_head_dim), dt)
        else:
            out["k"] = _sds((L, batch, seq_len, K, hd), dt)
            out["v"] = _sds((L, batch, seq_len, K, hd), dt)
    elif cfg.family == "ssm":
        out["ssm"] = _sds((L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        out["conv"] = _sds((L, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), dt)
    elif cfg.family == "hybrid":
        out["ssm"] = _sds((L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        out["conv"] = _sds((L, batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.ssm_state), dt)
        n_attn = cfg.n_layers // cfg.attn_every
        out["k"] = _sds((n_attn, batch, seq_len, K, hd), dt)
        out["v"] = _sds((n_attn, batch, seq_len, K, hd), dt)
    return out
