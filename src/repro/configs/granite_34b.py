"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch code model.  [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    gated_mlp=False,
    vocab_size=49152,
    rope_theta=1e5,
    source="arXiv:2405.04324",
)
