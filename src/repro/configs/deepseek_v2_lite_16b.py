"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H, MLA kv_lora=512,
vocab=102400, MoE: 2 shared + 64 routed experts top-6, expert ff=1408,
first layer dense (ff=10944).  The pool's bracket note says "160 routed"
(that is DeepSeek-V2-full); the assigned line says 64e top-6, which
matches the Lite model card, so we use 64.  [arXiv:2405.04434]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,            # dense first layer
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    first_layer_dense=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    source="arXiv:2405.04434",
)
