"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; pruned Nemotron.  [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    source="arXiv:2407.14679",
)
