"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=32000, ssm_state=64; Mamba2 backbone with a weight-shared
attention+MLP block applied every 6 blocks.  [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    source="arXiv:2411.15242",
)
