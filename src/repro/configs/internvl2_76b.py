"""internvl2-76b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  InternViT frontend is a stub: input_specs provides
(B, 256, d) patch embeddings.  LM backbone = Llama-3-70B-class.
[arXiv:2404.16821]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    n_vis_tokens=256,
    source="arXiv:2404.16821",
)
