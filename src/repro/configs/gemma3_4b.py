"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5 local (window 1024) : 1 global attention pattern, 128k
context.  [hf:google/gemma-3-1b-pt family, 4B point]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    qk_norm=True,
    window=1024,
    local_global_period=6,   # every 6th layer global -> 5:1 local:global
    rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt",
)
