"""Assigned-architecture registry.

Each module defines ``CONFIG`` (the exact assigned full-scale config).
``get_config(name)`` returns it; ``get_config(name, reduced=True)``
returns the CPU smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    cache_specs,
    input_specs,
    param_counts,
)

ARCH_IDS: list[str] = [
    "qwen3-14b",
    "internvl2-76b",
    "mixtral-8x7b",
    "granite-34b",
    "zamba2-1.2b",
    "mamba2-780m",
    "whisper-small",
    "deepseek-v2-lite-16b",
    "gemma3-4b",
    "minitron-8b",
]

_MODULES: dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs(reduced: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}
