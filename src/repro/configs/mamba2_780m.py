"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    source="arXiv:2405.21060",
)
