"""whisper-small [audio] — 12L enc + 12L dec, d_model=768 12H (MHA)
d_ff=3072 vocab=51865; mel+conv frontend is a stub: input_specs provides
(B, 1500, d) frame embeddings.  [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    gated_mlp=False,
    vocab_size=51865,
    n_enc_layers=12,
    enc_seq=1500,
    source="arXiv:2212.04356",
)
