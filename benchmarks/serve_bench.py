"""Load harness for the round service (``repro.serve``).

Three rows measure the service's three costs:

  serve/inproc_round    dispatch+train+upload against the RoundServer
                        object directly — the aggregation-loop floor
  serve/http_roundtrip  the same trips over the real HTTP wire
                        (ThreadingHTTPServer + npz-over-JSON payloads)
  serve/http_paced_wan  HTTP trips with clients paced by the measured
                        per-link bandwidths (``launch.mesh``'s WAN-heavy
                        fleet mix replayed as client-side dwell time)
  serve/wal_snapshot    one write-ahead checkpoint save + restore cycle

``secs`` is mean seconds per round trip (per snapshot for the WAL row);
derived carries p50/p95 latency, rounds/sec, and the byte ledgers.

  PYTHONPATH=src python -m benchmarks.serve_bench [--record] [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import bench_record, emit
from repro.obs import Telemetry
from repro.serve import http as serve_http
from repro.serve import state as serve_state
from repro.serve.client import (_build_workload, latency_quantiles,
                                make_clients, run_harness)
from repro.serve.core import RoundServer


def _drive(transport, loss_fn, params, data, parts, cfg, n_clients: int,
           rounds: int, pace: float, seed: int) -> tuple[float, dict]:
    clients = make_clients(n_clients, transport, loss_fn, params, data,
                           parts, cfg, pace=pace, seed=seed)
    t0 = time.perf_counter()
    results = run_harness(clients, rounds)
    wall = time.perf_counter() - t0
    q = latency_quantiles(results)
    n = len(results)
    derived = {
        "trips": n,
        "accepted": sum(r["status"] == "accepted" for r in results),
        "p50_ms": round(q["p50_ms"], 2),
        "p95_ms": round(q["p95_ms"], 2),
        "rounds_per_s": round(n / max(wall, 1e-9), 2),
    }
    return wall / max(n, 1), derived


def rows(quick: bool = True) -> list[tuple[str, float, dict]]:
    n_clients, n_rounds = (4, 3) if quick else (8, 6)
    seed = 0
    loss_fn, params, data, parts, cfg, sc = _build_workload(
        n_clients, seed, buffer_size=n_clients - 1, codecs="down:delta")
    out: list[tuple[str, float, dict]] = []

    # -- floor: no transport, no pacing --------------------------------
    rs = RoundServer(params, cfg, sc, telemetry=Telemetry())
    # warm the jitted paths so the rows measure steady state
    _drive(rs, loss_fn, params, data, parts, cfg, n_clients, 1, 0.0, seed)
    secs, derived = _drive(rs, loss_fn, params, data, parts, cfg,
                           n_clients, n_rounds, 0.0, seed)
    st = rs.status()
    derived.update(up_mb=round(st["uploaded_mb"], 4),
                   down_mb=round(st["downloaded_mb"], 4),
                   delta_dl=st["downloads_delta"])
    out.append(("serve/inproc_round", secs, derived))

    # -- the real wire --------------------------------------------------
    for name, pace in (("serve/http_roundtrip", 0.0),
                       ("serve/http_paced_wan", 1.0)):
        rs = RoundServer(_build_workload(n_clients, seed,
                                         buffer_size=n_clients - 1,
                                         codecs="down:delta")[1],
                         cfg, sc, telemetry=Telemetry())
        httpd = serve_http.start(rs)
        try:
            _drive(httpd.url, loss_fn, params, data, parts, cfg,
                   n_clients, 1, 0.0, seed)
            secs, derived = _drive(httpd.url, loss_fn, params, data, parts,
                                   cfg, n_clients, n_rounds, pace, seed)
        finally:
            serve_http.stop(httpd, checkpoint=False)
        st = rs.status()
        derived.update(up_mb=round(st["uploaded_mb"], 4),
                       down_mb=round(st["downloaded_mb"], 4))
        if pace:
            derived["pace"] = pace
        out.append((name, secs, derived))

    # -- WAL cost: save + restore one full snapshot ---------------------
    import os
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "wal")
        sc_w = serve_state.ServeConfig(buffer_size=sc.buffer_size,
                                       ckpt_path=path)
        rs = RoundServer(params, cfg, sc_w, telemetry=Telemetry())
        _drive(rs, loss_fn, params, data, parts, cfg, n_clients, 1, 0.0,
               seed)
        reps = 3 if quick else 10
        t0 = time.perf_counter()
        for _ in range(reps):
            serve_state.save(rs)
        t_save = (time.perf_counter() - t0) / reps
        rs2 = RoundServer(params, cfg, sc_w, telemetry=Telemetry())
        t0 = time.perf_counter()
        for _ in range(reps):
            serve_state.load_into(rs2, path)
        t_restore = (time.perf_counter() - t0) / reps
        kb = (os.path.getsize(path + ".npz")
              + os.path.getsize(path + ".json")) / 1e3
        out.append(("serve/wal_snapshot", t_save,
                    {"restore_ms": round(t_restore * 1e3, 2),
                     "snapshot_kb": round(kb, 1),
                     "arrays": int(np.load(path + ".npz").__len__())}))
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--record", action="store_true",
                    help="write BENCH_serve.json")
    ap.add_argument("--out-dir", default=".")
    args = ap.parse_args(argv)
    quick = not args.full
    print("name,us_per_call,derived")
    t0 = time.time()
    r = rows(quick)
    emit(r)
    if args.record:
        path = bench_record("serve", r, time.time() - t0, quick,
                            args.out_dir)
        print(f"# recorded {path}")


if __name__ == "__main__":
    main()
