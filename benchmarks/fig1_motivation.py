"""Figure 1: layers with the smallest gradient norms are NOT the layers
with the smallest gradient-to-weight ratio — the paper's motivating
observation, measured on the CNN workload."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_task, emit
from repro.core import build_units, s_metric, unit_sq_norms


def rows(quick: bool = True):
    task = make_task("femnist", n_clients=8)
    um = build_units(task.params, "module")
    x = jnp.asarray(task.data["x"][:256])
    y = jnp.asarray(task.data["y"][:256])
    g = jax.grad(task.loss_fn)(task.params, {"x": x, "y": y})
    gnorm = np.sqrt(np.asarray(unit_sq_norms(um, g)))
    ratio = np.asarray(s_metric(um, g, task.params))
    rank_g = np.argsort(gnorm)
    rank_r = np.argsort(ratio)
    spearman = float(np.corrcoef(np.argsort(rank_g), np.argsort(rank_r))[0, 1])
    out = {
        "min_gradnorm_layer": um.names[rank_g[0]],
        "min_ratio_layer": um.names[rank_r[0]],
        "rank_agreement": round(spearman, 3),
    }
    for n, gn, r in zip(um.names, gnorm, ratio):
        out[f"{n}"] = f"g{gn:.3g}/s{r:.3g}"
    return [("fig1/gradnorm_vs_ratio", 0.0, out)]


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
