"""Figure 4: accuracy vs cumulative communication (learning curves)."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 30 if quick else 150
    task = make_task("mixture" if quick else "femnist")
    out = []
    for name, kw in {
        "fedavg": {},
        "fedluar": dict(luar=LuarConfig(delta=2, granularity="leaf")),
        "dropping": dict(luar=LuarConfig(delta=2, granularity="leaf", mode="drop")),
    }.items():
        res, t = timed(lambda kw=kw: fl(task, rounds, eval_every=max(rounds // 6, 1), **kw))
        curve = "|".join(f"{h['comm_ratio']:.2f}:{h['acc']:.3f}" for h in res.history)
        out.append((f"fig4/{name}", t / rounds, {"curve(comm:acc)": curve}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
