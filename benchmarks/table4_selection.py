"""Table 4: layer-selection scheme ablation — LUAR's inverse-s sampling
vs random / top / bottom / gradient-norm / deterministic."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    task = make_task("mixture" if quick else "femnist")
    out = []
    for scheme in ("luar", "random", "top", "bottom", "grad_norm",
                   "deterministic"):
        res, t = timed(lambda scheme=scheme: fl(
            task, rounds,
            luar=LuarConfig(delta=2, scheme=scheme, granularity="leaf")))
        out.append((f"table4/{scheme}", t / rounds, {
            "acc": round(res.history[-1]["acc"], 4),
            "comm": round(res.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
