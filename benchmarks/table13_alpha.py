"""Tables 13-14: robustness to the degree of non-IIDness (Dirichlet
alpha) — FedLUAR tracks FedAvg accuracy at every alpha."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    out = []
    for alpha in (0.1, 0.5, 1.0):
        task = make_task("mixture" if quick else "femnist", alpha=alpha)
        base, t = timed(lambda task=task: fl(task, rounds))
        luar, _ = timed(lambda task=task: fl(
            task, rounds, luar=LuarConfig(delta=2, granularity="leaf")))
        out.append((f"table13/alpha{alpha}", t / rounds, {
            "acc_fedavg": round(base.history[-1]["acc"], 4),
            "acc_fedluar": round(luar.history[-1]["acc"], 4),
            "comm": round(luar.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
