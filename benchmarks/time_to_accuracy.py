"""Time-to-accuracy under heterogeneous clients (the repro.sim payoff).

For each heterogeneity scenario x algorithm, runs the event-driven
simulator and reports the SIMULATED wall-clock seconds to reach the
target accuracy — the systems-level claim the byte ratios of Table 2
only imply: recycled units skip the uplink, so under thin mobile links
FedLUAR's rounds close faster and time-to-accuracy drops.

Bandwidths are rescaled to the benchmark model's size (a full mobile
upload = ~2 simulated seconds) so the tiny CPU-scale models exercise the
same upload-dominated regime as the paper-scale workloads.

Ratios are BIDIRECTIONAL: ``comm`` is uplink bytes vs FedAvg over the
same spent uploads, ``down`` is downlink bytes vs the full-model
broadcast over the same dispatches, and the fedbuff downlink rows report
raw up/down/total MB for the delta-encoded broadcast (``down:delta``)
against the full-broadcast baseline.
"""
from __future__ import annotations

import argparse
import math

from repro.compress import split_codec_specs
from repro.configs.base import get_scenario
from repro.core import LuarConfig
from repro.core.units import build_units
from repro.fl.client import ClientConfig
from repro.fl.rounds import FLConfig
from repro.sim import SimConfig, run_sim, time_to_target

from benchmarks.common import Task, emit, make_task, timed


def scaled_scenario(name: str, model_bytes: float):
    """Rescale a named scenario so the mobile mode is upload-dominated
    for a model of ``model_bytes``: full upload ~2 s, download ~0.25 s,
    local compute ~0.3 s."""
    sc = get_scenario(name)
    return sc.replace(up_bw=model_bytes / 2.0, down_bw=model_bytes * 4.0,
                      step_time=0.06)


ALGOS: list[tuple[str, dict]] = [
    ("fedavg", dict()),
    ("fedluar", dict(luar=LuarConfig(delta=2, granularity="leaf"))),
    ("fedpaq", dict(codecs=("fedpaq:8",))),
    ("fedluar_paq", dict(luar=LuarConfig(delta=2, granularity="leaf"),
                         codecs=("fedpaq:8",))),
]


def rows(quick: bool = True, codec_specs: tuple[str, ...] | None = None):
    task: Task = make_task("mixture" if quick else "femnist")
    rounds = 30 if quick else 60
    target = 0.9 if quick else 0.7
    um = build_units(task.params, "leaf")
    model_bytes = float(sum(um.unit_bytes))

    algos = list(ALGOS)
    if codec_specs:
        # a user-declared codec stack (CLI --codecs), composed with LUAR
        algos.append(("codec_" + "+".join(codec_specs),
                      dict(luar=LuarConfig(delta=2, granularity="leaf"),
                           codecs=tuple(codec_specs))))
    out = []
    for scen in ("uniform", "lognormal", "bimodal"):
        sc = scaled_scenario(scen, model_bytes)
        for algo, kw in algos:
            cfg = FLConfig(n_clients=len(task.parts), n_active=8, tau=5,
                           batch_size=16, rounds=rounds,
                           client=ClientConfig(lr=0.05), eval_every=2, **kw)
            res, secs = timed(lambda sc=sc, cfg=cfg: run_sim(
                task.loss_fn, task.params, task.data, task.parts, cfg,
                SimConfig(scenario=sc), task.eval_fn))
            t_hit = time_to_target(res, "acc", target)
            out.append((f"tta_{scen}_{algo}", secs, {
                "t_target_s": round(t_hit, 2) if math.isfinite(t_hit) else "inf",
                "sim_time_s": round(res.sim_time, 2),
                "acc": round(res.history[-1]["acc"], 3),
                "comm": round(res.comm_ratio, 3),
                "down": round(res.down_ratio, 3),
            }))

    # buffered async under the bimodal population: the mask ledger vs the
    # PR-1 merge.  Wasted uplink is bytes stale clients uploaded for
    # units the current mask recycles — the ledger uses them instead
    sc = scaled_scenario("bimodal", model_bytes)
    for name, ledger, penalty in (("ledger", True, 0.0),
                                  ("ledger_pen", True, 1.0),
                                  ("noledger", False, 0.0)):
        cfg = FLConfig(n_clients=len(task.parts), n_active=8, tau=5,
                       batch_size=16, rounds=rounds,
                       client=ClientConfig(lr=0.05), eval_every=2,
                       luar=LuarConfig(delta=2, granularity="leaf",
                                       staleness_penalty=penalty))
        res, secs = timed(lambda cfg=cfg, ledger=ledger: run_sim(
            task.loss_fn, task.params, task.data, task.parts, cfg,
            SimConfig(scenario=sc, mode="fedbuff", buffer_size=4,
                      concurrency=16, mask_ledger=ledger), task.eval_fn))
        t_hit = time_to_target(res, "acc", target)
        out.append((f"tta_fedbuff_{name}", secs, {
            "t_target_s": round(t_hit, 2) if math.isfinite(t_hit) else "inf",
            "sim_time_s": round(res.sim_time, 2),
            "acc": round(res.history[-1]["acc"], 3),
            "wasted_mb": round(res.wasted_upload_bytes / 1e6, 3),
            "stal_q90": res.staleness_q["q90"] if res.staleness_q else 0.0,
        }))

    # participation policies under the mobile (bimodal) population:
    # uniform vs diurnal availability vs power-of-choice, with
    # comm-to-target and the per-client fairness spread side by side —
    # biased cohorts are only acceptable if both stay visible
    sc = scaled_scenario("bimodal", model_bytes)
    for part in ("uniform", "avail:diurnal", "powd:8"):
        cfg = FLConfig(n_clients=len(task.parts), n_active=8, tau=5,
                       batch_size=16, rounds=rounds,
                       client=ClientConfig(lr=0.05), eval_every=2,
                       luar=LuarConfig(delta=2, granularity="leaf"),
                       participation=part)
        res, secs = timed(lambda cfg=cfg: run_sim(
            task.loss_fn, task.params, task.data, task.parts, cfg,
            SimConfig(scenario=sc), task.eval_fn))
        t_hit = time_to_target(res, "acc", target)
        # uplink MB spent by the FIRST eval that cleared the target (the
        # history carries the cumulative ledger), inf if never reached
        comm_hit = next((h["up_mb"] for h in res.history
                         if h["acc"] >= target), math.inf)
        out.append((f"tta_part_{part.replace(':', '')}", secs, {
            "t_target_s": round(t_hit, 2) if math.isfinite(t_hit) else "inf",
            "comm_to_target_mb": (round(comm_hit, 2)
                                  if math.isfinite(comm_hit) else "inf"),
            "acc": round(res.history[-1]["acc"], 3),
            "fairness": {k: round(v, 1) for k, v in res.fairness.items()},
            "dropped": int(res.dropout_count.sum()),
        }))

    # the versioned downlink: the same fedbuff server with a delta-encoded
    # broadcast (down:delta) vs the full-model broadcast, BIDIRECTIONAL
    # byte totals.  Every client stays in flight and the buffer spans one
    # rotation, so redispatch lag is ~1 version and the delta chain beats
    # the snapshot on almost every download (the ledger prices the choice
    # per dispatch; first contacts still pay the cache-seeding snapshot)
    n_cl = len(task.parts)
    for name, codecs in (("full_bcast", ()), ("down_delta", ("down:delta",))):
        cfg = FLConfig(n_clients=n_cl, n_active=8, tau=5, batch_size=16,
                       rounds=rounds, client=ClientConfig(lr=0.05),
                       eval_every=2, codecs=codecs,
                       luar=LuarConfig(delta=4, granularity="leaf"))
        res, secs = timed(lambda cfg=cfg: run_sim(
            task.loss_fn, task.params, task.data, task.parts, cfg,
            SimConfig(scenario=scaled_scenario("uniform", model_bytes),
                      mode="fedbuff", buffer_size=n_cl, concurrency=n_cl),
            task.eval_fn))
        up_mb = res.comm_ratio * model_bytes * res.n_uplinks_spent / 1e6
        out.append((f"tta_fedbuff_{name}", secs, {
            "acc": round(res.history[-1]["acc"], 3),
            "up_ratio": round(res.comm_ratio, 3),
            "down_ratio": round(res.down_ratio, 3),
            "up_mb": round(up_mb, 2),
            "down_mb": round(res.downloaded / 1e6, 2),
            "total_mb": round(up_mb + res.downloaded / 1e6, 2),
            "delta_dls": f"{res.n_delta_downloads}/{res.n_dispatched}",
        }))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (synthetic FEMNIST + CNN)")
    ap.add_argument("--codecs", default="",
                    help="extra row: update-codec stack as '+'-separated "
                         "spec strings, e.g. 'fedpaq:4+topk:0.1+ef'")
    args = ap.parse_args(argv)
    specs = split_codec_specs(args.codecs)
    emit(rows(quick=not args.full, codec_specs=specs or None))


if __name__ == "__main__":
    main()
