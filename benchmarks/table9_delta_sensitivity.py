"""Tables 9-12: delta sensitivity — accuracy/communication vs the number
of recycled layers."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    task = make_task("mixture" if quick else "femnist")
    out = []
    n_units = 6  # MLP leaf units
    for delta in range(0, n_units):
        res, t = timed(lambda delta=delta: fl(
            task, rounds, luar=LuarConfig(delta=delta, granularity="leaf")))
        out.append((f"table9/delta{delta}", t / rounds, {
            "acc": round(res.history[-1]["acc"], 4),
            "comm": round(res.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
