"""Fleet-scale simulation benchmark (``repro.fleet``).

The claim under test: the wave-loop engine prices populations the event
heap cannot touch.  Two quick rows, one nightly row:

  fleet/fedbuff_256_smallN      the equivalence-scale run (the regime
                                ``tests/test_fleet.py`` pins against the
                                sim engine bit for bit) — the overhead
                                floor of the wave loop itself
  fleet/fedbuff_100k_diurnal    100_000 diurnally-available clients,
                                K=32 buffered LUAR merges, 1024 in
                                flight — the ISSUE's headline row; the
                                heap engine's event count alone makes
                                this regime unreachable for it
  fleet/fedbuff_1m_diurnal      (--full only) the same shape at one
                                MILLION clients

``secs`` is total engine wall; derived carries the population, rounds,
dispatch throughput (the population-scale figure of merit), the virtual
finish time, and — on the 100k row — the wall projected to 1M clients
in minutes (population-linear ops dominate; the nightly 1M row is the
measurement that keeps the projection honest).

  PYTHONPATH=src python -m benchmarks.fleet_bench
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.rounds import FLConfig
from repro.fleet import run_fleet
from repro.models.cnn import mlp_apply, mlp_init, softmax_xent
from repro.sim import SimConfig


def _task(seed: int = 0):
    x, y = gaussian_mixture(2000, n_classes=10, d=32, seed=seed)
    params = mlp_init(jax.random.PRNGKey(seed), n_features=32, n_classes=10)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    # the fleet proxy-pool layout: every client samples from one shared
    # index pool (no per-client partition exists at N ~ 10^5)
    return loss_fn, params, {"x": x, "y": y}, np.arange(len(y))


def _run(n_clients: int, rounds: int, K: int, concurrency: int):
    loss_fn, params, data, pool = _task()
    cfg = FLConfig(n_clients=n_clients, n_active=concurrency, tau=1,
                   batch_size=16, client=ClientConfig(lr=0.05),
                   rounds=rounds, eval_every=10 ** 6,
                   luar=LuarConfig(delta=2),
                   participation="avail:diurnal:0.5")
    sim = SimConfig(mode="fedbuff", scenario="diurnal", buffer_size=K,
                    concurrency=concurrency, ledger_capacity=64)
    t0 = time.perf_counter()
    res = run_fleet(loss_fn, params, data, pool, cfg, sim)
    wall = time.perf_counter() - t0
    return wall, res


def _derived(wall: float, res, n_clients: int) -> dict:
    return {
        "clients": n_clients,
        "rounds": res.rounds_done,
        "dispatches": res.n_dispatched,
        "accepted": res.n_received,
        "sim_time_s": round(res.sim_time, 3),
        "comm_ratio": round(res.comm_ratio, 4),
        "dispatch_per_s": round(res.n_dispatched / max(wall, 1e-9), 1),
    }


def rows(quick: bool = True):
    out = []

    wall, res = _run(n_clients=256, rounds=10, K=8, concurrency=32)
    out.append(("fleet/fedbuff_256_smallN", wall,
                _derived(wall, res, 256)))

    wall, res = _run(n_clients=100_000, rounds=15, K=32, concurrency=1024)
    d = _derived(wall, res, 100_000)
    # population-linear projection the nightly 1M row keeps honest
    d["projected_1m_min"] = round(wall * 10.0 / 60.0, 2)
    out.append(("fleet/fedbuff_100k_diurnal", wall, d))

    if not quick:
        wall, res = _run(n_clients=1_000_000, rounds=10, K=64,
                         concurrency=4096)
        out.append(("fleet/fedbuff_1m_diurnal", wall,
                    _derived(wall, res, 1_000_000)))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=True)
