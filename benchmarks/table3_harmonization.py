"""Table 3: LUAR composes with advanced FL optimizers (FedProx / FedOpt /
FedACG / FedPAQ) — accuracy with and without recycling."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig
from repro.fl.client import ClientConfig
from repro.fl.server import ServerConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    task = make_task("mixture" if quick else "femnist")
    luar = LuarConfig(delta=2, granularity="leaf")
    variants = {
        "fedprox": dict(client=ClientConfig(lr=0.05, prox_mu=0.001)),
        "fedopt": dict(server=ServerConfig(kind="fedopt", lr=0.2)),
        "fedacg": dict(server=ServerConfig(kind="fedacg", acg_lambda=0.5)),
        "fedpaq": dict(codecs=("fedpaq:8",)),
    }
    out = []
    for name, kw in variants.items():
        base, t1 = timed(lambda kw=kw: fl(task, rounds, **kw))
        with_luar, t2 = timed(lambda kw=kw: fl(task, rounds, luar=luar, **kw))
        out.append((f"table3/{name}", t1 / rounds, {
            "acc": round(base.history[-1]["acc"], 4),
            "acc_luar": round(with_luar.history[-1]["acc"], 4),
            "comm_luar": round(with_luar.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
