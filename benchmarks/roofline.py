"""Roofline report.

Two row families:

  * ``roofline/<arch>/...`` — reads the dry-run JSONs
    (experiments/dryrun/) and prints, per (arch x shape x mesh): the
    three time terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS,
    and what would move the dominant term.  Needs the sweep first:
    ``PYTHONPATH=src python -m repro.launch.sweep`` (rows skip silently
    without it — CI runs none of the sweep).

  * ``roofline/server_agg/...`` — the fused-vs-reference server
    aggregation roofline, computed from first principles (no dryruns):
    the LUAR round is pure streaming (O(1) flops per loaded byte), so
    its TPU time floor is bytes-moved / HBM bandwidth.  The rows price
    the per-leaf reference's separate merge/select/metric/norm passes
    against the batched kernel's single sweep and report the projected
    round time at a v4-class 1.2 TB/s — the artifact the nightly job
    uploads so the HBM-pass claim in BENCH_kernels.json has its
    derivation on disk.
"""
from __future__ import annotations

import json
import os

from repro.launch.sweep import ARCHS, SHAPES, path_for

ADVICE = {
    "compute_s": "raise arithmetic intensity / fewer remat passes",
    "memory_s": "Pallas flash-attention keeps score tiles in VMEM",
    "collective_s": "static LUAR schedule drops gated all-reduces",
}

HBM_GBPS = 1200.0               # v4-class reference bandwidth


def server_agg_rows(quick: bool = True) -> list[tuple[str, float, dict]]:
    """Bandwidth-bound roofline of the server aggregation round.

    Element traffic per full round, in model-sized f32 passes:
      reference — merge reads K deltas + fallback and writes the merged
      update (K+2), the recycle select reads merged + prev and writes
      applied (3), the s-metric reads applied + params (2) and the
      grad-norm pass reads applied again (1): K+8 total;
      fused — one sweep reads K deltas + prev + params and writes
      applied: K+3.
    The projected times are those traffic totals at ``HBM_GBPS``; the
    measured interpret-mode walls live in BENCH_kernels.json.
    """
    import jax

    from benchmarks.kernels_bench import model_mb
    from repro.models.cnn import cnn_init

    out: list[tuple[str, float, dict]] = []
    params = cnn_init(jax.random.PRNGKey(0))
    mb = model_mb(params)
    for K in (1, 4) if quick else (1, 4, 16, 64):
        ref_mb = (K + 8) * mb
        fused_mb = (K + 3) * mb
        ref_s = ref_mb / 1e3 / HBM_GBPS
        fused_s = fused_mb / 1e3 / HBM_GBPS
        out.append((f"roofline/server_agg/cnn/K{K}", fused_s, {
            "model_mb": round(mb, 2),
            "ref_hbm_mb": round(ref_mb, 1),
            "fused_hbm_mb": round(fused_mb, 1),
            "ref_s_at_1.2TBps": round(ref_s, 9),
            "fused_s_at_1.2TBps": round(fused_s, 9),
            "traffic_reduction": round(ref_mb / fused_mb, 2),
            "tree_passes_ref": 4,
            "tree_passes_fused": 1,
        }))
    return out


def rows(quick: bool = True) -> list[tuple[str, float, dict]]:
    out = server_agg_rows(quick)
    meshes = (False,) if quick else (False, True)
    for arch in ARCHS:
        for shape in SHAPES:
            for mp in meshes:
                p = path_for(arch, shape, mp)
                if not os.path.exists(p):
                    continue
                rec = json.load(open(p))
                tag = f"roofline/{arch}/{shape}/{'pod2' if mp else 'pod1'}"
                if "skipped" in rec:
                    out.append((tag, 0.0, {"skipped": "sub-quadratic-only"}))
                    continue
                rl = rec["roofline"]
                dom = rl["bottleneck"]
                out.append((tag, rl[dom], {
                    "compute_s": round(rl["compute_s"], 3),
                    "memory_s": round(rl["memory_s"], 3),
                    "collective_s": round(rl["collective_s"], 3),
                    "bottleneck": dom,
                    "useful_flops": round(rec.get("useful_flops_ratio", 0), 3),
                    "fix": ADVICE[dom],
                }))
    return out


def main(quick: bool = True):
    from benchmarks.common import emit
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
