"""Roofline report: reads the dry-run JSONs (experiments/dryrun/) and
prints, per (arch x shape x mesh): the three time terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and what would move the dominant term.

Run the sweep first:  PYTHONPATH=src python -m repro.launch.sweep
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

from repro.launch.sweep import ARCHS, SHAPES, path_for

ADVICE = {
    "compute_s": "raise arithmetic intensity / fewer remat passes",
    "memory_s": "Pallas flash-attention keeps score tiles in VMEM",
    "collective_s": "static LUAR schedule drops gated all-reduces",
}


def rows(quick: bool = True) -> List[Tuple[str, float, Dict]]:
    out = []
    meshes = (False,) if quick else (False, True)
    for arch in ARCHS:
        for shape in SHAPES:
            for mp in meshes:
                p = path_for(arch, shape, mp)
                if not os.path.exists(p):
                    continue
                rec = json.load(open(p))
                tag = f"roofline/{arch}/{shape}/{'pod2' if mp else 'pod1'}"
                if "skipped" in rec:
                    out.append((tag, 0.0, {"skipped": "sub-quadratic-only"}))
                    continue
                rl = rec["roofline"]
                dom = rl["bottleneck"]
                out.append((tag, rl[dom], {
                    "compute_s": round(rl["compute_s"], 3),
                    "memory_s": round(rl["memory_s"], 3),
                    "collective_s": round(rl["collective_s"], 3),
                    "bottleneck": dom,
                    "useful_flops": round(rec.get("useful_flops_ratio", 0), 3),
                    "fix": ADVICE[dom],
                }))
    return out


def main(quick: bool = True):
    from benchmarks.common import emit
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
