"""Table 5: update Dropping vs Recycling at identical communication."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 30 if quick else 150
    task = make_task("mixture" if quick else "femnist")
    out = []
    for delta in ((2, 3) if quick else (2, 3, 4)):
        rec, t1 = timed(lambda delta=delta: fl(
            task, rounds, luar=LuarConfig(delta=delta, granularity="leaf")))
        drp, t2 = timed(lambda delta=delta: fl(
            task, rounds, luar=LuarConfig(delta=delta, granularity="leaf",
                                          mode="drop")))
        out.append((f"table5/delta{delta}", (t1 + t2) / (2 * rounds), {
            "acc_recycle": round(rec.history[-1]["acc"], 4),
            "acc_drop": round(drp.history[-1]["acc"], 4),
            "comm": round(rec.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
