"""Perf-trajectory regression gate over ``BENCH_*.json`` snapshots.

Two modes:

  * no arguments — validate every committed ``BENCH_*.json`` at the
    repo root (schema version, non-empty rows, finite timings, footer
    present).  This is the cheap tier-1 sanity pass: the committed
    trajectory must always be loadable by the comparator.

      PYTHONPATH=src python -m benchmarks.check_regression

  * ``--baseline`` + ``--fresh`` — compare a freshly recorded snapshot
    against the committed baseline.  A row regresses when its
    ``us_per_call`` exceeds baseline by more than ``--tolerance``
    (a RATIO, default 3.0: CI runners are noisy shared VMs, so the gate
    only catches step-function blowups — an accidentally interpreted
    kernel, a jit cache miss in the hot loop — not percent-level drift).
    Rows missing from fresh count as coverage regressions; new rows are
    fine.  ``--soft`` demotes failure to a warning and exit 0 (tier-1
    stays green on a noisy runner; the nightly full run uploads fresh
    artifacts for human eyes).  ``--format=github`` renders every
    message as a workflow-command annotation (``::error::`` when the
    gate is hard, ``::warning::`` when soft or informational) so the CI
    run surfaces them inline; the default ``text`` stays plain for
    local shells.

      python -m benchmarks.run --record --only kernels --out-dir /tmp/b
      python -m benchmarks.check_regression \\
          --baseline BENCH_kernels.json --fresh /tmp/b/BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import sys

from benchmarks.common import BENCH_SCHEMA

REQUIRED_FOOTER = ("total_wall_s", "git_sha", "jax_version")


def _emit(msg: str, kind: str, fmt: str, stream=None) -> None:
    """Print ``msg`` plainly (text) or as a ``::error::``/``::warning::``
    workflow command (github)."""
    stream = stream or sys.stdout
    print(f"::{kind}::{msg}" if fmt == "github" else msg, file=stream)
# "dirty" is OPTIONAL footer (schema 1 back-compat: snapshots recorded
# before the flag existed still load); when present and true the snapshot
# was recorded from an uncommitted tree, so its stamped SHA alone cannot
# reproduce the numbers — every consumer warns below.


def dirty_warning(doc: dict, path: str) -> str:
    """Non-empty message when a snapshot's footer says the tree was dirty
    at record time (or the flag is absent AND the snapshot claims an
    unknown sha)."""
    footer = doc.get("footer", {})
    if footer.get("dirty"):
        return (f"{path}: recorded from a DIRTY working tree — sha "
                f"{footer.get('git_sha')} does not reproduce these numbers")
    return ""


def load_snapshot(path: str) -> dict:
    """Load + validate one BENCH_*.json snapshot; raise ValueError with
    the reason on any malformation."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"{path}: unreadable ({e})") from e
    if doc.get("schema") != BENCH_SCHEMA:
        raise ValueError(f"{path}: schema {doc.get('schema')!r} != "
                         f"{BENCH_SCHEMA}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"{path}: no rows")
    for r in rows:
        if not isinstance(r.get("name"), str):
            raise ValueError(f"{path}: row without a name: {r!r}")
        us = r.get("us_per_call")
        if not isinstance(us, (int, float)) or not math.isfinite(us) or us < 0:
            raise ValueError(f"{path}: row {r['name']!r} has bad "
                             f"us_per_call {us!r}")
    footer = doc.get("footer")
    if not isinstance(footer, dict):
        raise ValueError(f"{path}: missing footer")
    missing = [k for k in REQUIRED_FOOTER if k not in footer]
    if missing:
        raise ValueError(f"{path}: footer missing {missing}")
    return doc


def compare(baseline: dict, fresh: dict, tolerance: float) -> list[str]:
    """Regression messages (empty = pass)."""
    problems: list[str] = []
    base_rows = {r["name"]: r for r in baseline["rows"]}
    fresh_rows = {r["name"]: r for r in fresh["rows"]}
    for name, b in base_rows.items():
        f = fresh_rows.get(name)
        if f is None:
            problems.append(f"{name}: present in baseline, missing from "
                            "fresh run (coverage regression)")
            continue
        if b["us_per_call"] <= 0:
            continue                    # degenerate baseline: nothing to gate
        ratio = f["us_per_call"] / b["us_per_call"]
        if ratio > tolerance:
            problems.append(
                f"{name}: {b['us_per_call']:.1f}us -> "
                f"{f['us_per_call']:.1f}us ({ratio:.2f}x > "
                f"{tolerance:.2f}x tolerance)")
    return problems


def validate_committed(root: str = ".", fmt: str = "text") -> int:
    paths = sorted(glob.glob(f"{root}/BENCH_*.json"))
    if not paths:
        print(f"no BENCH_*.json snapshots under {root!r}", file=sys.stderr)
        return 2
    for p in paths:
        doc = load_snapshot(p)
        print(f"{p}: ok — {len(doc['rows'])} rows, "
              f"sha {doc['footer']['git_sha']}, "
              f"jax {doc['footer']['jax_version']}")
        warn = dirty_warning(doc, p)
        if warn:
            _emit(warn, "warning", fmt, sys.stderr)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="",
                    help="committed BENCH_*.json to compare against")
    ap.add_argument("--fresh", default="",
                    help="freshly recorded BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="max fresh/baseline us_per_call ratio "
                         "(default 3.0)")
    ap.add_argument("--soft", action="store_true",
                    help="on regression print ::warning:: and exit 0")
    ap.add_argument("--root", default=".",
                    help="where no-arg mode looks for BENCH_*.json")
    ap.add_argument("--format", choices=("text", "github"), default="text",
                    help="github = workflow-command annotations "
                         "(::error:: hard, ::warning:: soft)")
    args = ap.parse_args(argv)

    if bool(args.baseline) != bool(args.fresh):
        ap.error("--baseline and --fresh must be given together")
    if not args.baseline:
        return validate_committed(args.root, args.format)

    try:
        base = load_snapshot(args.baseline)
        fresh = load_snapshot(args.fresh)
    except ValueError as e:
        _emit(str(e), "warning" if args.soft else "error", args.format,
              sys.stderr)
        return 0 if args.soft else 2
    warn = dirty_warning(base, args.baseline)
    if warn:
        # never fatal: a dirty BASELINE is a provenance problem, not a
        # perf regression — flag it for human eyes in both modes
        _emit(f"comparing against a dirty baseline — {warn}", "warning",
              args.format, sys.stderr)
    problems = compare(base, fresh, args.tolerance)
    if not problems:
        print(f"perf gate ok: {len(fresh['rows'])} rows within "
              f"{args.tolerance:.2f}x of {args.baseline} "
              f"(sha {base['footer']['git_sha']})")
        return 0
    for msg in problems:
        _emit(f"perf regression — {msg}",
              "warning" if args.soft else "error", args.format)
    return 0 if args.soft else 1


if __name__ == "__main__":
    sys.exit(main())
