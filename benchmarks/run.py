"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default is the quick profile
(synthetic mixture task, short rounds); pass ``--full`` for the
paper-scale settings (synthetic FEMNIST + CNN, long rounds).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,kernels]

``--record`` persists each module's rows as ``BENCH_<module>.json``
(schema + machine-readable footer: total wall time, git SHA, jax
version) — the perf-trajectory snapshots ``check_regression.py`` gates
CI against.  ``--only`` takes a comma-separated module list and raises
``ValueError`` on an unknown key so a typo'd CI job fails loudly
instead of silently benchmarking nothing.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig1_motivation, fig3_layer_counts, fig4_curves,
                        fleet_bench, kernels_bench, roofline, serve_bench,
                        table1_memory, table2_comparative,
                        table3_harmonization, table4_selection,
                        table5_drop_vs_recycle, table9_delta_sensitivity,
                        table13_alpha, table15_clients, time_to_accuracy)
from benchmarks.common import bench_record, emit

MODULES = {
    "table1": table1_memory,
    "table2": table2_comparative,
    "table3": table3_harmonization,
    "table4": table4_selection,
    "table5": table5_drop_vs_recycle,
    "table9": table9_delta_sensitivity,
    "table13": table13_alpha,
    "table15": table15_clients,
    "fig1": fig1_motivation,
    "fig3": fig3_layer_counts,
    "fig4": fig4_curves,
    "roofline": roofline,
    "kernels": kernels_bench,
    "tta": time_to_accuracy,
    "serve": serve_bench,
    "fleet": fleet_bench,
}


def resolve_only(only: str) -> list:
    """Comma-separated ``--only`` values -> module keys, loudly."""
    names = [n.strip() for n in only.split(",") if n.strip()]
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        raise ValueError(
            f"unknown benchmark module(s) {unknown}; "
            f"valid keys: {', '.join(MODULES)}")
    return names


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (synthetic FEMNIST + CNN)")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick profile (the default; mutually "
                         "exclusive with --full)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of modules to run")
    ap.add_argument("--record", action="store_true",
                    help="write BENCH_<module>.json perf snapshots")
    ap.add_argument("--out-dir", default=".",
                    help="directory for --record snapshots (default: cwd)")
    args = ap.parse_args(argv)
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    quick = not args.full
    names = resolve_only(args.only) if args.only else list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        t_mod = time.time()
        try:
            rows = MODULES[name].rows(quick)
        except Exception as e:  # keep the harness running
            print(f"{name},0,ERROR={type(e).__name__}:{e}", file=sys.stdout)
            continue
        emit(rows)
        if args.record:
            path = bench_record(name, rows, time.time() - t_mod, quick,
                                args.out_dir)
            print(f"# recorded {path}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
