"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default is the quick profile
(synthetic mixture task, short rounds); pass ``--full`` for the
paper-scale settings (synthetic FEMNIST + CNN, long rounds).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig1_motivation, fig3_layer_counts, fig4_curves,
                        kernels_bench, roofline, table1_memory,
                        table2_comparative, table3_harmonization,
                        table4_selection, table5_drop_vs_recycle,
                        table9_delta_sensitivity, table13_alpha,
                        table15_clients, time_to_accuracy)
from benchmarks.common import emit

MODULES = {
    "table1": table1_memory,
    "table2": table2_comparative,
    "table3": table3_harmonization,
    "table4": table4_selection,
    "table5": table5_drop_vs_recycle,
    "table9": table9_delta_sensitivity,
    "table13": table13_alpha,
    "table15": table15_clients,
    "fig1": fig1_motivation,
    "fig3": fig3_layer_counts,
    "fig4": fig4_curves,
    "roofline": roofline,
    "kernels": kernels_bench,
    "tta": time_to_accuracy,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    quick = not args.full
    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        try:
            emit(MODULES[name].rows(quick))
        except Exception as e:  # keep the harness running
            print(f"{name},0,ERROR={type(e).__name__}:{e}", file=sys.stdout)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
