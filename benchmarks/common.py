"""Shared harness for the paper-table benchmarks.

Two workloads mirror the paper's regimes at laptop scale:
  * "femnist" — synthetic 28x28 images + the paper's 4-layer CNN
    (module-granularity LUAR units, delta in 0..3 as in Table 11);
  * "mixture" — Gaussian mixture + MLP (fast; used by run.py quick mode).

Every benchmark returns rows of (name, seconds, metrics-dict) and run.py
prints the ``name,us_per_call,derived`` CSV contract.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture, synthetic_images
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, FLResult, run_fl
from repro.fl.server import ServerConfig
from repro.models.cnn import cnn_init, cnn_apply, mlp_init, mlp_apply, softmax_xent


class Task:
    def __init__(self, loss_fn, eval_fn, params, data, parts):
        self.loss_fn, self.eval_fn = loss_fn, eval_fn
        self.params, self.data, self.parts = params, data, parts


def make_task(kind: str = "mixture", n_clients: int = 24, alpha: float = 0.1,
              seed: int = 0) -> Task:
    if kind == "mixture":
        x, y = gaussian_mixture(3000, n_classes=10, d=32, seed=seed)
        xt, yt = gaussian_mixture(800, n_classes=10, d=32, seed=seed + 1)
        params = mlp_init(jax.random.PRNGKey(seed), n_features=32, n_classes=10)
        apply_fn = mlp_apply
    elif kind == "femnist":
        x, y = synthetic_images(3000, n_classes=16, seed=seed)
        xt, yt = synthetic_images(800, n_classes=16, seed=seed + 1)
        params = cnn_init(jax.random.PRNGKey(seed), n_classes=16)
        apply_fn = cnn_apply
    else:
        raise ValueError(kind)
    parts = dirichlet_partition(y, n_clients, alpha=alpha, seed=seed)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def loss_fn(p, b):
        return softmax_xent(apply_fn(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(apply_fn(p, xt_j), -1) == yt_j))}

    return Task(loss_fn, eval_fn, params, {"x": x, "y": y}, parts)


def fl(task: Task, rounds: int = 30, *, luar: Optional[LuarConfig] = None,
       server: Optional[ServerConfig] = None, client: Optional[ClientConfig] = None,
       codecs: Tuple[str, ...] = (),
       n_active: int = 8, tau: int = 5, eval_every: int = 0) -> FLResult:
    cfg = FLConfig(
        n_clients=len(task.parts), n_active=n_active, tau=tau, batch_size=16,
        rounds=rounds,
        client=client or ClientConfig(lr=0.05),
        server=server or ServerConfig(),
        luar=luar or LuarConfig(),
        codecs=tuple(codecs),
        eval_every=eval_every or rounds)
    return run_fl(task.loss_fn, task.params, task.data, task.parts, cfg,
                  task.eval_fn)


def timed(fn: Callable[[], FLResult]) -> Tuple[FLResult, float]:
    t0 = time.time()
    res = fn()
    return res, time.time() - t0


def emit(rows: List[Tuple[str, float, Dict]]):
    for name, secs, derived in rows:
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{secs * 1e6:.0f},{d}")
