"""Shared harness for the paper-table benchmarks.

Two workloads mirror the paper's regimes at laptop scale:
  * "femnist" — synthetic 28x28 images + the paper's 4-layer CNN
    (module-granularity LUAR units, delta in 0..3 as in Table 11);
  * "mixture" — Gaussian mixture + MLP (fast; used by run.py quick mode).

Every benchmark returns rows of (name, seconds, metrics-dict) and run.py
prints the ``name,us_per_call,derived`` CSV contract.  ``--record``
additionally persists each module's rows as a ``BENCH_<module>.json``
perf-trajectory snapshot (schema below) that
``benchmarks/check_regression.py`` gates CI against.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture, synthetic_images
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, FLResult, run_fl
from repro.fl.server import ServerConfig
from repro.models.cnn import cnn_init, cnn_apply, mlp_init, mlp_apply, softmax_xent


class Task:
    def __init__(self, loss_fn, eval_fn, params, data, parts):
        self.loss_fn, self.eval_fn = loss_fn, eval_fn
        self.params, self.data, self.parts = params, data, parts


def make_task(kind: str = "mixture", n_clients: int = 24, alpha: float = 0.1,
              seed: int = 0) -> Task:
    if kind == "mixture":
        x, y = gaussian_mixture(3000, n_classes=10, d=32, seed=seed)
        xt, yt = gaussian_mixture(800, n_classes=10, d=32, seed=seed + 1)
        params = mlp_init(jax.random.PRNGKey(seed), n_features=32, n_classes=10)
        apply_fn = mlp_apply
    elif kind == "femnist":
        x, y = synthetic_images(3000, n_classes=16, seed=seed)
        xt, yt = synthetic_images(800, n_classes=16, seed=seed + 1)
        params = cnn_init(jax.random.PRNGKey(seed), n_classes=16)
        apply_fn = cnn_apply
    else:
        raise ValueError(kind)
    parts = dirichlet_partition(y, n_clients, alpha=alpha, seed=seed)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def loss_fn(p, b):
        return softmax_xent(apply_fn(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(apply_fn(p, xt_j), -1) == yt_j))}

    return Task(loss_fn, eval_fn, params, {"x": x, "y": y}, parts)


def fl(task: Task, rounds: int = 30, *, luar: LuarConfig | None = None,
       server: ServerConfig | None = None, client: ClientConfig | None = None,
       codecs: tuple[str, ...] = (),
       n_active: int = 8, tau: int = 5, eval_every: int = 0) -> FLResult:
    cfg = FLConfig(
        n_clients=len(task.parts), n_active=n_active, tau=tau, batch_size=16,
        rounds=rounds,
        client=client or ClientConfig(lr=0.05),
        server=server or ServerConfig(),
        luar=luar or LuarConfig(),
        codecs=tuple(codecs),
        eval_every=eval_every or rounds)
    return run_fl(task.loss_fn, task.params, task.data, task.parts, cfg,
                  task.eval_fn)


def timed(fn: Callable[[], FLResult]) -> tuple[FLResult, float]:
    t0 = time.time()
    res = fn()
    return res, time.time() - t0


def emit(rows: list[tuple[str, float, dict]]):
    for name, secs, derived in rows:
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{secs * 1e6:.0f},{d}")


# -- perf-trajectory snapshots (BENCH_*.json) -------------------------------

BENCH_SCHEMA = 1


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def git_dirty() -> bool:
    """True when the working tree differs from HEAD — a snapshot recorded
    then does NOT reproduce from the stamped SHA alone.  Unknown (not a
    repo, git missing) counts as dirty: an unverifiable claim is treated
    like a false one."""
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True).stdout
        return bool(out.strip())
    except Exception:
        return True


def bench_record(suite: str, rows: list[tuple[str, float, dict]],
                 wall_s: float, quick: bool, out_dir: str = ".") -> str:
    """Persist one suite's rows as ``BENCH_<suite>.json``.

    The machine-readable footer (total wall time, git SHA, jax version)
    makes every snapshot self-describing, so a regression report can say
    WHICH commit and runtime produced the numbers it compares."""
    import jax

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    doc = {
        "schema": BENCH_SCHEMA,
        "suite": suite,
        "quick": bool(quick),
        "rows": [{"name": name, "us_per_call": round(secs * 1e6, 1),
                  "derived": {k: v for k, v in derived.items()}}
                 for name, secs, derived in rows],
        "footer": {
            "total_wall_s": round(wall_s, 2),
            "git_sha": git_sha(),
            # an honest SHA claim: dirty=True flags that the tree had
            # uncommitted changes, so the SHA alone doesn't reproduce
            # these numbers (check_regression warns on such baselines)
            "dirty": git_dirty(),
            "jax_version": jax.__version__,
        },
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=False)
        fh.write("\n")
    return path
