"""Table 2: comparative study — FedAvg vs SOTA communication-efficient FL
methods vs FedLUAR, accuracy at reduced communication."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    kind = "mixture" if quick else "femnist"
    delta = 2
    task = make_task(kind)
    out = []

    def add(name, res, secs, comm=None):
        out.append((f"table2/{name}", secs / max(res.luar_state.round, 1) if res else secs, {
            "acc": round(res.history[-1]["acc"], 4),
            "comm": round(comm if comm is not None else res.comm_ratio, 3),
            "down": round(res.down_ratio, 3)}))

    res, t = timed(lambda: fl(task, rounds))
    add("fedavg", res, t)
    res, t = timed(lambda: fl(task, rounds, codecs=("fedpaq:8",)))
    add("fedpaq_8bit", res, t, comm=res.comm_ratio)
    res, t = timed(lambda: fl(task, rounds, codecs=("lbgm:0.9",)))
    add("lbgm", res, t)
    res, t = timed(lambda: fl(task, rounds, codecs=("prune:0.25",)))
    add("prunefl_25pct", res, t, comm=res.comm_ratio)
    res, t = timed(lambda: fl(task, rounds, codecs=("dropout:0.5",)))
    add("feddropoutavg", res, t, comm=res.comm_ratio)
    # stages the legacy scalar flags could not express: global top-k with
    # value+index pricing, and the quantize+sparsify stack wrapped in
    # per-round error feedback
    res, t = timed(lambda: fl(task, rounds, codecs=("topk:0.1",)))
    add("topk_10pct", res, t, comm=res.comm_ratio)
    res, t = timed(lambda: fl(task, rounds, codecs=("fedpaq:4", "topk:0.1", "ef")))
    add("paq4_topk_ef", res, t, comm=res.comm_ratio)
    res, t = timed(lambda: fl(task, rounds,
                              luar=LuarConfig(delta=delta, mode="drop",
                                              granularity="leaf")))
    add("dropping", res, t)
    res, t = timed(lambda: fl(task, rounds,
                              luar=LuarConfig(delta=delta, granularity="leaf")))
    add("fedluar", res, t)
    # the versioned downlink: same recycling, but the broadcast is the
    # delta chain against the cohort's previous version instead of a full
    # snapshot — the "down" column finally moves below 1.0 (the paper's
    # 17%-of-FedAvg number is uplink-only; this is the other half)
    res, t = timed(lambda: fl(task, rounds, codecs=("down:delta",),
                              luar=LuarConfig(delta=delta, granularity="leaf")))
    add("fedluar_ddl", res, t)
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
