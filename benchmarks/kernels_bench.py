"""Microbenchmarks: LUAR server-op + kernel wall times (CPU numbers are
indicative only; the kernels target TPU)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import LuarConfig, luar_init, luar_round
from repro.kernels import ops
from repro.models.cnn import cnn_init


def _time(fn, reps=5):
    fn()  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def rows(quick: bool = True):
    out = []
    params = cnn_init(jax.random.PRNGKey(0))
    cfg = LuarConfig(delta=2, granularity="module")
    state, um = luar_init(params, cfg, jax.random.PRNGKey(1))
    upd = jax.tree.map(jnp.ones_like, params)
    step = jax.jit(lambda s, u: luar_round(s, um, cfg, u, params))
    t = _time(lambda: step(state, upd)[1].s)
    out.append(("bench/luar_round_cnn", t, {"units": len(um.names)}))

    if not quick:
        S = 1024
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 8, S, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 8, S, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 8, S, 64), jnp.float32)
        t = _time(lambda: ops.flash_attention(q, k, v, interpret=True), reps=2)
        out.append(("bench/flash_attention_interp_1k", t, {"note": "interpret-mode"}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
