"""Microbenchmarks: LUAR server-op + kernel wall times (CPU numbers are
indicative only; the kernels target TPU)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import LuarConfig, luar_init, luar_round
from repro.kernels import ops
from repro.models.cnn import cnn_init


def _time(fn, reps=5):
    """Per-rep wall times with the async dispatch fence INSIDE the loop.

    The old version blocked once after the whole loop, so each lap
    clocked only dispatch (~us) while the device was still chewing — and
    the mean hid the compile-adjacent first-rep jitter.  Blocking every
    rep times actual execution; ``min`` is the steady-state number the
    regression gate tracks, ``mean`` rides along in ``derived``.
    """
    jax.block_until_ready(fn())     # compile + warm caches
    laps = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        laps.append(time.perf_counter() - t0)
    return min(laps), sum(laps) / len(laps)


def rows(quick: bool = True):
    out = []
    params = cnn_init(jax.random.PRNGKey(0))
    cfg = LuarConfig(delta=2, granularity="module")
    state, um = luar_init(params, cfg, jax.random.PRNGKey(1))
    upd = jax.tree.map(jnp.ones_like, params)
    step = jax.jit(lambda s, u: luar_round(s, um, cfg, u, params))
    t_min, t_mean = _time(lambda: step(state, upd)[1].s)
    out.append(("bench/luar_round_cnn", t_min,
                {"units": len(um.names),
                 "mean_us": round(t_mean * 1e6, 1)}))

    if not quick:
        S = 1024
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 8, S, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 8, S, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 8, S, 64), jnp.float32)
        t_min, t_mean = _time(
            lambda: ops.flash_attention(q, k, v, interpret=True), reps=2)
        out.append(("bench/flash_attention_interp_1k", t_min,
                    {"note": "interpret-mode",
                     "mean_us": round(t_mean * 1e6, 1)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
