"""Microbenchmarks: LUAR server-op + kernel wall times (CPU numbers are
indicative only; the kernels target TPU).

The fused rows carry a modeled HBM-traffic figure next to the measured
wall time: ``model_passes`` counts how many times the round's math
sweeps the full parameter set through memory (the per-leaf reference
does merge, select, s-metric and grad-norm as SEPARATE tree-wide
passes; the batched kernel does all four in one), and ``hbm_mb`` is
that pass count priced in f32 model bytes.  On the CPU container the
wall numbers time interpret-mode emulation, so the pass count is the
architecture-honest claim the TPU inherits; the regression gate prices
every row against its own committed baseline either way."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (LuarConfig, fused_buffer_round, luar_init,
                        luar_round, staleness_weighted_merge)
from repro.kernels import ops
from repro.models.cnn import cnn_init


def _time(fn, reps=5):
    """Per-rep wall times with the async dispatch fence INSIDE the loop.

    The old version blocked once after the whole loop, so each lap
    clocked only dispatch (~us) while the device was still chewing — and
    the mean hid the compile-adjacent first-rep jitter.  Blocking every
    rep times actual execution; ``min`` is the steady-state number the
    regression gate tracks, ``mean`` rides along in ``derived``.
    """
    jax.block_until_ready(fn())     # compile + warm caches
    laps = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        laps.append(time.perf_counter() - t0)
    return min(laps), sum(laps) / len(laps)


def model_mb(params) -> float:
    """f32 parameter footprint in MB (one full HBM pass moves this)."""
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    return n * 4 / 1e6


def rows(quick: bool = True):
    out = []
    params = cnn_init(jax.random.PRNGKey(0))
    cfg = LuarConfig(delta=2, granularity="module")
    state, um = luar_init(params, cfg, jax.random.PRNGKey(1))
    upd = jax.tree.map(jnp.ones_like, params)
    mb = model_mb(params)
    step = jax.jit(lambda s, u: luar_round(s, um, cfg, u, params))
    t_min, t_mean = _time(lambda: step(state, upd)[1].s)
    out.append(("bench/luar_round_cnn", t_min,
                {"units": len(um.names),
                 "model_passes": 3, "hbm_mb": round(3 * mb, 1),
                 "mean_us": round(t_mean * 1e6, 1)}))

    # same round through the batched multi-unit kernel (select + both
    # Eq. (1) norms in one sweep instead of three tree-wide passes)
    fcfg = cfg._replace(fused_agg=True)
    fstep = jax.jit(lambda s, u: luar_round(s, um, fcfg, u, params))
    f_min, f_mean = _time(lambda: fstep(state, upd)[1].s)
    out.append(("bench/luar_round_cnn_fused", f_min,
                {"units": len(um.names),
                 "model_passes": 1, "hbm_mb": round(mb, 1),
                 "wall_vs_ref": round(f_min / max(t_min, 1e-9), 2),
                 "note": "interpret-mode off-TPU",
                 "mean_us": round(f_mean * 1e6, 1)}))

    # the fedbuff server round: K-buffer validity merge + LUAR.  The
    # reference does merge / select / s-metric / grad-norm as four
    # separate passes; fused_buffer_round is one kernel sweep.
    K = 4
    stacked = jax.tree.map(
        lambda l: jnp.stack([l * (i + 1.0) for i in range(K)]), upd)
    staleness = jnp.asarray([0, 1, 3, 7], jnp.int32)
    validity = jnp.asarray(
        np.random.default_rng(0).random((K, len(um.names))) > 0.3)

    def ref_round(s, st):
        fresh = staleness_weighted_merge(st, staleness, 0.5,
                                         validity=validity, um=um,
                                         fallback=s.prev_update)
        eff = ~jnp.any(validity, axis=0)
        return luar_round(s, um, cfg, fresh, params, mask_override=eff)

    rstep = jax.jit(ref_round)
    r_min, r_mean = _time(lambda: rstep(state, stacked)[1].s)
    out.append(("bench/fedbuff_round_cnn", r_min,
                {"units": len(um.names), "K": K,
                 "model_passes": 4, "hbm_mb": round(4 * mb, 1),
                 "mean_us": round(r_mean * 1e6, 1)}))

    fbstep = jax.jit(lambda s, st: fused_buffer_round(
        s, um, fcfg, st, staleness, 0.5, params, validity=validity))
    fb_min, fb_mean = _time(lambda: fbstep(state, stacked)[1].s)
    out.append(("bench/fedbuff_round_cnn_fused", fb_min,
                {"units": len(um.names), "K": K,
                 "model_passes": 1, "hbm_mb": round(mb, 1),
                 "wall_vs_ref": round(fb_min / max(r_min, 1e-9), 2),
                 "note": "interpret-mode off-TPU",
                 "mean_us": round(fb_mean * 1e6, 1)}))

    # same fused round on a bf16 model: the kernel's bf16 bucket packs
    # (and writes the applied update) in bf16, so the sweep moves half
    # the f32 pack's HBM bytes; math stays f32 in-register either way
    bparams = jax.tree.map(lambda l: l.astype(jnp.bfloat16), params)
    bstate, bum = luar_init(bparams, fcfg, jax.random.PRNGKey(1))
    bstacked = jax.tree.map(lambda l: l.astype(jnp.bfloat16), stacked)
    bbstep = jax.jit(lambda s, st: fused_buffer_round(
        s, bum, fcfg, st, staleness, 0.5, bparams, validity=validity))
    bb_min, bb_mean = _time(lambda: bbstep(bstate, bstacked)[1].s)
    out.append(("bench/fedbuff_round_cnn_fused_bf16", bb_min,
                {"units": len(bum.names), "K": K, "pack_dtype": "bf16",
                 "model_passes": 1, "hbm_mb": round(mb / 2, 1),
                 "wall_vs_f32_fused": round(bb_min / max(fb_min, 1e-9), 2),
                 "note": "interpret-mode off-TPU",
                 "mean_us": round(bb_mean * 1e6, 1)}))

    if not quick:
        S = 1024
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 8, S, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 8, S, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 8, S, 64), jnp.float32)
        t_min, t_mean = _time(
            lambda: ops.flash_attention(q, k, v, interpret=True), reps=2)
        out.append(("bench/flash_attention_interp_1k", t_min,
                    {"note": "interpret-mode",
                     "mean_us": round(t_mean * 1e6, 1)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
