"""Table 1: server memory footprint — FedAvg a*d vs FedLUAR a*(d-k)+k."""
import jax

from benchmarks.common import make_task, emit
from repro.core import build_units, server_memory_bytes
from repro.configs import get_config
from repro.models.registry import build


def rows(quick: bool = True):
    out = []
    # paper-style CNN workload
    task = make_task("femnist", n_clients=8)
    um = build_units(task.params, "module")
    k = sum(sorted(um.unit_bytes)[-2:])          # delta=2 largest units
    m = server_memory_bytes(um, k, n_active=32)
    out.append(("table1/cnn_delta2", 0.0, {
        "fedavg_MB": round(m["fedavg"] / 2**20, 2),
        "fedluar_MB": round(m["fedluar"] / 2**20, 2),
        "saving": round(1 - m["fedluar"] / m["fedavg"], 3)}))
    # an assigned-architecture workload (leaf granularity)
    cfg = get_config("qwen3-14b", reduced=quick)
    params_shapes = jax.eval_shape(lambda: build(cfg).init(jax.random.PRNGKey(0)))
    um2 = build_units(params_shapes, "leaf")
    k2 = sum(sorted(um2.unit_bytes)[-len(um2.names) // 4:])
    m2 = server_memory_bytes(um2, k2, n_active=32)
    out.append((f"table1/{cfg.name}", 0.0, {
        "fedavg_GB": round(m2["fedavg"] / 2**30, 3),
        "fedluar_GB": round(m2["fedluar"] / 2**30, 3),
        "saving": round(1 - m2["fedluar"] / m2["fedavg"], 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
