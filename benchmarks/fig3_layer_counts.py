"""Figure 3: per-layer aggregation counts (communications per layer)."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 20 if quick else 150
    task = make_task("mixture" if quick else "femnist", n_clients=12)
    res, t = timed(lambda: fl(task, rounds, n_active=4, tau=3,
                              luar=LuarConfig(delta=1 if quick else 2,
                                              granularity="module")))
    counts = {n: int(c) for n, c in zip(res.unit_names, res.agg_count)}
    counts["rounds"] = rounds
    return [("fig3/agg_counts", t / rounds, counts)]


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
