"""Tables 15-16: scalability across client-population sizes with a fixed
active cohort (activation ratios 0.5 / 0.25 / 0.125)."""
from benchmarks.common import emit, fl, make_task, timed
from repro.core import LuarConfig


def rows(quick: bool = True):
    rounds = 25 if quick else 120
    out = []
    for n_clients in (16, 32, 64):
        task = make_task("mixture" if quick else "femnist", n_clients=n_clients)
        base, t = timed(lambda task=task: fl(task, rounds, n_active=8))
        luar, _ = timed(lambda task=task: fl(
            task, rounds, n_active=8,
            luar=LuarConfig(delta=2, granularity="leaf")))
        out.append((f"table15/clients{n_clients}", t / rounds, {
            "activation": round(8 / n_clients, 3),
            "acc_fedavg": round(base.history[-1]["acc"], 4),
            "acc_fedluar": round(luar.history[-1]["acc"], 4),
            "comm": round(luar.comm_ratio, 3)}))
    return out


def main(quick: bool = True):
    emit(rows(quick))


if __name__ == "__main__":
    main(quick=False)
