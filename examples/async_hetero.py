"""Heterogeneous/async federated learning with the event-driven simulator.

Byte counts only matter if they buy wall-clock time.  This example runs
FedAvg, FedLUAR and FedPAQ through ``repro.sim`` under the bimodal
"mobile vs datacenter" population — 80% of clients sit behind a thin
uplink, so the round barrier waits on mobile uploads — and reports the
SIMULATED seconds each method needs to reach the target loss.  FedLUAR's
recycle mask removes ~1/3 of the payload from every uplink, which under
this profile turns directly into faster rounds.  A FedBuff-style
buffered-async pass shows the same model trained without any barrier.

  PYTHONPATH=src python examples/async_hetero.py       (CPU, <2 min)
"""
import math

import jax
import jax.numpy as jnp

from repro.configs.base import get_scenario
from repro.core import LuarConfig
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.sim import SimConfig, describe, run_sim, sample_resources, time_to_target

# 1. non-IID federated task (as in quickstart.py)
x, y = gaussian_mixture(4000, n_classes=10, d=32, seed=0)
xt, yt = gaussian_mixture(1000, n_classes=10, d=32, seed=1)
parts = dirichlet_partition(y, n_clients=32, alpha=0.1)
params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
loss_fn = lambda p, b: softmax_xent(mlp_apply(p, b["x"]), b["y"])
xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)


def eval_fn(p):
    return {"loss": float(softmax_xent(mlp_apply(p, xt_j), yt_j)),
            "acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xt_j), -1) == yt_j))}


# 2. the bimodal population, with bandwidths scaled to this model's size
#    so the mobile uplink is the bottleneck (full upload ~2 sim-seconds)
um = build_units(params, "leaf")
model_bytes = float(sum(um.unit_bytes))
scenario = get_scenario("bimodal").replace(
    up_bw=model_bytes / 2.0, down_bw=model_bytes * 4.0, step_time=0.06)
print("bimodal population:", describe(sample_resources(scenario, 32)))

TARGET_LOSS = 0.35
ALGOS = [
    ("fedavg", dict()),
    ("fedluar", dict(luar=LuarConfig(delta=2, granularity="leaf"))),
    ("fedpaq", dict(codecs=("fedpaq:8",))),
]


def fl_cfg(**kw):
    return FLConfig(n_clients=32, n_active=8, tau=5, rounds=40,
                    client=ClientConfig(lr=0.05), eval_every=2, **kw)


# 3. synchronous-with-deadline rounds under the bimodal profile
print(f"\nsync rounds, bimodal profile (target loss {TARGET_LOSS}):")
print(f"{'algo':<10} {'t_target(sim s)':>16} {'total(sim s)':>13} "
      f"{'final acc':>10} {'comm vs fedavg':>15}")
t_fedavg = None
times = {}
for name, kw in ALGOS:
    res = run_sim(loss_fn, params, {"x": x, "y": y}, parts, fl_cfg(**kw),
                  SimConfig(scenario=scenario), eval_fn)
    t_hit = time_to_target(res, "loss", TARGET_LOSS, mode="min")
    times[name] = t_hit
    t_str = f"{t_hit:.1f}" if math.isfinite(t_hit) else "never"
    print(f"{name:<10} {t_str:>16} {res.sim_time:>13.1f} "
          f"{res.history[-1]['acc']:>10.3f} {res.comm_ratio:>15.2f}")

if math.isfinite(times["fedavg"]) and math.isfinite(times["fedluar"]):
    speedup = times["fedavg"] / times["fedluar"]
    print(f"\nFedLUAR reaches loss {TARGET_LOSS} {speedup:.2f}x faster than "
          "FedAvg in simulated wall-clock (recycled units skip the thin "
          "mobile uplink).")
else:
    print(f"\nWARNING: a method never reached loss {TARGET_LOSS}; "
          f"no speedup claim (fedavg={times['fedavg']}, "
          f"fedluar={times['fedluar']}).")

# 4. the same population without a round barrier: FedBuff buffered async.
#    Under version skew each in-flight client carries a possibly-stale
#    recycle mask; the mask ledger versions every dispatched R_t so the
#    merge averages each unit only over clients that actually uploaded
#    it — wasted uplink drops to exactly zero (vs the maskless merge)
print("\nfedbuff buffered-async (buffer=4, staleness discount 1/sqrt(1+tau)):")
FEDBUFF_ROWS = [
    ("fedavg", dict(), True),
    ("fedluar", dict(luar=LuarConfig(delta=2, granularity="leaf")), True),
    ("fedluar/pen", dict(luar=LuarConfig(delta=2, granularity="leaf",
                                         staleness_penalty=1.0)), True),
    ("fedluar/nl", dict(luar=LuarConfig(delta=2, granularity="leaf")), False),
    # a full codec stack (4-bit quantize -> global top-10% -> per-client
    # error feedback) composed with recycling, still zero wasted uplink
    ("fedluar/stk", dict(luar=LuarConfig(delta=2, granularity="leaf"),
                         codecs=("fedpaq:4", "topk:0.1", "ef")), True),
]
for name, kw, ledger in FEDBUFF_ROWS:
    res = run_sim(loss_fn, params, {"x": x, "y": y}, parts, fl_cfg(**kw),
                  SimConfig(scenario=scenario, mode="fedbuff",
                            buffer_size=4, concurrency=8,
                            mask_ledger=ledger), eval_fn)
    t_hit = time_to_target(res, "loss", TARGET_LOSS, mode="min")
    t_str = f"{t_hit:.1f}" if math.isfinite(t_hit) else "never"
    q90 = res.staleness_q["q90"] if res.staleness_q else 0.0
    print(f"{name:<13} t_target={t_str:>8} sim s   total={res.sim_time:.1f} "
          f"sim s   acc={res.history[-1]['acc']:.3f} "
          f"updates={res.n_received} wasted_kb={res.wasted_upload_bytes/1e3:.1f} "
          f"stal_q90={q90:.1f}")
print("(/pen = staleness-conditioned selection, the knob that keeps honest "
      "async LUAR converging;\n /nl = mask ledger off: the merge prices "
      "stale uploads against the CURRENT mask,\n discarding the bytes the "
      "ledger puts to work — and silently averaging units clients\n never "
      "uploaded, which only LOOKS fine because the simulator knows them)")

# 5. FedAsync (buffer=1): the discount scales the server mixing rate, and
#    adaptive alpha re-fits it to the observed staleness quantiles
print("\nfedasync (buffer=1, concurrency=4), fixed vs adaptive alpha:")
for tag, kw in (("alpha=0.5", dict(staleness_alpha=0.5)),
                ("adaptive", dict(staleness_alpha=0.5, adaptive_alpha=True))):
    res = run_sim(loss_fn, params, {"x": x, "y": y}, parts,
                  fl_cfg(luar=LuarConfig(delta=2, granularity="leaf",
                                         staleness_penalty=1.0)),
                  SimConfig(scenario=scenario, mode="fedbuff", buffer_size=1,
                            concurrency=4, **kw), eval_fn)
    t_hit = time_to_target(res, "loss", TARGET_LOSS, mode="min")
    t_str = f"{t_hit:.1f}" if math.isfinite(t_hit) else "never"
    alphas = sorted(set(round(a, 2) for a in res.alphas))
    print(f"{tag:<10} t_target={t_str:>8} sim s   acc={res.history[-1]['acc']:.3f} "
          f"stal_q={res.staleness_q}   alphas={alphas[:4]}"
          f"{'...' if len(alphas) > 4 else ''}")

# 6. the versioned downlink: so far every dispatch downloaded the FULL
#    model — half the round trip the uplink codecs never touched.  With
#    codecs=("down:delta",) the fedbuff server keeps a DeltaLedger of
#    per-version applied updates and each client downloads the delta
#    chain against the version it last saw (full snapshot only on first
#    contact, ledger eviction, or when a long lag makes the chain dearer
#    — the server prices both and ships the cheaper).  Keeping every
#    client in flight with the buffer spanning one rotation pins the
#    redispatch lag to ~1 version, where the chain wins almost always.
print("\nversioned downlink (fedbuff, buffer=concurrency=32): full broadcast "
      "vs down:delta")
print(f"{'broadcast':<12} {'up MB':>8} {'down MB':>9} {'total MB':>9} "
      f"{'down ratio':>11} {'delta dls':>10} {'acc':>6}")
for name, codecs in (("full", ()), ("down:delta", ("down:delta",))):
    res = run_sim(loss_fn, params, {"x": x, "y": y}, parts,
                  fl_cfg(luar=LuarConfig(delta=4, granularity="leaf"),
                         codecs=codecs),
                  SimConfig(scenario=scenario, mode="fedbuff",
                            buffer_size=32, concurrency=32), eval_fn)
    up_mb = res.comm_ratio * model_bytes * res.n_uplinks_spent / 1e6
    print(f"{name:<12} {up_mb:>8.2f} {res.downloaded / 1e6:>9.2f} "
          f"{up_mb + res.downloaded / 1e6:>9.2f} {res.down_ratio:>11.2f} "
          f"{res.n_delta_downloads:>4}/{res.n_dispatched:<5} "
          f"{res.history[-1]['acc']:>6.3f}")
print("(first contacts still pay a cache-seeding snapshot; every later "
      "download ships the delta\n chain — recycled units cost 4 bytes a "
      "step, so the downlink finally shares the\n uplink's recycling "
      "discount instead of re-broadcasting the whole model)")

# 7. biased participation: so far every cohort was a uniform draw from
#    the population — the idealized regime the paper measures in.  Real
#    deployments face diurnal availability (phones charge at night),
#    loss-hungry selection (power-of-choice), and battery budgets.  The
#    participation axis is declarative now (FLConfig.participation,
#    repro.participate): policies report inclusion probabilities, the
#    engines thread Horvitz-Thompson weights into the merge so the
#    aggregate stays unbiased, and per-client fairness telemetry shows
#    exactly how skewed the cohorts were.
print("\nbiased participation (fedbuff, buffer=4): uniform vs diurnal "
      "availability vs\npower-of-choice vs a 6-joule battery budget")
print(f"{'policy':<18} {'t_target':>9} {'acc':>6} {'recv':>5} "
      f"{'fairness min/med/max':>21} {'dead-ends':>9}")
PART_ROWS = [
    ("uniform", "uniform"),
    # availability phase-locked to the (virtual) time of day: half the
    # population is reachable at any instant, and WHICH half rotates
    ("avail:diurnal", "avail:diurnal:0.5"),
    # sample 12 candidates, train the ones with the highest tracked loss
    # — HT weights debias the merge.  The 30% exploration floor matters
    # under buffered async: with the default 10%, power-of-choice
    # concentrates the cohorts on a handful of hot clients and this
    # non-IID split (alpha=0.1) visibly destabilizes
    ("powd:12/e.3", "powd:12:0.3"),
    # 6 J batteries drained by busy seconds, recharged 0.3 J/s on idle:
    # depleted clients drop out of the selectable pool until they charge
    ("energy:6", "energy:6:0.3"),
]
for label, part in PART_ROWS:
    res = run_sim(loss_fn, params, {"x": x, "y": y}, parts,
                  fl_cfg(luar=LuarConfig(delta=2, granularity="leaf"),
                         participation=part),
                  SimConfig(scenario=scenario, mode="fedbuff",
                            buffer_size=4, concurrency=8), eval_fn)
    t_hit = time_to_target(res, "loss", TARGET_LOSS, mode="min")
    t_str = f"{t_hit:.1f}" if math.isfinite(t_hit) else "never"
    f = res.fairness
    print(f"{label:<18} {t_str:>9} {res.history[-1]['acc']:>6.3f} "
          f"{res.n_received:>5} "
          f"{f['min']:>7.0f}/{f['median']:.0f}/{f['max']:.0f}"
          f"{int(res.dropout_count.sum()):>10}")
print("(fairness = per-client dispatch counts: biased policies spread "
      "them unevenly, and\n the HT-weighted merge is what keeps the "
      "MODEL unbiased while they do; declare the\n old dropout scalar "
      "as participation='avail:bernoulli:p' — the scenario field is\n "
      "a deprecated shim now)")
