"""End-to-end driver: federated fine-tuning of an assigned-architecture
LM with FedLUAR (update recycling on the transformer's stacked weight
tensors) — the paper's "communication-efficient LLM fine-tuning" future-
work direction, runnable at reduced scale on CPU.

  PYTHONPATH=src python examples/fedluar_lm.py [--arch qwen3-14b] [--rounds 30]

For a ~100M-parameter run on real hardware:
  python -m repro.launch.train --workload lm --arch qwen3-14b \
      --lm-scale 6 --rounds 300 --delta 8
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--workload", "lm", "--rounds", "30", "--delta", "6",
                "--clients", "16", "--active", "4", "--tau", "2",
                "--batch-size", "8", "--lr", "0.3", "--eval-every", "10"]
    main(defaults + args)
