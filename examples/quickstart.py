"""Quickstart: FedLUAR in ~30 lines.

Runs FedAvg vs FedLUAR on a synthetic non-IID task and prints the
accuracy/communication trade-off (the paper's headline claim).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent

# 1. a non-IID federated dataset (Dirichlet alpha=0.1, as in the paper)
x, y = gaussian_mixture(4000, n_classes=10, d=32, seed=0)
xt, yt = gaussian_mixture(1000, n_classes=10, d=32, seed=1)
parts = dirichlet_partition(y, n_clients=32, alpha=0.1)

# 2. a model + loss
params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
loss_fn = lambda p, b: softmax_xent(mlp_apply(p, b["x"]), b["y"])
eval_fn = lambda p: {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, jnp.asarray(xt)), -1) == jnp.asarray(yt)))}

# 3. FedAvg baseline vs FedLUAR (recycle 2 of 6 layer-units per round)
for name, luar in [("FedAvg ", LuarConfig(delta=0)),
                   ("FedLUAR", LuarConfig(delta=2, granularity="leaf"))]:
    cfg = FLConfig(n_clients=32, n_active=8, tau=5, rounds=40,
                   client=ClientConfig(lr=0.05), luar=luar, eval_every=40)
    res = run_fl(loss_fn, params, {"x": x, "y": y}, parts, cfg, eval_fn)
    print(f"{name}: accuracy={res.history[-1]['acc']:.3f} "
          f"communication={res.comm_ratio:.2f}x of FedAvg")
