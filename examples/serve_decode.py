"""Serving example: batched prefill + greedy decode with a KV cache for
any assigned architecture (reduced configs on CPU).

  PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-780m]
"""
import sys

from repro.launch.generate import main

if __name__ == "__main__":
    main(sys.argv[1:])
