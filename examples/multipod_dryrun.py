"""Multi-pod dry-run example: lower + compile one architecture for the
single-pod (16x16) and multi-pod (2x16x16) production meshes and print
the roofline terms.  Must run as a fresh process per mesh (jax locks the
device count at first init), so this shells out to repro.launch.dryrun.

  PYTHONPATH=src python examples/multipod_dryrun.py [arch] [shape]
"""
import json
import subprocess
import sys

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-4b"
shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"

for flags, mesh in ([], "single-pod (16,16)=256 chips"), (["--multi-pod"], "multi-pod (2,16,16)=512 chips"):
    print(f"== {mesh}")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", "/tmp/dryrun_example"] + flags,
        capture_output=True, text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    if out.returncode:
        print(out.stderr[-1000:])
        sys.exit(1)
    rec = json.loads(out.stdout)
    print(json.dumps(rec.get("roofline", rec), indent=1))
