"""Property tests for the batched multi-unit fused aggregation path.

The claim under test: ``fused_buffer_round`` / ``luar_round(fused_agg)``
— one Pallas sweep — match the per-leaf reference composition
(``staleness_weighted_merge`` + ``luar_round``) within f32 accumulation
tolerance across random unit maps, validity masks, HT weights and
staleness vectors, including the all-recycled and all-fresh extremes.

The fuzz runs on the seeded conftest hypothesis stub in tier-1 (bounded
examples) and is soaked nightly by the CI ``full`` job via
STUB_HYPOTHESIS_MAX_EXAMPLES (the slow-marked deep case).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LuarConfig, fused_buffer_round, luar_init,
                        luar_round, staleness_weighted_merge)
from repro.core.units import build_units

# a few FIXED model layouts (so jit/pallas trace caches hit across
# examples; the randomness lives in weights, masks and staleness)
_LAYOUTS = {
    "mlp_module": (
        {"l1": {"w": (32, 16), "b": (16,)}, "l2": {"w": (16, 4), "b": (4,)}},
        "module"),
    "odd_leaf": (
        {"a": {"w": (7,), "b": ()}, "c": {"w": (13, 3)}},
        "leaf"),
    "stacked_depth": (
        {"blocks": {"w": (3, 6, 4), "b": (3, 4)}, "head": {"w": (4, 2)}},
        "depth"),
}


def _params_for(layout_key, rng):
    tmpl, granularity = _LAYOUTS[layout_key]
    params = jax.tree.map(
        lambda s: jnp.asarray(rng.normal(size=s), jnp.float32), tmpl,
        is_leaf=lambda x: isinstance(x, tuple))
    return params, granularity


def _reference_round(state, um, cfg, stacked, staleness, alpha, params,
                     validity, ht, fedasync):
    fresh = staleness_weighted_merge(stacked, staleness, alpha,
                                     validity=validity, um=um,
                                     fallback=state.prev_update, ht=ht)
    if fedasync:
        eta = (1.0 + staleness[0].astype(jnp.float32)) ** (-alpha)
        fresh = jax.tree.map(lambda l: l * eta, fresh)
    eff_mask = ~jnp.any(validity, axis=0)
    return luar_round(state, um, cfg, fresh, params, mask_override=eff_mask)


def _check_fused_matches(layout_key, K, seed, alpha, use_ht, mode,
                         validity_kind):
    rng = np.random.default_rng(seed)
    params, granularity = _params_for(layout_key, rng)
    cfg = LuarConfig(delta=1, granularity=granularity, mode=mode)
    fcfg = cfg._replace(fused_agg=True)
    state, um = luar_init(params, cfg, jax.random.PRNGKey(seed))
    # a non-zero prev_update so the recycled direction is visible
    prev = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=l.shape), l.dtype), params)
    state = state._replace(prev_update=prev)
    n = len(um.names)
    stacked = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=(K,) + l.shape), l.dtype),
        params)
    staleness = jnp.asarray(rng.integers(0, 9, K), jnp.int32)
    if validity_kind == "all_fresh":
        validity = jnp.ones((K, n), bool)
    elif validity_kind == "all_recycled":
        validity = jnp.zeros((K, n), bool)
    else:
        validity = jnp.asarray(rng.random((K, n)) > 0.4)
    ht = (jnp.asarray(rng.uniform(0.5, 3.0, K), jnp.float32)
          if use_ht else None)
    fedasync = K == 1

    ar, sr = _reference_round(state, um, cfg, stacked, staleness, alpha,
                              params, validity, ht, fedasync)
    af, sf = fused_buffer_round(state, um, fcfg, stacked, staleness, alpha,
                                params, validity=validity, ht=ht,
                                fedasync=fedasync)
    for x, y in zip(jax.tree.leaves(ar), jax.tree.leaves(af)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sr.s), np.asarray(sf.s),
                               rtol=1e-3, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sr.staleness),
                                  np.asarray(sf.staleness))
    np.testing.assert_array_equal(np.asarray(sr.mask), np.asarray(sf.mask))


@pytest.mark.parametrize("validity_kind", ["all_fresh", "all_recycled"])
@pytest.mark.parametrize("layout_key", sorted(_LAYOUTS))
def test_fused_extremes(layout_key, validity_kind):
    """All-fresh (everybody uploaded everything) and all-recycled
    (nobody uploaded anything) pin both ends of the coefficient math."""
    _check_fused_matches(layout_key, K=3, seed=0, alpha=0.5, use_ht=False,
                         mode="recycle", validity_kind=validity_kind)


def test_fused_fedasync_eta_scaling():
    """K=1 routes the staleness weight through the server mixing rate."""
    _check_fused_matches("mlp_module", K=1, seed=4, alpha=0.7, use_ht=False,
                         mode="recycle", validity_kind="random")


def test_fused_drop_mode():
    _check_fused_matches("odd_leaf", K=2, seed=5, alpha=0.5, use_ht=True,
                         mode="drop", validity_kind="random")


@pytest.mark.slow
@given(st.sampled_from(sorted(_LAYOUTS)), st.integers(1, 4),
       st.integers(0, 10_000), st.floats(0.0, 1.5), st.booleans(),
       st.sampled_from(["recycle", "drop"]))
@settings(deadline=None, max_examples=10)
def test_fused_matches_reference_fuzz(layout_key, K, seed, alpha, use_ht,
                                      mode):
    """Random unit maps x masks x HT weights x staleness vectors."""
    _check_fused_matches(layout_key, K, seed, alpha, use_ht, mode,
                         validity_kind="random")


@pytest.mark.slow
def test_fedbuff_engine_fused_run_matches_reference():
    """End to end through the event-driven fedbuff engine: the fused
    agg_fn reproduces the reference trajectory within tolerance (same
    seeds, same event order — only the server math is rerouted)."""
    from repro.data.synthetic import gaussian_mixture
    from repro.fl.client import ClientConfig
    from repro.fl.partition import dirichlet_partition
    from repro.fl.rounds import FLConfig
    from repro.models.cnn import mlp_apply, mlp_init, softmax_xent
    from repro.sim import SimConfig, run_sim

    x, y = gaussian_mixture(600, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 12, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    finals = {}
    for fused in (False, True):
        cfg = FLConfig(n_clients=12, n_active=6, tau=2, batch_size=8,
                       rounds=4, eval_every=4,
                       client=ClientConfig(lr=0.05),
                       luar=LuarConfig(delta=2, fused_agg=fused))
        sim = SimConfig(scenario="bimodal", mode="fedbuff", buffer_size=3,
                        concurrency=6)
        res = run_sim(loss_fn, params, {"x": x, "y": y}, parts, cfg, sim)
        finals[fused] = np.concatenate(
            [np.asarray(l, np.float32).ravel()
             for l in jax.tree.leaves(res.params)])
        assert res.rounds_done == cfg.rounds
    np.testing.assert_allclose(finals[True], finals[False],
                               atol=1e-4, rtol=1e-3)


def test_fused_flag_default_off():
    """The reference path stays the default: fingerprint-pinned
    trajectories must not route through the kernel silently."""
    assert LuarConfig().fused_agg is False


def test_luar_round_unknown_mode_raises_with_fused():
    params = {"a": jnp.ones((4,))}
    cfg = LuarConfig(delta=0, mode="bogus", fused_agg=True)
    state, um = luar_init(params, LuarConfig(), jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        luar_round(state, um, cfg, params, params)
