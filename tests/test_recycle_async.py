"""Versioned staleness-aware LUAR for buffered async: the mask ledger,
the per-unit validity merge, staleness-conditioned selection, adaptive
alpha, and the property/regression tier over the recycle–sim stack.

The load-bearing claims:
  * with the mask ledger enabled a fedbuff run NEVER discards an
    uploaded byte (``SimResult.wasted_per_unit`` is exactly zero), while
    the PR-1 semantics (``mask_ledger=False``) waste every byte a stale
    client uploads for a unit the current mask recycles;
  * in the no-staleness regime the whole machinery is bitwise inert.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LuarConfig, luar_init, luar_round, recycle_probs,
                        select_recycle_set, staleness_weighted_merge)
from repro.core.selection import gumbel_topk_mask
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import (FLConfig, client_payload_bytes,
                             client_payload_bytes_per_unit, run_fl)
from repro.models.cnn import cnn_init, mlp_init, mlp_apply, softmax_xent
from repro.sim import ARRIVAL, EventQueue, MaskLedger, SimConfig, run_sim


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    kw.setdefault("n_active", 6)
    return FLConfig(n_clients=16, tau=3, batch_size=8, **kw)


def _run(task, cfg, sim):
    return run_sim(task["loss_fn"], task["params"], task["data"],
                   task["parts"], cfg, sim, task["eval_fn"])


def _trees_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# MaskLedger (ring buffer of dispatched masks keyed by version)
# ---------------------------------------------------------------------------


def test_mask_ledger_records_and_gets():
    led = MaskLedger(capacity=4)
    m0 = np.array([True, False, False])
    led.record(0, m0)
    assert 0 in led and len(led) == 1
    np.testing.assert_array_equal(led.get(0), m0)
    assert led.get(99) is None


def test_mask_ledger_record_is_idempotent():
    led = MaskLedger(capacity=4)
    m = np.array([True, False])
    led.record(0, m)
    led.record(0, np.array([False, True]))      # same version: ignored
    np.testing.assert_array_equal(led.get(0), m)
    assert len(led) == 1


def test_mask_ledger_evicts_oldest():
    led = MaskLedger(capacity=2)
    for v in range(4):
        led.record(v, np.array([v % 2 == 0]))
    assert led.evictions == 2
    assert led.get(0) is None and led.get(1) is None
    assert led.get(2) is not None and led.get(3) is not None


def test_mask_ledger_copies_and_validates():
    with pytest.raises(ValueError):
        MaskLedger(capacity=0)
    led = MaskLedger()
    m = np.array([True, False])
    led.record(0, m)
    m[0] = False                                # caller mutates its copy
    assert bool(led.get(0)[0])                  # ledger unaffected


def test_event_queue_pending_count():
    q = EventQueue()
    q.push(1.0, ARRIVAL, 0)
    q.push(2.0, ARRIVAL, 1)
    q.push(3.0, "deadline")
    assert q.pending_count() == 3
    assert q.pending_count(ARRIVAL) == 2
    q.pop()
    assert q.pending_count(ARRIVAL) == 1


# ---------------------------------------------------------------------------
# staleness_weighted_merge properties (satellite: hypothesis tier)
# ---------------------------------------------------------------------------

_TEMPLATE = {"a": jnp.zeros((3,), jnp.float32),
             "b": jnp.zeros((2, 2), jnp.float32)}
_UM = build_units(_TEMPLATE, "leaf")            # 2 units
_NU = len(_UM.names)


def _stacked(rng, k):
    return {"a": jnp.asarray(rng.standard_normal((k, 3)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((k, 2, 2)), jnp.float32)}


@pytest.mark.slow
@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=0.0, max_value=2.0),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=25)
def test_merge_weights_sum_to_one(k, alpha, seed):
    """Merging K copies of the SAME tree returns that tree: the discount
    weights are a convex combination, with or without a validity mask."""
    rng = np.random.default_rng(seed)
    one = _stacked(rng, 1)
    stacked = jax.tree.map(lambda l: jnp.repeat(l, k, axis=0), one)
    stal = jnp.asarray(rng.integers(0, 10, k), jnp.int32)
    plain = staleness_weighted_merge(stacked, stal, alpha)
    # validity with every unit covered by at least one client
    v = rng.random((k, _NU)) < 0.5
    v[rng.integers(0, k)] = True
    masked = staleness_weighted_merge(stacked, stal, alpha,
                                      validity=jnp.asarray(v), um=_UM)
    for got in (plain, masked):
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(one)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w)[0],
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=25)
def test_merge_alpha_zero_is_plain_mean(k, seed):
    rng = np.random.default_rng(seed)
    stacked = _stacked(rng, k)
    stal = jnp.asarray(rng.integers(0, 20, k), jnp.int32)
    got = staleness_weighted_merge(stacked, stal, alpha=0.0)
    gotv = staleness_weighted_merge(stacked, stal, alpha=0.0,
                                    validity=jnp.ones((k, _NU), bool), um=_UM)
    for g, gv, l in zip(jax.tree.leaves(got), jax.tree.leaves(gotv),
                        jax.tree.leaves(stacked)):
        want = np.asarray(l).mean(axis=0)
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(gv), want, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=0.0, max_value=2.0),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=25)
def test_merge_never_divides_by_zero(k, alpha, seed):
    """A unit NO valid client uploaded must come out finite: equal to the
    fallback (recycled prev_update) when given, zeros otherwise."""
    rng = np.random.default_rng(seed)
    stacked = _stacked(rng, k)
    stal = jnp.asarray(rng.integers(0, 10, k), jnp.int32)
    v = np.ones((k, _NU), bool)
    dead = int(rng.integers(0, _NU))
    v[:, dead] = False                          # nobody uploaded this unit
    fb = {"a": jnp.full((3,), 7.0, jnp.float32),
          "b": jnp.full((2, 2), 7.0, jnp.float32)}
    got = staleness_weighted_merge(stacked, stal, alpha,
                                   validity=jnp.asarray(v), um=_UM,
                                   fallback=fb)
    got0 = staleness_weighted_merge(stacked, stal, alpha,
                                    validity=jnp.asarray(v), um=_UM)
    for i, (g, g0, f, _leaf) in enumerate(zip(
            jax.tree.leaves(got), jax.tree.leaves(got0),
            jax.tree.leaves(fb), jax.tree.leaves(stacked))):
        assert np.all(np.isfinite(np.asarray(g)))
        u = _UM.leaf_unit[i]
        if u == dead:
            np.testing.assert_array_equal(np.asarray(g), np.asarray(f))
            np.testing.assert_array_equal(np.asarray(g0),
                                          np.zeros_like(np.asarray(g0)))


@pytest.mark.slow
@given(st.integers(min_value=2, max_value=6),
       st.floats(min_value=0.0, max_value=2.0),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=25)
def test_merge_invariant_to_buffer_permutation(k, alpha, seed):
    """FedBuff semantics: the server must not care in which order the
    buffer filled (permute deltas + staleness + validity together)."""
    rng = np.random.default_rng(seed)
    stacked = _stacked(rng, k)
    stal = jnp.asarray(rng.integers(0, 10, k), jnp.int32)
    v = rng.random((k, _NU)) < 0.7
    v[0] = True
    perm = rng.permutation(k)
    a = staleness_weighted_merge(stacked, stal, alpha,
                                 validity=jnp.asarray(v), um=_UM)
    b = staleness_weighted_merge(
        jax.tree.map(lambda l: l[perm], stacked), stal[perm], alpha,
        validity=jnp.asarray(v[perm]), um=_UM)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_merge_depth_granularity_units():
    """The validity merge follows (start, L) stacked depth units too."""
    template = {"blocks": {"w": jnp.zeros((3, 4), jnp.float32)}}
    um = build_units(template, "depth")         # 3 units, one per slice
    assert len(um.names) == 3
    rng = np.random.default_rng(0)
    stacked = {"blocks": {"w": jnp.asarray(rng.standard_normal((2, 3, 4)),
                                           jnp.float32)}}
    v = jnp.asarray([[True, False, False], [True, True, False]])
    fb = {"blocks": {"w": jnp.full((3, 4), -1.0, jnp.float32)}}
    got = np.asarray(staleness_weighted_merge(
        stacked, jnp.zeros(2, jnp.int32), 0.5, validity=v, um=um,
        fallback=fb)["blocks"]["w"])
    raw = np.asarray(stacked["blocks"]["w"])
    np.testing.assert_allclose(got[0], raw[:, 0].mean(0), rtol=1e-5)  # both
    # only k=1 uploaded slice 1: k=0's weight mass goes to the fallback
    np.testing.assert_allclose(got[1], 0.5 * raw[1, 1] + 0.5 * (-1.0),
                               rtol=1e-5)
    np.testing.assert_array_equal(got[2], -np.ones((4,)))             # fallback
    # without a fallback the valid subset renormalizes to full weight
    got0 = np.asarray(staleness_weighted_merge(
        stacked, jnp.zeros(2, jnp.int32), 0.5, validity=v,
        um=um)["blocks"]["w"])
    np.testing.assert_allclose(got0[1], raw[1, 1], rtol=1e-5)
    np.testing.assert_array_equal(got0[2], np.zeros((4,)))


# ---------------------------------------------------------------------------
# gumbel_topk_mask matches Plackett-Luce marginals (satellite: statistical)
# ---------------------------------------------------------------------------


def _pl_top2_inclusion(p: np.ndarray) -> np.ndarray:
    """Exact P(i in top-2) under sequential (Plackett-Luce) sampling w/o
    replacement: P(i first) + sum_j P(j first) P(i second | j first)."""
    n = len(p)
    inc = np.zeros(n)
    for i in range(n):
        inc[i] = p[i] + sum(p[j] * p[i] / (1.0 - p[j])
                            for j in range(n) if j != i)
    return inc


@pytest.mark.slow
def test_gumbel_topk_matches_plackett_luce_marginals():
    p = np.asarray([0.5, 0.25, 0.15, 0.10])
    want = _pl_top2_inclusion(p)
    keys = jax.random.split(jax.random.PRNGKey(42), 2000)
    masks = jax.vmap(lambda k: gumbel_topk_mask(k, jnp.log(jnp.asarray(p)), 2))(keys)
    masks = np.asarray(masks)
    assert np.all(masks.sum(axis=1) == 2)       # always exactly delta units
    freq = masks.mean(axis=0)
    # binomial sd at 2000 draws is <= 0.011; 0.045 is a > 4-sigma band
    np.testing.assert_allclose(freq, want, atol=0.045)


def test_select_recycle_set_clamps_delta_to_n():
    s = jnp.asarray([0.1, 0.5, 0.2, 0.9])
    g = jnp.ones((4,))
    for delta in (4, 7, 100):
        mask = select_recycle_set(jax.random.PRNGKey(0), "luar", delta,
                                  s=s, grad_sq=g)
        assert bool(jnp.all(mask))              # delta >= n selects everything


# ---------------------------------------------------------------------------
# staleness-conditioned selection
# ---------------------------------------------------------------------------


def test_recycle_probs_staleness_penalty_damps_stale_units():
    s = jnp.asarray([1.0, 1.0, 1.0])
    stal = jnp.asarray([0, 3, 0], jnp.int32)
    base = np.asarray(recycle_probs(s))
    pen = np.asarray(recycle_probs(s, stal, 0.5))
    np.testing.assert_allclose(base, np.full(3, 1 / 3), rtol=1e-6)
    assert pen[1] < base[1]                     # stale unit damped ...
    assert pen[0] > base[0] and pen[2] > base[2]  # ... others boosted
    assert np.isclose(pen.sum(), 1.0, atol=1e-6)


def test_recycle_probs_penalty_zero_is_bitwise_noop():
    s = jnp.asarray([0.3, 1.7, 0.9])
    stal = jnp.asarray([5, 0, 2], jnp.int32)
    np.testing.assert_array_equal(np.asarray(recycle_probs(s)),
                                  np.asarray(recycle_probs(s, stal, 0.0)))


def test_staleness_penalty_rotates_deterministic_selection():
    """End-to-end through luar_round: the deterministic scheme recycles
    the same units forever (unbounded staleness) unless the penalty
    forces long-recycled units back into aggregation."""
    params = cnn_init(jax.random.PRNGKey(0))
    fresh = jax.tree.map(lambda a: 0.01 * jnp.ones_like(a), params)

    def run(penalty):
        cfg = LuarConfig(delta=3, granularity="module", scheme="deterministic",
                         staleness_penalty=penalty)
        state, um = luar_init(params, cfg, jax.random.PRNGKey(5))
        worst = 0
        for _ in range(12):
            _, state = luar_round(state, um, cfg, fresh, params)
            worst = max(worst, int(jnp.max(state.staleness)))
        return worst, np.asarray(state.agg_count)

    worst_off, _ = run(0.0)
    worst_on, agg_on = run(2.0)
    assert worst_off > 4                        # stuck without the penalty
    assert worst_on < worst_off                 # penalty forces re-entry
    assert np.all(agg_on > 0)                   # every unit aggregated


@pytest.mark.parametrize("scheme", ["luar", "random", "grad_norm"])
def test_staleness_penalty_keeps_exact_delta(scheme):
    s = jnp.asarray([0.1, 0.5, 0.01, 2.0, 0.3])
    g = jnp.asarray([1.0, 2.0, 0.5, 3.0, 0.1])
    stal = jnp.asarray([4, 0, 9, 1, 0], jnp.int32)
    mask = select_recycle_set(jax.random.PRNGKey(1), scheme, 2, s=s, grad_sq=g,
                              staleness=stal, staleness_penalty=1.0)
    assert int(jnp.sum(mask)) == 2


# ---------------------------------------------------------------------------
# luar_round mask override (per-unit fallback-to-recycle)
# ---------------------------------------------------------------------------


def test_luar_round_mask_override_recycles_per_unit():
    params = cnn_init(jax.random.PRNGKey(0))
    cfg = LuarConfig(delta=0, granularity="module")
    state, um = luar_init(params, cfg, jax.random.PRNGKey(1))
    fresh1 = jax.tree.map(lambda a: 0.2 * jnp.ones_like(a), params)
    applied1, state = luar_round(state, um, cfg, fresh1, params)
    fresh2 = jax.tree.map(lambda a: 0.7 * jnp.ones_like(a), params)
    override = jnp.asarray([True, False, True, False])
    applied2, state2 = luar_round(state, um, cfg, fresh2, params,
                                  mask_override=override)
    ov = np.asarray(override)
    for u, a1, a2, f2 in zip(um.leaf_unit, jax.tree.leaves(applied1),
                             jax.tree.leaves(applied2), jax.tree.leaves(fresh2)):
        want = a1 if ov[u] else f2              # overridden -> prev_update
        np.testing.assert_array_equal(np.asarray(a2), np.asarray(want))
    # bookkeeping follows the effective mask, not state.mask (empty here)
    np.testing.assert_array_equal(np.asarray(state2.staleness > 0), ov)
    np.testing.assert_array_equal(np.asarray(state2.agg_count),
                                  1 + (~ov).astype(np.int32))


# ---------------------------------------------------------------------------
# per-unit payload accounting (dispatched mask, not current)
# ---------------------------------------------------------------------------


def test_client_payload_bytes_per_unit_sums_to_total():
    sizes = np.asarray([100.0, 200.0, 400.0])
    mask = np.asarray([False, True, False])
    cfg = _cfg(fedpaq_bits=8)
    per_unit = client_payload_bytes_per_unit(sizes, mask, cfg)
    assert per_unit.shape == (3,)
    assert per_unit[1] == 0.0                   # recycled: never serialized
    assert per_unit.sum() == client_payload_bytes(sizes, mask, cfg)
    assert per_unit[0] == 100.0 * (8 / 32.0)


def test_client_payload_bytes_per_unit_lbgm_scalar():
    sizes = np.asarray([100.0, 200.0, 400.0])
    mask = np.asarray([False, False, True])
    sent = np.asarray([True, False, True])
    cfg = _cfg(codecs=("lbgm:0.5",))
    # aux is the per-stage evidence tuple an encode pass returns: the
    # single lbgm stage's sent-full mask
    per_unit = client_payload_bytes_per_unit(sizes, mask, cfg, (sent,))
    np.testing.assert_array_equal(per_unit, [100.0, 4.0, 0.0])
    assert client_payload_bytes(sizes, mask, cfg, (sent,)) == 104.0


# ---------------------------------------------------------------------------
# regression: PR-1 equivalence survives the ledger (no-staleness regime)
# ---------------------------------------------------------------------------


def test_fedbuff_one_round_matches_run_fl_bitwise(task):
    """buffer=1, concurrency=1, uniform: the lone in-flight client always
    sees the current version, so one fedbuff aggregation must replay one
    run_fl round bit-for-bit (same RNG stream, same jitted client step,
    identity merge, identical LUAR transition) with the ledger enabled."""
    cfg = _cfg(luar=LuarConfig(delta=2), n_active=1, rounds=1)
    ref = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                 cfg, task["eval_fn"])
    got = _run(task, cfg, SimConfig(scenario="uniform", mode="fedbuff",
                                    buffer_size=1, concurrency=1))
    assert _trees_equal(ref.params, got.params)
    np.testing.assert_array_equal(np.asarray(ref.luar_state.mask),
                                  np.asarray(got.luar_state.mask))
    assert got.staleness_observed.max(initial=0) == 0
    assert got.wasted_per_unit.sum() == 0.0


@pytest.mark.slow
def test_fedbuff_ledger_bitwise_inert_without_staleness(task):
    """With buffer=1 and concurrency=1 no staleness can occur, so the
    ledger machinery (validity merge + mask override + waste accounting)
    must be bitwise invisible next to the PR-1 semantics."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=6)
    base = dict(scenario="uniform", mode="fedbuff", buffer_size=1,
                concurrency=1)
    on = _run(task, cfg, SimConfig(mask_ledger=True, **base))
    off = _run(task, cfg, SimConfig(mask_ledger=False, **base))
    assert _trees_equal(on.params, off.params)
    assert [h["acc"] for h in on.history] == [h["acc"] for h in off.history]
    for r in (on, off):
        assert r.staleness_observed.max(initial=0) == 0
        assert r.wasted_per_unit.sum() == 0.0
        assert r.wasted_upload_bytes == 0.0


# ---------------------------------------------------------------------------
# the acceptance claim: the ledger eliminates wasted uplink end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fedbuff_ledger_zero_waste_under_heterogeneity(task):
    """Heterogeneous fedbuff with real mask staleness: the ledger merge
    uses every uploaded byte (per-unit waste exactly 0), whereas the
    PR-1 merge demonstrably discards stale uploads."""
    cfg = _cfg(luar=LuarConfig(delta=2))
    base = dict(scenario="bimodal", mode="fedbuff", buffer_size=4,
                concurrency=8)
    on = _run(task, cfg, SimConfig(mask_ledger=True, **base))
    assert on.rounds_done == cfg.rounds
    assert on.staleness_observed.max() > 0      # staleness actually occurred
    assert on.ledger_misses == 0
    np.testing.assert_array_equal(on.wasted_per_unit,
                                  np.zeros_like(on.wasted_per_unit))
    assert on.wasted_upload_bytes == 0.0
    assert on.staleness_q is not None and on.staleness_q["max"] > 0

    off = _run(task, cfg, SimConfig(mask_ledger=False, **base))
    assert off.wasted_per_unit.sum() > 0        # PR-1 semantics waste bytes
    assert off.wasted_upload_bytes == pytest.approx(off.wasted_per_unit.sum())
    # the per-unit attribution only ever charges non-recycled uploads
    assert np.all(off.wasted_per_unit >= 0)
    assert on.history[-1]["acc"] > 0.5


@pytest.mark.slow
def test_fedbuff_ledger_eviction_counts_misses(task):
    """capacity=1 forces every stale arrival's dispatch mask out of the
    ring: those arrivals become ledger misses, are rejected outright
    (excluded from the merge and from n_received), their full payload is
    charged as waste per unit, and the run still completes."""
    cfg = _cfg(luar=LuarConfig(delta=2))
    res = _run(task, cfg, SimConfig(scenario="bimodal", mode="fedbuff",
                                    buffer_size=2, concurrency=8,
                                    ledger_capacity=1))
    assert res.rounds_done == cfg.rounds
    assert res.ledger_misses > 0
    assert res.wasted_upload_bytes > 0          # evicted payloads charged
    assert res.wasted_per_unit.sum() == pytest.approx(res.wasted_upload_bytes)
    # rejected arrivals are not accepted updates, but every accepted one
    # still fed an aggregation of buffer_size updates
    assert res.n_received >= cfg.rounds * 2
    assert len(res.staleness_observed) == res.n_received


@pytest.mark.slow
def test_fedbuff_cutoff_charges_stranded_buffer(task):
    """A truncated run (finite max_sim_time) can leave accepted uploads
    in a partially filled buffer: they never reach a merge, so their
    remaining payload must land on the waste ledger — the 'no uploaded
    byte is silently dropped' invariant under truncation."""
    cfg = _cfg(luar=LuarConfig(delta=2))
    base = dict(scenario="lognormal", mode="fedbuff", buffer_size=4,
                concurrency=8)
    full = _run(task, cfg, SimConfig(**base))
    assert full.n_stranded_end == 0             # completed run: buffer empty
    cut = _run(task, cfg, SimConfig(max_sim_time=0.6 * full.sim_time, **base))
    assert cut.rounds_done < cfg.rounds
    assert cut.sim_time <= 0.6 * full.sim_time + 1e-9   # exact cutoff
    assert cut.n_stranded_end > 0
    assert cut.wasted_upload_bytes > 0          # stranded payloads charged
    assert cut.wasted_per_unit.sum() == pytest.approx(cut.wasted_upload_bytes)
    assert cut.n_inflight_end > 0               # dispatches still in flight


@pytest.mark.slow
def test_fedbuff_staleness_penalty_end_to_end(task):
    """The staleness-conditioned selection knob flows from LuarConfig
    through the async engine: run completes and every unit keeps
    aggregating (no unit starves under async lag)."""
    cfg = _cfg(luar=LuarConfig(delta=2, staleness_penalty=0.5), rounds=10)
    res = _run(task, cfg, SimConfig(scenario="bimodal", mode="fedbuff",
                                    buffer_size=4, concurrency=8))
    assert res.rounds_done == cfg.rounds
    assert np.all(np.asarray(res.luar_state.agg_count) > 0)
    assert res.history[-1]["acc"] > 0.5


# ---------------------------------------------------------------------------
# adaptive alpha (FedAsync, buffer_size=1)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_adaptive_alpha_tracks_staleness_quantiles(task):
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=12)
    res = _run(task, cfg, SimConfig(scenario="bimodal", mode="fedbuff",
                                    buffer_size=1, concurrency=8,
                                    staleness_alpha=0.5, adaptive_alpha=True))
    assert res.rounds_done == cfg.rounds
    assert res.staleness_q["q90"] > 0
    assert len(set(res.alphas)) > 1             # the schedule actually moves
    for a in res.alphas:                        # and stays in its clip band
        assert 0.5 / 4 <= a <= 0.5 * 4


def test_adaptive_alpha_without_staleness_is_base(task):
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=4)
    res = _run(task, cfg, SimConfig(scenario="uniform", mode="fedbuff",
                                    buffer_size=1, concurrency=1,
                                    staleness_alpha=0.7, adaptive_alpha=True))
    assert set(res.alphas) == {0.7}             # q90=0 -> alpha untouched


@pytest.mark.slow
def test_fedasync_alpha_scales_mixing_under_staleness(task):
    """buffer_size=1 used to renormalize any discount away; with the
    FedAsync mixing fix, alpha changes the trajectory exactly when
    staleness occurs and is inert when it cannot."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=12)
    stale = dict(scenario="bimodal", mode="fedbuff", buffer_size=1,
                 concurrency=8)
    a = _run(task, cfg, SimConfig(staleness_alpha=0.1, **stale))
    b = _run(task, cfg, SimConfig(staleness_alpha=4.0, **stale))
    assert a.staleness_observed.max() > 0
    assert not _trees_equal(a.params, b.params)

    calm_cfg = _cfg(luar=LuarConfig(delta=2), rounds=6)
    calm = dict(scenario="uniform", mode="fedbuff", buffer_size=1,
                concurrency=1)
    c = _run(task, calm_cfg, SimConfig(staleness_alpha=0.1, **calm))
    d = _run(task, calm_cfg, SimConfig(staleness_alpha=4.0, **calm))
    assert _trees_equal(c.params, d.params)     # (1+0)^-alpha == 1 exactly


# ---------------------------------------------------------------------------
# LBGM: fenced under async, covered under sync (satellite)
# ---------------------------------------------------------------------------


def test_fedbuff_lbgm_raises_with_actionable_message(task):
    cfg = _cfg(lbgm_threshold=0.5)
    with pytest.raises(NotImplementedError) as exc:
        _run(task, cfg, SimConfig(scenario="uniform", mode="fedbuff"))
    msg = str(exc.value)
    assert "lbgm_threshold=0" in msg            # knob 1: disable LBGM
    assert "mode='sync'" in msg                 # knob 2: use the sync engine


@pytest.mark.slow
def test_sync_lbgm_sim_baseline_covered(task):
    """The synchronous engine keeps full LBGM support: the run completes,
    the dispatch ledger balances, and the comm accounting reflects the
    4-byte scalar uploads of suppressed units."""
    cfg = _cfg(lbgm_threshold=0.1, rounds=6)
    res = _run(task, cfg, SimConfig(scenario="uniform"))
    assert res.rounds_done == cfg.rounds
    assert res.n_received == cfg.n_active * cfg.rounds
    assert 0.0 < res.comm_ratio < 1.0           # some units went scalar
    assert res.history[-1]["acc"] > 0.3
