"""End-to-end driver smoke tests: the CLI trainer and the decode loop."""
import jax.numpy as jnp
import pytest

from repro.launch.generate import serve
from repro.launch.train import main as train_main


def test_train_driver_mlp(capsys):
    train_main(["--workload", "mlp", "--rounds", "6", "--clients", "8",
                "--active", "4", "--tau", "2", "--delta", "2",
                "--eval-every", "3"])
    out = capsys.readouterr().out
    assert '"acc"' in out and '"comm_ratio"' in out


def test_train_driver_lm_with_ckpt(tmp_path, capsys):
    ck = str(tmp_path / "m")
    train_main(["--workload", "lm", "--arch", "gemma3-4b", "--rounds", "4",
                "--clients", "6", "--active", "2", "--tau", "2",
                "--batch-size", "4", "--seq-len", "16", "--delta", "4",
                "--eval-every", "2", "--ckpt", ck])
    out = capsys.readouterr().out
    assert '"val_loss"' in out and "checkpoint" in out
    import os
    assert os.path.exists(ck + ".npz")


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-780m", "zamba2-1.2b",
                                  "whisper-small"])
def test_serve_loop(arch):
    out, stats = serve(arch, batch=2, prompt_len=8, steps=4)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0))
    assert stats["decode_s_per_tok"] > 0
