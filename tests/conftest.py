"""Shared test config.

1. Puts ``src/`` on sys.path so ``pytest`` works without PYTHONPATH=src
   (the tier-1 command still sets it; this is a fallback).
2. Installs a minimal ``hypothesis`` stand-in when the real package is
   absent so the property-test modules still collect AND run: the
   stub's ``@given`` re-runs the test body over a seeded pseudo-random
   sample of the strategy space (a bounded fuzz, not full shrinking).
   With real hypothesis installed the stub never activates.  Setting
   STUB_HYPOTHESIS_MAX_EXAMPLES explicitly overrides every per-test
   ``@settings(max_examples=...)`` cap — the CI ``full`` job uses this
   (installing WITHOUT hypothesis) to soak the slow-marked property
   tests at a much deeper budget than the tier-1 default of 20.
"""
from __future__ import annotations

import os
import random
import sys
import types

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)      # benchmarks/ is a root-level package

try:
    import hypothesis  # noqa: F401
except ImportError:
    # blank/zero/negative env values must not silently turn the fuzz tier
    # into a vacuous pass: only an explicit positive budget overrides
    _STUB_ENV = os.environ.get("STUB_HYPOTHESIS_MAX_EXAMPLES")
    _STUB_OVERRIDE = int(_STUB_ENV) if _STUB_ENV and int(_STUB_ENV) > 0 else None
    _STUB_MAX_EXAMPLES = _STUB_OVERRIDE or 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    def _settings(**kw):
        def deco(fn):
            fn._stub_settings = kw
            return fn
        return deco

    def _given(*strategies):
        def deco(fn):
            import functools
            import inspect

            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            drawn_names = [p.name for p in params[len(params) - len(strategies):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(fn, "_stub_settings", {})
                if _STUB_OVERRIDE is not None:
                    # an explicit env budget overrides per-test @settings
                    # caps — the CI `full` job raises it for soak runs
                    n = _STUB_OVERRIDE
                else:
                    n = min(cfg.get("max_examples", _STUB_MAX_EXAMPLES),
                            _STUB_MAX_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    # bind drawn values to the rightmost parameters BY NAME
                    # so leading fixture args (passed by pytest as kwargs)
                    # don't collide with them
                    drawn = {name: s.example(rng)
                             for name, s in zip(drawn_names, strategies)}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn (rightmost) parameters from pytest's fixture
            # resolution; remaining leading params stay visible as fixtures
            kept = params[:len(params) - len(strategies)]
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=kept)
            wrapper._stub_settings = getattr(fn, "_stub_settings", {})
            return wrapper
        return deco

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _mod.strategies = _st
    _mod.__stub__ = True
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
