"""Per-kernel allclose sweeps (interpret=True) against the pure-jnp
oracles in kernels/ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,K,S,hd", [
    (1, 2, 2, 128, 32),     # MHA
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 1, 128, 64),     # MQA
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_shapes(B, H, K, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=3e-2)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_matches_model_attention():
    """The model's chunked jnp attention and the kernel agree."""
    from repro.models.layers import attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, K, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out_model = attention(q, k, v, chunk=64)
    out_kernel = ops.flash_attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(out_kernel, 1, 2)),
                               np.asarray(out_model), atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,nh,P,N,T", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 4, 64, 32, 64),
])
def test_ssd_scan_vs_sequential_ref(B, S, nh, P, N, T):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.ones((nh,))
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=T, interpret=True)
    ye, ste = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), atol=2e-4, rtol=1e-3)


def test_ssd_chunk_invariance():
    """Different chunk sizes give the same answer (chunking is exact)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, nh, P, N = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.zeros((nh,))
    y32, s32 = ssd_chunked(x, dt, A, Bm, Cm, D, 32)
    y128, s128 = ssd_chunked(x, dt, A, Bm, Cm, D, 128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s128), atol=1e-4)


def test_ssd_decode_continues_prefill():
    """Running SSD over S tokens == SSD over S-1 then one decode step."""
    from repro.models.ssm import ssd_chunked, ssd_decode
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, S, nh, P, N = 1, 65, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.ones((nh,))
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, D, 64)
    _, st_prefix = ssd_chunked(x[:, :-1], dt[:, :-1], A, Bm[:, :-1],
                               Cm[:, :-1], D, 64)
    y_t, st_t = ssd_decode(st_prefix, x[:, -1], dt[:, -1], A, Bm[:, -1],
                           Cm[:, -1], D)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_t), np.asarray(st_full),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# LUAR aggregation kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [100, 1000, 128 * 256 + 17])
@pytest.mark.parametrize("use_recycled", [0.0, 1.0])
def test_luar_agg(n, use_recycled):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    d = jax.random.normal(ks[0], (n,))
    x = jax.random.normal(ks[1], (n,))
    r = jax.random.normal(ks[2], (n,))
    a, d2, x2 = ops.luar_agg(d, x, r, jnp.asarray(use_recycled), interpret=True)
    ae, d2e, x2e = ref.luar_agg_ref(d, x, r, jnp.asarray(use_recycled))
    np.testing.assert_allclose(np.asarray(a), np.asarray(ae), atol=1e-6)
    assert np.isclose(float(d2), float(d2e), rtol=1e-4)
    assert np.isclose(float(x2), float(x2e), rtol=1e-4)


def test_luar_agg_2d_shape():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    d = jax.random.normal(ks[0], (37, 53))
    x = jax.random.normal(ks[1], (37, 53))
    r = jax.random.normal(ks[2], (37, 53))
    a, d2, x2 = ops.luar_agg(d, x, r, jnp.asarray(1.0), interpret=True)
    assert a.shape == (37, 53)
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-6)
