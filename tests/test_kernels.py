"""Per-kernel allclose sweeps (interpret=True) against the pure-jnp
oracles in kernels/ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,K,S,hd", [
    (1, 2, 2, 128, 32),     # MHA
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 1, 128, 64),     # MQA
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_shapes(B, H, K, S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=3e-2)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_matches_model_attention():
    """The model's chunked jnp attention and the kernel agree."""
    from repro.models.layers import attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, K, hd = 2, 256, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out_model = attention(q, k, v, chunk=64)
    out_kernel = ops.flash_attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(out_kernel, 1, 2)),
                               np.asarray(out_model), atol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,nh,P,N,T", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 4, 64, 32, 64),
])
def test_ssd_scan_vs_sequential_ref(B, S, nh, P, N, T):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.ones((nh,))
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=T, interpret=True)
    ye, ste = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), atol=2e-4, rtol=1e-3)


def test_ssd_chunk_invariance():
    """Different chunk sizes give the same answer (chunking is exact)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, nh, P, N = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.zeros((nh,))
    y32, s32 = ssd_chunked(x, dt, A, Bm, Cm, D, 32)
    y128, s128 = ssd_chunked(x, dt, A, Bm, Cm, D, 128)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y128), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s32), np.asarray(s128), atol=1e-4)


def test_ssd_decode_continues_prefill():
    """Running SSD over S tokens == SSD over S-1 then one decode step."""
    from repro.models.ssm import ssd_chunked, ssd_decode
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, S, nh, P, N = 1, 65, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, nh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    D = jnp.ones((nh,))
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, D, 64)
    _, st_prefix = ssd_chunked(x[:, :-1], dt[:, :-1], A, Bm[:, :-1],
                               Cm[:, :-1], D, 64)
    y_t, st_t = ssd_decode(st_prefix, x[:, -1], dt[:, -1], A, Bm[:, -1],
                           Cm[:, -1], D)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_t), np.asarray(st_full),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# LUAR aggregation kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [100, 1000, 128 * 256 + 17])
@pytest.mark.parametrize("use_recycled", [0.0, 1.0])
def test_luar_agg(n, use_recycled):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    d = jax.random.normal(ks[0], (n,))
    x = jax.random.normal(ks[1], (n,))
    r = jax.random.normal(ks[2], (n,))
    a, d2, x2 = ops.luar_agg(d, x, r, jnp.asarray(use_recycled), interpret=True)
    ae, d2e, x2e = ref.luar_agg_ref(d, x, r, jnp.asarray(use_recycled))
    np.testing.assert_allclose(np.asarray(a), np.asarray(ae), atol=1e-6)
    assert np.isclose(float(d2), float(d2e), rtol=1e-4)
    assert np.isclose(float(x2), float(x2e), rtol=1e-4)


def test_luar_agg_2d_shape():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    d = jax.random.normal(ks[0], (37, 53))
    x = jax.random.normal(ks[1], (37, 53))
    r = jax.random.normal(ks[2], (37, 53))
    a, d2, x2 = ops.luar_agg(d, x, r, jnp.asarray(1.0), interpret=True)
    assert a.shape == (37, 53)
    np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-6)


@pytest.mark.parametrize("n", [1, 7, 127, 129, 1023, 8 * 128 + 1])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_luar_agg_edge_shapes(n, dtype):
    """Tiny/odd sizes (scalar-bias-like leaves) and non-fp32 inputs —
    the shapes the old block-shrink loop mishandled."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    d = jax.random.normal(ks[0], (n,), dtype)
    x = jax.random.normal(ks[1], (n,), dtype)
    r = jax.random.normal(ks[2], (n,), dtype)
    a, d2, x2 = ops.luar_agg(d, x, r, jnp.asarray(0.0), interpret=True)
    ae, d2e, x2e = ref.luar_agg_ref(d, x, r, jnp.asarray(0.0))
    assert a.dtype == dtype
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(ae, np.float32), atol=1e-6)
    assert np.isclose(float(d2), float(d2e), rtol=1e-4, atol=1e-6)
    assert np.isclose(float(x2), float(x2e), rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("block_rows", [3, 8, 17, 100, 256])
def test_luar_agg_block_rows_legal(block_rows):
    """Any block_rows request (odd included) resolves to a legal
    8-aligned divisor of the padded rows — the fixed shrink loop."""
    from repro.kernels.luar_agg import _ROWS, _block_rows_for, luar_agg
    for pad_rows in (8, 16, 24, 40, 8 * 37):
        bt = _block_rows_for(pad_rows, block_rows)
        assert bt % _ROWS == 0 and bt >= _ROWS and pad_rows % bt == 0
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    n = 5000
    d, x, r = (jax.random.normal(k, (n,)) for k in ks)
    a, d2, x2 = luar_agg(d, x, r, jnp.asarray(0.0),
                         block_rows=block_rows, interpret=True)
    ae, d2e, x2e = ref.luar_agg_ref(d, x, r, jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(a), np.asarray(ae), atol=1e-6)
    assert np.isclose(float(d2), float(d2e), rtol=1e-4)


# ---------------------------------------------------------------------------
# batched multi-unit fused round kernel
# ---------------------------------------------------------------------------


def _rand_leaves(rng, shapes, dtypes, lead=()):
    return [jnp.asarray(rng.normal(size=lead + s), d)
            for s, d in zip(shapes, dtypes)]


def _assert_batched_matches(shapes, leaf_unit, dtypes, K, seed=0,
                            block_rows=64):
    rng = np.random.default_rng(seed)
    n = 0
    for u in leaf_unit:
        n = max(n, u[0] + u[1] if isinstance(u, tuple) else u + 1)
    dl = _rand_leaves(rng, shapes, dtypes, lead=(K,))
    xl = _rand_leaves(rng, shapes, dtypes)
    pl_ = _rand_leaves(rng, shapes, dtypes)
    wn = jnp.asarray(rng.uniform(size=(K, n)), jnp.float32)
    ap = jnp.asarray(rng.uniform(size=(n,)), jnp.float32)
    af = jnp.asarray(rng.uniform(size=(n,)), jnp.float32)
    a, d2, x2 = ops.luar_agg_batched(dl, xl, pl_, leaf_unit, wn=wn,
                                     a_prev=ap, a_fresh=af,
                                     block_rows=block_rows, interpret=True)
    ae, d2e, x2e = ref.luar_agg_batched_ref(dl, xl, pl_, leaf_unit, wn=wn,
                                            a_prev=ap, a_fresh=af)
    for g, e in zip(a, ae):
        assert g.shape == e.shape and g.dtype == e.dtype
        tol = 2e-2 if g.dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(e, np.float32),
                                   atol=tol, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2e),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x2e),
                               rtol=1e-4, atol=1e-5)


def test_luar_agg_batched_cnn_like():
    """Module-granularity CNN-like layout: several leaves per unit."""
    shapes = [(3, 3, 1, 8), (8,), (3, 3, 8, 16), (16,), (392, 32), (32,)]
    _assert_batched_matches(shapes, [0, 0, 1, 1, 2, 2],
                            [jnp.float32] * 6, K=4)


def test_luar_agg_batched_edge_leaves():
    """Scalars, tiny odd leaves, bf16, stacked depth leaves and odd
    block_rows all in one layout."""
    shapes = [(), (7,), (33, 5), (3, 10, 4), (129,)]
    leaf_unit = [0, 1, 1, (2, 3), 5]
    dtypes = [jnp.float32, jnp.float32, jnp.bfloat16, jnp.float32,
              jnp.float32]
    _assert_batched_matches(shapes, leaf_unit, dtypes, K=3, block_rows=17)


def test_luar_agg_batched_k1():
    """K=1 (the synchronous round's degenerate merge)."""
    shapes = [(40, 3), (3,), (3, 9)]
    _assert_batched_matches(shapes, [0, 0, 1], [jnp.float32] * 3, K=1)


def test_pack_unpack_roundtrip():
    """pack -> unpack is the identity on every leaf (padding dropped)."""
    from repro.kernels.luar_agg import (build_pack_layout, pack_leaves,
                                        unpack_applied)
    shapes = ((), (5,), (2, 3, 4), (3, 6))
    leaf_unit = (0, 1, 0, (2, 3))
    rng = np.random.default_rng(3)
    leaves = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]
    layout = build_pack_layout(leaf_unit, shapes, 8)
    packed = pack_leaves(leaves, layout)
    back = unpack_applied(packed, layout, shapes, [l.dtype for l in leaves])
    for a, b in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bf16 dtype bucket
# ---------------------------------------------------------------------------


def test_luar_agg_batched_all_bf16():
    """A fully-bf16 model takes the single bf16 bucket (no f32 pack)."""
    shapes = [(16, 8), (8,), (8, 4), (4,)]
    _assert_batched_matches(shapes, [0, 0, 1, 1], [jnp.bfloat16] * 4, K=3)


def test_bf16_bucket_storage_is_bf16_and_lossless():
    """The bf16 bucket stores leaves in bf16 (half the HBM bytes) and
    bf16 -> bf16 packing is bit-lossless round-trip."""
    from repro.kernels.luar_agg import (build_pack_layout, pack_leaves,
                                        unpack_applied)
    shapes = ((16, 8), (8,))
    leaf_unit = (0, 1)
    rng = np.random.default_rng(7)
    leaves = [jnp.asarray(rng.normal(size=s), jnp.bfloat16) for s in shapes]
    layout = build_pack_layout(leaf_unit, shapes, 64, n_units=2, sublane=16)
    assert layout.block_rows % 16 == 0
    packed = pack_leaves(leaves, layout, dtype=jnp.bfloat16)
    assert packed.dtype == jnp.bfloat16
    back = unpack_applied(packed, layout, shapes, [l.dtype for l in leaves])
    for a, b in zip(leaves, back):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_pack_layout_sublane_alignment():
    """block_rows aligns DOWN to the dtype's sublane tile: a bf16 bucket
    may never emit a block whose height isn't a multiple of 16."""
    from repro.kernels.luar_agg import build_pack_layout
    lay = build_pack_layout((0,), ((100, 128),), 24, n_units=1, sublane=16)
    assert lay.block_rows == 16
    lay8 = build_pack_layout((0,), ((100, 128),), 24, n_units=1, sublane=8)
    assert lay8.block_rows == 24


def test_pack_layout_absent_unit_gets_zero_block():
    """A bucket holding only SOME units still spans the full unit-id
    space — absent units get one zero block so the per-unit norm
    accumulators align across buckets."""
    from repro.kernels.luar_agg import build_pack_layout, pack_leaves
    lay = build_pack_layout((2,), ((6,),), 8, n_units=4, sublane=8)
    assert lay.n_units == 4 and len(lay.unit_rows) == 4
    assert lay.seg.count(0) >= 1 and lay.seg.count(3) >= 1
    packed = pack_leaves([jnp.ones((6,), jnp.float32)], lay)
    v = np.asarray(packed).reshape(-1)
    assert v.sum() == 6.0      # only the real leaf's payload is nonzero
    start = lay.unit_row_start[2] * 128
    assert (v[start:start + 6] == 1.0).all()


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=12, deadline=None)
def test_luar_agg_batched_mixed_dtype_property(seed):
    """Property fuzz: random per-leaf dtype assignment (f32/bf16 buckets
    in one round, including stacked-depth leaves split across units)
    always matches the per-leaf oracle."""
    rng = np.random.default_rng(seed)
    shapes = [(9, 4), (4,), (3, 8, 2), (17,), ()]
    leaf_unit = [0, 0, (1, 3), 4, 4]
    dtypes = [jnp.bfloat16 if rng.random() < 0.5 else jnp.float32
              for _ in shapes]
    K = int(rng.integers(1, 5))
    _assert_batched_matches(shapes, leaf_unit, dtypes, K=K,
                            seed=int(rng.integers(0, 2 ** 16)),
                            block_rows=int(rng.choice([16, 32, 64])))
