"""Versioned downlink: delta-encoded model broadcast + bidirectional
byte accounting.

Covers the four load-bearing properties of the download path:
  * delta-chain reconstruction is LOSSLESS — replaying the ledger's
    applied-update trees reproduces the later snapshot bit-for-bit
    (additive servers apply the exact same additions);
  * chain-vs-snapshot pricing picks the cheaper transport per dispatch;
  * DeltaLedger eviction forces a full download (the downlink mirror of
    the MaskLedger's reject-on-miss);
  * a no-versioning config reproduces the PR-3 upload byte ledger
    exactly — declaring a lossless downlink must not perturb anything
    the uplink accounting or the learning trajectory already pinned.

The end-to-end simulator checks are slow-marked into the nightly CI
``full`` tier alongside the other async-path soak tests.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compress import (DELTA_STEP_UNIT_BYTES, Direction, delta_step_price,
                            parse_codec, parse_codecs, partition_codec_specs,
                            snapshot_price, versioned_download_price)
from repro.core import LuarConfig
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.sim import DeltaLedger, SimConfig, run_sim


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 12, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    return FLConfig(n_clients=12, n_active=6, tau=3, batch_size=8, **kw)


def _trees_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# grammar + direction
# ---------------------------------------------------------------------------


def test_down_prefix_round_trips_and_partitions():
    c = parse_codec("down:fedpaq:8")
    assert c.direction is Direction.DOWN and c.spec() == "down:fedpaq:8"
    d = parse_codec("down:delta")
    assert d.direction is Direction.DOWN and d.spec() == "down:delta"
    up, down = partition_codec_specs("fedpaq:4+down:delta+ef+down:fedpaq:8")
    assert up == ("fedpaq:4", "ef")
    assert down == ("down:delta", "down:fedpaq:8")


def test_delta_is_down_only_and_pipelines_are_one_direction():
    with pytest.raises(ValueError, match="only exists on the broadcast"):
        parse_codec("delta")
    with pytest.raises(ValueError, match="one direction"):
        parse_codecs(("fedpaq:4", "down:delta"))
    # direction filter splits the mixed declaration instead
    up = parse_codecs(("fedpaq:4", "down:delta"), Direction.UP)
    down = parse_codecs(("fedpaq:4", "down:delta"), Direction.DOWN)
    assert up.specs() == ("fedpaq:4",)
    assert down.specs() == ("down:delta",)


def test_delta_hoisted_before_lossy_down_stages():
    """The transport decision (chain vs snapshot) must price before a
    lossy broadcast codec scales the bytes, whatever the listed order."""
    pipe = parse_codecs(("down:fedpaq:8", "down:delta"))
    assert pipe.specs() == ("down:delta", "down:fedpaq:8")
    sizes = np.array([100.0, 200.0, 400.0])
    chain = np.array([4.0, 200.0, 400.0])
    priced = pipe.price_per_unit(sizes, np.zeros(3, bool),
                                 pipe.aux_for("delta", chain))
    np.testing.assert_allclose(priced, chain * 0.25)   # 8/32 on the chain
    nominal = pipe.price_per_unit(sizes, np.zeros(3, bool))
    np.testing.assert_allclose(nominal, sizes * 0.25)  # snapshot fallback


# ---------------------------------------------------------------------------
# pricing algebra: chain vs snapshot
# ---------------------------------------------------------------------------


def test_delta_step_and_snapshot_prices():
    sizes = np.array([100.0, 200.0, 400.0])
    mask = np.array([True, False, True])
    step = delta_step_price(sizes, mask)
    np.testing.assert_array_equal(
        step, [DELTA_STEP_UNIT_BYTES, 200.0, DELTA_STEP_UNIT_BYTES])
    # non-additive servers cannot let clients derive recycled units:
    # delta steps degenerate to dense and the snapshot always wins
    np.testing.assert_array_equal(delta_step_price(sizes, mask, additive=False),
                                  sizes)
    # the snapshot seeds the recycled-update cache for masked units
    np.testing.assert_array_equal(snapshot_price(sizes, mask),
                                  [200.0, 200.0, 800.0])
    np.testing.assert_array_equal(snapshot_price(sizes, mask, seed_cache=False),
                                  sizes)


def test_versioned_download_price_picks_cheaper():
    sizes = np.array([100.0, 200.0, 400.0])
    mask = np.zeros(3, bool)
    short = np.array([4.0, 4.0, 400.0])
    pu, used = versioned_download_price(sizes, mask, short)
    assert used and np.array_equal(pu, short)
    long_chain = short * 10
    pu, used = versioned_download_price(sizes, mask, long_chain)
    assert not used and np.array_equal(pu, sizes)      # snapshot wins
    pu, used = versioned_download_price(sizes, mask, None)
    assert not used and np.array_equal(pu, sizes)      # miss forces snapshot
    # a client already at the current version downloads nothing
    pu, used = versioned_download_price(sizes, mask, np.zeros(3))
    assert used and pu.sum() == 0.0


# ---------------------------------------------------------------------------
# DeltaLedger: bitwise chain reconstruction + eviction
# ---------------------------------------------------------------------------


def test_delta_chain_reconstruction_is_bitwise():
    """Replaying the ledger's applied trees IS the additive server's own
    computation, so the reconstructed model equals the later snapshot
    bit-for-bit — the losslessness claim of the delta transport."""
    rng = np.random.default_rng(0)
    tmpl = {"w": (5, 3), "b": (4,)}
    tree = lambda: {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
                    for k, s in tmpl.items()}
    ledger = DeltaLedger(capacity=8, store_trees=True)
    params = tree()
    snapshots = [params]
    for v in range(5):
        applied = tree()
        ledger.record_step(v, np.ones(2), applied)
        params = jax.tree.map(lambda p, d: p + d, params, applied)
        snapshots.append(params)
    # from the start and from any midpoint
    assert _trees_equal(ledger.reconstruct(snapshots[0], 0, 5), snapshots[5])
    assert _trees_equal(ledger.reconstruct(snapshots[2], 2, 4), snapshots[4])
    # empty chain is the identity
    assert _trees_equal(ledger.reconstruct(snapshots[3], 3, 3), snapshots[3])


def test_delta_ledger_eviction_and_tree_policy():
    ledger = DeltaLedger(capacity=2)
    for v in range(4):
        ledger.record_step(v, np.full(3, float(v + 1)))
    assert ledger.evictions == 2
    # steps 0/1 evicted: any chain touching them is gone
    assert ledger.chain_price(1, 4, 3) is None
    np.testing.assert_array_equal(ledger.chain_price(2, 4, 3), np.full(3, 7.0))
    np.testing.assert_array_equal(ledger.chain_price(3, 3, 3), np.zeros(3))
    with pytest.raises(RuntimeError, match="store_trees"):
        ledger.reconstruct({}, 2, 4)
    trees = DeltaLedger(capacity=2, store_trees=True)
    trees.record_step(0, np.ones(1), {"w": jnp.ones(2)})
    trees.record_step(1, np.ones(1), {"w": jnp.ones(2)})
    trees.record_step(2, np.ones(1), {"w": jnp.ones(2)})
    with pytest.raises(KeyError, match="evicted"):
        trees.reconstruct({"w": jnp.zeros(2)}, 0, 3)


# ---------------------------------------------------------------------------
# run_fl + sync engine: lossless transport, honest ledger
# ---------------------------------------------------------------------------


def test_run_fl_down_delta_is_bitwise_and_cheaper(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    plain = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                   cfg, task["eval_fn"])
    delta = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                   _cfg(luar=LuarConfig(delta=2), codecs=("down:delta",)),
                   task["eval_fn"])
    # the transport is lossless: identical trajectory, identical uplink
    assert _trees_equal(plain.params, delta.params)
    assert plain.comm_ratio == delta.comm_ratio
    # no-versioning reproduces the PR-3 ledger exactly: full broadcast
    assert plain.down_ratio == 1.0
    # versioned downlink strictly cheaper than the full broadcast
    assert 0.0 < delta.down_ratio < 1.0
    assert delta.downloaded < plain.downloaded


@pytest.mark.slow
def test_sync_sim_down_delta_bitwise_and_counts(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    plain = run_sim(task["loss_fn"], task["params"], task["data"],
                    task["parts"], cfg, SimConfig(scenario="uniform"),
                    task["eval_fn"])
    delta = run_sim(task["loss_fn"], task["params"], task["data"],
                    task["parts"],
                    _cfg(luar=LuarConfig(delta=2), codecs=("down:delta",)),
                    SimConfig(scenario="uniform"), task["eval_fn"])
    assert _trees_equal(plain.params, delta.params)
    assert plain.comm_ratio == delta.comm_ratio
    assert plain.down_ratio == 1.0 and plain.n_delta_downloads == 0
    assert plain.n_dispatched == cfg.n_active * cfg.rounds
    # every client's FIRST dispatch is the cache-seeding snapshot; each
    # re-dispatch ships the one-step chain (uniform scenario: nobody
    # misses, the subscribed population stays one version behind)
    assert cfg.n_active <= delta.n_full_downloads <= cfg.n_clients
    assert delta.n_delta_downloads == delta.n_dispatched - delta.n_full_downloads
    assert delta.n_delta_downloads > 0
    assert delta.down_ratio < 1.0
    # bidirectional history: both ratios reported every eval
    assert all("down_ratio" in h and "comm_ratio" in h for h in delta.history)


def test_non_additive_server_degrades_to_plain_snapshots(task):
    """fedopt's broadcast is not ``x + applied``: a chain follower cannot
    derive recycled units, so down:delta must disable itself — every
    download is the plain (unseeded) full snapshot."""
    from repro.fl.server import ServerConfig
    cfg = _cfg(rounds=4, luar=LuarConfig(delta=2),
               server=ServerConfig(kind="fedopt", lr=0.1),
               codecs=("down:delta",))
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, SimConfig(scenario="uniform"),
                  task["eval_fn"])
    assert res.n_delta_downloads == 0
    assert res.n_full_downloads == res.n_dispatched
    assert res.down_ratio == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# fedbuff: the tentpole end-to-end claims
# ---------------------------------------------------------------------------


def _fedbuff(task, sim_kw, **cfg_kw):
    cfg = _cfg(rounds=20, eval_every=5, **cfg_kw)
    return cfg, run_sim(task["loss_fn"], task["params"], task["data"],
                        task["parts"], cfg, SimConfig(mode="fedbuff", **sim_kw),
                        task["eval_fn"])


@pytest.mark.slow
def test_fedbuff_down_delta_total_bytes_below_full_broadcast(task):
    """The acceptance claim: with the delta-encoded broadcast, TOTAL
    (up + down) bytes drop strictly below the full-broadcast baseline at
    equal accuracy.  Every client stays in flight and the buffer spans
    one rotation, so the redispatch lag is ~1 version and the chain wins
    nearly every pricing comparison."""
    um = build_units(task["params"], "leaf")
    total = float(sum(um.unit_bytes))
    sim_kw = dict(scenario="uniform", buffer_size=12, concurrency=12)
    luar = LuarConfig(delta=4, granularity="leaf")
    _, base = _fedbuff(task, sim_kw, luar=luar)
    _, delt = _fedbuff(task, sim_kw, luar=luar, codecs=("down:delta",))
    up_base = base.comm_ratio * total * base.n_uplinks_spent
    up_delt = delt.comm_ratio * total * delt.n_uplinks_spent
    assert base.down_ratio == 1.0
    assert delt.down_ratio < 1.0
    assert delt.n_delta_downloads > delt.n_full_downloads
    # total bytes strictly below the full-broadcast baseline...
    assert up_delt + delt.downloaded < up_base + base.downloaded
    # ...at equal accuracy (the lossless transport trains the same model;
    # async arrival order shifts with the faster downlink, so "equal" is
    # statistical, not bitwise)
    assert abs(base.history[-1]["acc"] - delt.history[-1]["acc"]) < 0.05


@pytest.mark.slow
def test_fedbuff_delta_ledger_eviction_forces_full_download(task):
    """With the DeltaLedger too small for the population's version lag,
    every chain lookup misses and the engine falls back to snapshots —
    eviction degrades cost, never correctness."""
    luar = LuarConfig(delta=5, scheme="random", granularity="leaf")
    # idle-pool rotation: lag ~4 versions between a client's downloads
    sim_kw = dict(scenario="uniform", buffer_size=2, concurrency=4,
                  mask_ledger=False)
    _, roomy = _fedbuff(task, dict(sim_kw, ledger_capacity=64),
                        luar=luar, codecs=("down:delta",))
    _, tiny = _fedbuff(task, dict(sim_kw, ledger_capacity=2),
                       luar=luar, codecs=("down:delta",))
    assert roomy.n_delta_downloads > 0          # chains do win when resident
    assert tiny.n_delta_downloads < roomy.n_delta_downloads
    assert tiny.n_full_downloads > roomy.n_full_downloads
    # forced snapshots cost more downlink than resident chains
    assert tiny.downloaded > roomy.downloaded


@pytest.mark.slow
def test_fedbuff_no_versioning_reproduces_pr3_ledger(task):
    """A config with no down: stages must reproduce the PR-3 byte ledger
    exactly: full-model broadcast per dispatch, upload accounting only
    over spent uplinks (== received when nothing is rejected)."""
    um = build_units(task["params"], "leaf")
    total = float(sum(um.unit_bytes))
    _, res = _fedbuff(task, dict(scenario="bimodal", buffer_size=4,
                                 concurrency=8),
                      luar=LuarConfig(delta=2, granularity="leaf"))
    assert res.down_ratio == 1.0
    assert res.downloaded == total * res.n_dispatched
    assert res.n_full_downloads == res.n_dispatched
    assert res.n_delta_downloads == 0
    assert res.ledger_misses == 0
    assert res.n_uplinks_spent == res.n_received
    # the upload ledger: zero waste with the mask ledger on (PR-2/PR-3
    # invariant), so comm_ratio is exactly the old accepted-only formula
    assert res.wasted_upload_bytes == 0.0
    assert res.comm_ratio <= 1.0
