"""repro.sim — event queue, heterogeneity profiles, cost model, and the
two server modes.  The load-bearing check is the equivalence path: the
event-driven engine with heterogeneity disabled and deadline=inf must
reproduce the synchronous ``fl/rounds.py`` trajectory BIT-FOR-BIT."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SIM_SCENARIOS, get_scenario
from repro.core import (CommStats, LuarConfig, comm_init, comm_update,
                        staleness_discount, staleness_weighted_merge)
from repro.core.comm import ClientResources, round_trip_time, upload_time
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.sim import (EventQueue, SimConfig, run_sim, sample_resources,
                       time_to_target)


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    return FLConfig(n_clients=16, n_active=6, tau=3, batch_size=8, **kw)


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------


def test_event_queue_orders_by_time_then_fifo():
    q = EventQueue()
    q.push(2.0, "arrival", 0)
    q.push(1.0, "arrival", 1)
    q.push(1.0, "arrival", 2)        # same time: FIFO by push order
    order = [(q.pop().client, q.now) for _ in range(3)]
    assert order == [(1, 1.0), (2, 1.0), (0, 2.0)]


def test_event_queue_rejects_past_and_nonfinite():
    q = EventQueue()
    q.push(1.0, "arrival", 0)
    q.pop()
    with pytest.raises(ValueError):
        q.push(0.5, "arrival", 1)
    with pytest.raises(ValueError):
        q.push(math.inf, "deadline")


def test_sim_run_is_seed_deterministic(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    sim = SimConfig(scenario="bimodal", deadline=60.0, sys_seed=3)
    a = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                cfg, sim, task["eval_fn"])
    b = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                cfg, sim, task["eval_fn"])
    assert a.sim_time == b.sim_time
    assert a.history == b.history
    for p, q_ in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(np.asarray(p), np.asarray(q_))


# ---------------------------------------------------------------------------
# heterogeneity profiles + cost model
# ---------------------------------------------------------------------------


def test_profiles_deterministic_and_shaped():
    for name in SIM_SCENARIOS:
        r1 = sample_resources(name, 32, seed=7)
        r2 = sample_resources(name, 32, seed=7)
        assert r1 == r2 and len(r1) == 32
    uni = sample_resources("uniform", 8)
    assert len(set(uni)) == 1            # heterogeneity disabled = identical


def test_bimodal_has_two_modes():
    res = sample_resources("bimodal", 400, seed=0)
    ups = np.array([r.up_bw for r in res])
    sc = get_scenario("bimodal")
    fast = ups > 10 * sc.up_bw
    assert 0.05 < fast.mean() < 0.5      # both populations present
    slow_med = np.median([r.step_time for r, f in zip(res, fast) if not f])
    fast_med = np.median([r.step_time for r, f in zip(res, fast) if f])
    assert fast_med < slow_med / 5


def test_recycle_mask_shrinks_upload_time():
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    um = build_units(params, "leaf")
    r = ClientResources(step_time=0.01, up_bw=1e5, down_bw=1e6)
    full = upload_time(um, np.zeros(len(um.names), bool), r)
    masked = upload_time(um, np.array([True] + [False] * (len(um.names) - 1)), r)
    assert masked < full
    assert round_trip_time(um, np.zeros(len(um.names), bool), r, tau=5) > full


# ---------------------------------------------------------------------------
# equivalence: ideal-regime event engine == synchronous round engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delta", [0, 2])
def test_sync_ideal_matches_run_fl_bitwise(task, delta):
    cfg = _cfg(luar=LuarConfig(delta=delta))
    ref = run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                 cfg, task["eval_fn"])
    got = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="uniform", deadline=math.inf),
                  task["eval_fn"])
    for p, q_ in zip(jax.tree.leaves(ref.params), jax.tree.leaves(got.params)):
        assert np.array_equal(np.asarray(p), np.asarray(q_))
    assert np.array_equal(np.asarray(ref.luar_state.mask),
                          np.asarray(got.luar_state.mask))
    assert [h["acc"] for h in ref.history] == [h["acc"] for h in got.history]
    assert np.isclose(ref.comm_ratio, got.comm_ratio)
    assert got.n_stragglers == 0 and got.n_dropped == 0


# ---------------------------------------------------------------------------
# systems behaviours
# ---------------------------------------------------------------------------


def test_deadline_drops_stragglers(task):
    cfg = _cfg()
    sc = get_scenario("bimodal")
    # deadline chosen between the datacenter (~0.01s) and mobile (~0.2s)
    # round-trip times for this model size
    fast = SimConfig(scenario=sc, deadline=0.1, overprovision=1.5)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, fast, task["eval_fn"])
    assert res.n_stragglers > 0
    # a straggler's uplink was spent and discarded: charged as waste
    assert res.wasted_upload_bytes > 0
    assert res.wasted_per_unit.sum() == pytest.approx(res.wasted_upload_bytes)
    assert res.sim_time <= 0.1 * cfg.rounds + 1e-9
    assert res.n_received + res.n_stragglers + res.n_dropped \
        == int(round(cfg.n_active * 1.5)) * cfg.rounds


def test_dropout_past_deadline_still_counted(task):
    """A device that vanishes later than the round closes is dropped, not
    a straggler: the full dispatch ledger must still balance."""
    cfg = _cfg()
    sc = get_scenario("bimodal_flaky")        # dropout on the mobile mode
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario=sc, deadline=0.05, sys_seed=1),
                  task["eval_fn"])
    assert res.n_dropped > 0
    assert res.n_received + res.n_stragglers + res.n_dropped \
        == cfg.n_active * cfg.rounds


def test_dropout_clients_never_upload(task):
    cfg = _cfg()
    sc = get_scenario("uniform").replace(dropout=0.5)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario=sc), task["eval_fn"])
    assert res.n_dropped > 0
    assert res.n_received + res.n_dropped == cfg.n_active * cfg.rounds


def test_overprovision_collect_k(task):
    """Over-provisioned cohort, close at k arrivals: slowest are dropped."""
    cfg = _cfg()
    sim = SimConfig(scenario="lognormal", overprovision=2.0, collect=cfg.n_active)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, sim, task["eval_fn"])
    assert res.n_received == cfg.n_active * cfg.rounds
    assert res.n_stragglers == cfg.n_active * cfg.rounds   # 2x - k
    assert res.history[-1]["acc"] > 0.5


def test_fedbuff_progresses_and_counts(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    sim = SimConfig(scenario="bimodal", mode="fedbuff", buffer_size=4,
                    concurrency=8)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, sim, task["eval_fn"])
    assert res.rounds_done == cfg.rounds
    assert res.n_received >= cfg.rounds * 4
    assert res.history[-1]["acc"] > 0.5
    assert res.sim_time > 0


def test_luar_cuts_wall_clock_under_thin_uplink(task):
    """The tentpole claim at test scale: with upload-dominated mobile
    links, the recycle mask turns byte savings into time savings."""
    params = task["params"]
    um = build_units(params, "leaf")
    model_bytes = float(sum(um.unit_bytes))
    sc = get_scenario("uniform").replace(
        step_time=1e-4, up_bw=model_bytes / 10.0, down_bw=model_bytes * 10.0)
    times = {}
    for name, delta in [("fedavg", 0), ("fedluar", 3)]:
        cfg = _cfg(luar=LuarConfig(delta=delta))
        res = run_sim(task["loss_fn"], params, task["data"], task["parts"],
                      cfg, SimConfig(scenario=sc), task["eval_fn"])
        times[name] = res.sim_time
    assert times["fedluar"] < 0.8 * times["fedavg"]


# ---------------------------------------------------------------------------
# staleness-aware aggregation path
# ---------------------------------------------------------------------------


def test_staleness_discount_monotone():
    w = staleness_discount(jnp.arange(5), alpha=0.5)
    assert np.all(np.diff(np.asarray(w)) < 0)
    assert np.isclose(float(w[0]), 1.0)


def test_staleness_merge_equal_staleness_is_mean():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    out = staleness_weighted_merge(tree, jnp.zeros(3, jnp.int32))
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"]).mean(0), rtol=1e-6)


def test_staleness_merge_downweights_stale():
    tree = {"a": jnp.stack([jnp.ones(4), -jnp.ones(4)])}
    out = staleness_weighted_merge(tree, jnp.asarray([0, 8]), alpha=1.0)
    assert np.all(np.asarray(out["a"]) > 0)      # fresh +1 outweighs stale -1


# ---------------------------------------------------------------------------
# host-side comm accounting precision (satellite fix)
# ---------------------------------------------------------------------------


class _UMStub:
    def __init__(self, sizes):
        self.unit_bytes = tuple(sizes)


def test_comm_accounting_exact_past_float32_range():
    um = _UMStub([1 << 24])              # 16 MiB units
    stats = comm_init()
    mask = np.zeros(1, bool)
    for _ in range(10):
        stats = comm_update(stats, um, mask, 1)
        stats = CommStats(stats.bytes_uploaded + 1.0, stats.rounds)  # odd byte
    # float32 accumulation would round the +1s away past 2**24
    assert stats.bytes_uploaded == 10 * (1 << 24) + 10
    assert isinstance(stats.bytes_uploaded, float)
    assert stats.rounds == 10


def test_time_to_target_helper(task):
    cfg = _cfg(rounds=12, eval_every=2)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="uniform"), task["eval_fn"])
    t = time_to_target(res, "acc", 0.8)
    assert math.isfinite(t) and t <= res.sim_time
    assert time_to_target(res, "acc", 2.0) == math.inf


def test_time_to_target_rejects_bad_mode(task):
    """A typo'd mode used to silently return inf — indistinguishable
    from 'never reached the target'."""
    cfg = _cfg(rounds=4, eval_every=2)
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="uniform"), task["eval_fn"])
    with pytest.raises(ValueError, match="'max' or 'min'"):
        time_to_target(res, "acc", 0.5, mode="mx")


# ---------------------------------------------------------------------------
# bidirectional byte accounting (the comm-ratio bugfixes)
# ---------------------------------------------------------------------------


def test_comm_ratio_at_most_one_for_uncompressed_straggler_run(task):
    """The denominator bug: straggler waste was in the numerator but the
    denominator only counted accepted uploads, so an UNCOMPRESSED run
    could report a ratio above 1 — i.e. worse than the FedAvg baseline
    that would have paid for the very same dispatches.  Denominated over
    dispatched-and-spent uplinks, no compression means exactly 1."""
    cfg = _cfg()          # no codecs, delta=0: every upload is full-size
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario="bimodal", deadline=0.1,
                                 overprovision=2.0), task["eval_fn"])
    assert res.n_stragglers > 0                   # the regime that broke
    assert res.n_uplinks_spent == res.n_received + res.n_stragglers
    assert res.comm_ratio == pytest.approx(1.0)
    assert all(h["comm_ratio"] <= 1.0 + 1e-9 for h in res.history)


def test_dropout_and_straggler_downloads_charged_to_waste(task):
    """A sync-mode dropout vanishes after download+compute: its (priced)
    downlink is spent and must land in the waste ledger — as must a
    straggler's, whose whole round trip was discarded."""
    cfg = _cfg()
    sc = get_scenario("bimodal_flaky")
    res = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, SimConfig(scenario=sc, deadline=0.1, sys_seed=1),
                  task["eval_fn"])
    assert res.n_dropped > 0 and res.n_stragglers > 0
    # no downlink codecs: every download is the full model, so the waste
    # is exactly (dropouts + stragglers) x model bytes
    um = build_units(task["params"], "leaf")
    um_bytes = float(sum(um.unit_bytes))
    assert res.wasted_download_bytes == pytest.approx(
        um_bytes * (res.n_dropped + res.n_stragglers))
    assert res.downloaded == pytest.approx(um_bytes * res.n_dispatched)
    assert res.down_ratio == pytest.approx(1.0)


def test_diurnal_validation_fires_at_resolution():
    """Bad diurnal parameters raise when the scenario is RESOLVED, even
    with the amplitude at 0 (the old per-call check skipped validation
    entirely then and only raised mid-run otherwise)."""
    from repro.sim import get_scenario as resolve, validate_scenario
    bad_period = SIM_SCENARIOS["diurnal"].replace(bw_amplitude=0.0,
                                                  bw_period=-5.0)
    with pytest.raises(ValueError, match="bw_period"):
        resolve(bad_period)
    bad_amp = SIM_SCENARIOS["diurnal"].replace(bw_amplitude=1.5)
    with pytest.raises(ValueError, match="bw_amplitude"):
        validate_scenario(bad_amp)
    # the hot path trusts resolution: a valid quiet cycle just returns 1
    from repro.sim.profiles import bandwidth_multiplier
    quiet = SIM_SCENARIOS["diurnal"].replace(bw_amplitude=0.0)
    assert bandwidth_multiplier(quiet, 123.4) == 1.0
