"""repro.analyze tests: per-rule fixture positives/negatives (with
file:line span assertions), the whole-repo clean smoke gate, the CLI
surface, and the baseline workflow."""
import json
from pathlib import Path

import pytest

from repro.analyze import (Finding, load_baseline, parse_rules, run_rules,
                           write_baseline)
from repro.analyze.cli import main

REPO = Path(__file__).resolve().parent.parent
FIX = Path(__file__).resolve().parent / "analyze_fixtures"
BAD, GOOD = FIX / "bad", FIX / "good"

ALL_RULES = ("jit-purity", "rng-discipline", "pallas-layout",
             "ckpt-coverage", "metric-consistency", "spec-consistency")


def marker_line(rel: str, marker: str) -> int:
    """1-based line of the ``# VIOLATION: <marker>`` comment in a bad
    fixture file — the tests assert spans by marker so they survive
    fixture edits."""
    text = (BAD / rel).read_text().splitlines()
    for i, line in enumerate(text, 1):
        if f"VIOLATION: {marker}" in line:
            return i
    raise AssertionError(f"no marker {marker!r} in {rel}")


# every expected positive: (rule, file, marker-or-None, message fragment)
EXPECTED = [
    ("jit-purity", "src/proj/jitmod.py", "tracer-branch",
     "branch on parameter `flag`"),
    ("jit-purity", "src/proj/jitmod.py", "host-numpy",
     "host numpy call `numpy.cumsum`"),
    ("jit-purity", "src/proj/jitmod.py", "materializer",
     "`.item()` materializes"),
    ("jit-purity", "src/proj/jitmod.py", "host-coercion",
     "`float(...)` coerces"),
    ("rng-discipline", "src/proj/jitmod.py", "numpy-rng",
     "numpy RNG `numpy.random.rand`"),
    ("rng-discipline", "src/proj/jitmod.py", "key-reuse",
     "key `key` consumed twice"),
    ("pallas-layout", "src/proj/kernels/badkernel.py", "kernel-arity",
     "takes 3 positional refs but pallas_call wires 2"),
    ("pallas-layout", "src/proj/kernels/badkernel.py", None,
     "lane dim 100 is not a multiple of 128"),
    ("pallas-layout", "src/proj/kernels/badkernel.py", None,
     "index map takes 2 args; grid has 1 axes"),
    ("pallas-layout", "src/proj/kernels/badkernel.py", "sublane-misaligned",
     "sublane dim 7 is not a multiple of 8"),
    ("ckpt-coverage", "src/proj/serve/core.py", "uncovered-attr",
     "`lost_counter` is never saved"),
    ("ckpt-coverage", "src/proj/serve/core.py", "uncovered-attr",
     "`lost_counter` is never restored"),
    ("ckpt-coverage", "src/proj/serve/state.py", "unfingerprinted-field",
     "`drift_knob` is not part of _fingerprint"),
    ("ckpt-coverage", "src/proj/serve/state.py", None,
     "meta key `note` is read by load_into() but never written"),
    ("metric-consistency", "src/proj/engine.py", "uncatalogued-metric",
     "`fl_rogue_total` is not in the obs catalogue"),
    ("metric-consistency", "src/proj/engine.py", "kind-conflict",
     "created as counter here but as gauge"),
    ("metric-consistency", "src/proj/engine.py", "label-disagreement",
     "label sets must agree"),
    ("spec-consistency", "src/proj/engine.py", "bad-codec-spec",
     "codecs spec ['nosuch:9'] rejected"),
    ("spec-consistency", "src/proj/engine.py", "bad-participation-spec",
     "participation spec ['nosuch:1'] rejected"),
]


@pytest.fixture(scope="module")
def bad_findings():
    return run_rules(BAD)


@pytest.mark.parametrize("rule,rel,marker,fragment", EXPECTED,
                         ids=[f"{r}-{m or f[:20]}" for r, _, m, f in EXPECTED])
def test_bad_fixture_detected_with_span(bad_findings, rule, rel, marker,
                                        fragment):
    hits = [f for f in bad_findings
            if f.rule == rule and f.path == rel and fragment in f.message]
    assert hits, (f"{rule} did not flag {fragment!r} in {rel}; got "
                  f"{[f.format() for f in bad_findings if f.rule == rule]}")
    if marker is not None:
        want = marker_line(rel, marker)
        assert any(f.line == want for f in hits), \
            f"expected line {want}, got {[f.line for f in hits]}"


def test_bad_fixture_exact_count(bad_findings):
    # the fixture set is closed: every finding is one of the expected
    # ones (no FP drift), and every expectation is found
    assert len(bad_findings) == len(EXPECTED)


@pytest.mark.parametrize("rule", ALL_RULES)
def test_good_fixture_clean(rule):
    assert run_rules(GOOD, rules=rule) == []


def test_whole_repo_clean():
    """Tier-1 smoke: the checker must exit clean on this checkout (CI
    runs the same thing as a blocking job)."""
    findings = run_rules(REPO)
    assert findings == [], "\n" + "\n".join(f.format() for f in findings)


# --- registry / API surface ------------------------------------------------


def test_parse_rules_unknown_name():
    with pytest.raises(ValueError, match="unknown rule 'nope'"):
        parse_rules("nope")


def test_parse_rules_selects_subset():
    rules = parse_rules("jit-purity,pallas-layout")
    assert [r.name for r in rules] == ["jit-purity", "pallas-layout"]


def test_run_rules_accepts_iterable_of_names():
    fs = run_rules(BAD, rules=["spec-consistency"])
    assert fs and all(f.rule == "spec-consistency" for f in fs)


def test_fingerprint_is_line_independent():
    a = Finding("r", "p.py", 10, 0, "msg")
    b = Finding("r", "p.py", 99, 4, "msg")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != Finding("r", "p.py", 10, 0, "other").fingerprint


# --- call-graph rooting ----------------------------------------------------


def _graph_for(tmp_path, source: str):
    from repro.analyze.callgraph import CallGraph
    from repro.analyze.core import Project
    mod = tmp_path / "src" / "proj" / "mod.py"
    mod.parent.mkdir(parents=True)
    mod.write_text(source)
    return CallGraph(Project.load(tmp_path))


def test_shard_map_body_is_jit_root(tmp_path):
    # the fleet wave-kernel shape: shard_map traces its body per shard
    # exactly like jit traces its argument, including through a
    # functools.partial wrapper
    g = _graph_for(tmp_path, """\
from functools import partial
from jax.experimental.shard_map import shard_map

def body(axes, key, x):
    return x

def make(mesh):
    return shard_map(partial(body, ("data",)), mesh=mesh,
                     in_specs=None, out_specs=None)
""")
    info = g.funcs["proj.mod:body"]
    assert info.is_root and info.root_reason == "shard_map(...)"


def test_vmap_wrapper_unwrapped_for_jit_root(tmp_path):
    # jax.jit(jax.vmap(f)) traces f: the rooting must see through the
    # transform wrapper (the fleet wave trainer's exact shape)
    g = _graph_for(tmp_path, """\
import jax

def train_one(p, b):
    return p

def make():
    return jax.jit(jax.vmap(train_one))
""")
    info = g.funcs["proj.mod:train_one"]
    assert info.is_root and info.root_reason == "jax.jit(...)"


# --- baseline workflow -----------------------------------------------------


def test_baseline_roundtrip_suppresses(tmp_path, bad_findings):
    path = tmp_path / "baseline.json"
    write_baseline(path, bad_findings, reason="fixture: intentional bad code")
    fps = load_baseline(path)
    assert len(fps) == len({f.fingerprint for f in bad_findings})
    assert run_rules(BAD, baseline=fps) == []


def test_baseline_entry_without_reason_rejected(tmp_path):
    path = tmp_path / "baseline.json"
    doc = {"version": 1, "entries": [
        {"fingerprint": "abc123", "path": "x.py", "reason": "  "}]}
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="has no reason"):
        load_baseline(path)


def test_baseline_todo_placeholder_rejected(tmp_path, bad_findings):
    # the reason-less --write-baseline output must NOT load: a stamped
    # placeholder that satisfied the mandatory-reason check forever was
    # exactly the loophole this closes
    path = tmp_path / "baseline.json"
    write_baseline(path, bad_findings)
    with pytest.raises(ValueError, match="placeholder reason"):
        load_baseline(path)
    doc = {"version": 1, "entries": [
        {"fingerprint": "abc123", "path": "x.py",
         "reason": "todo later, promise"}]}
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="placeholder reason"):
        load_baseline(path)


def test_baseline_version_mismatch_rejected(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="baseline version"):
        load_baseline(path)


# --- CLI surface -----------------------------------------------------------


def test_cli_exit_codes_and_text(capsys):
    assert main(["--root", str(BAD), "--baseline", ""]) == 1
    out = capsys.readouterr().out
    assert f"repro.analyze: {len(EXPECTED)} finding(s)" in out
    assert main(["--root", str(GOOD), "--baseline", ""]) == 0


def test_cli_github_format(capsys):
    assert main(["--root", str(BAD), "--baseline", "",
                 "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=src/proj/jitmod.py,line=" in out
    assert f"::notice::repro.analyze: {len(EXPECTED)} finding(s)" in out


def test_cli_json_format(capsys):
    assert main(["--root", str(BAD), "--baseline", "",
                 "--rules", "pallas-layout", "--format", "json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["rules"] == ["pallas-layout"]
    assert all(f["rule"] == "pallas-layout" for f in doc["findings"])
    assert all(set(f) >= {"rule", "path", "line", "col", "message",
                          "fingerprint"} for f in doc["findings"])


def test_cli_unknown_rule_is_usage_error(capsys):
    assert main(["--rules", "bogus"]) == 2
    assert "unknown rule" in capsys.readouterr().err


def test_cli_write_baseline_then_clean(tmp_path, capsys):
    bl = tmp_path / "bl.json"
    assert main(["--root", str(BAD), "--write-baseline", str(bl),
                 "--reason", "fixture: intentional bad code"]) == 0
    assert main(["--root", str(BAD), "--baseline", str(bl)]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out and "baselined" in out


def test_cli_write_baseline_without_reason_is_inert(tmp_path, capsys):
    # no --reason: the file writes (with a warning) but refuses to load,
    # so the stamped TODO cannot silently grandfather findings
    bl = tmp_path / "bl.json"
    assert main(["--root", str(BAD), "--write-baseline", str(bl)]) == 0
    assert "placeholder" in capsys.readouterr().out
    assert main(["--root", str(BAD), "--baseline", str(bl)]) == 2
    assert "placeholder reason" in capsys.readouterr().err
