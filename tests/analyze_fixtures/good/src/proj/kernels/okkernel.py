"""Clean mirror of bad/src/proj/kernels/badkernel.py."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def run(x):
    return pl.pallas_call(
        _kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
    )(x)
