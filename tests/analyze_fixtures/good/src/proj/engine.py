"""Clean mirror of bad/src/proj/engine.py."""
from proj.obs.metrics import M_BYTES, M_ROUNDS


def setup(m):
    g = m.gauge(M_ROUNDS, "rounds")
    b = m.counter(M_BYTES, "bytes")
    b.labels(client="0").inc()
    b.labels(client="1").inc()
    return g, b


def make(run):
    return run(codecs=("fedpaq:4",), participation="powd:10")
