"""Clean mirror of bad/src/proj/jitmod.py."""
import jax
import jax.numpy as jnp


@jax.jit
def pure_step(x):
    y = jnp.cumsum(x)
    return x + y


def disciplined(key):
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, (4,))
    b = jax.random.uniform(key, (4,))
    return a + b
