"""Clean mirror of bad/src/proj/serve/core.py."""


class RoundServer:
    def __init__(self, params, cfg, serve_cfg):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.version = 0

    def step(self, delta):
        self.params = delta
        self.version += 1
