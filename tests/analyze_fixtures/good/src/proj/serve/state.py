"""Clean mirror of bad/src/proj/serve/state.py."""
from dataclasses import dataclass


@dataclass(frozen=True)
class ServeConfig:
    buffer_size: int = 4
    ckpt_path: str = ""


def _fingerprint(server):
    return {"buffer_size": int(server.serve_cfg.buffer_size)}


def snapshot(server):
    arrays = {}
    arrays["version"] = server.version
    arrays["params"] = server.params
    meta = {"schema": 1, "config": _fingerprint(server)}
    return arrays, meta


def load_into(server, arrays, meta):
    if meta["schema"] != 1:
        raise ValueError("schema drift")
    if meta["config"] != _fingerprint(server):
        raise ValueError("config drift")
    server.version = arrays["version"]
    server.params = arrays["params"]
