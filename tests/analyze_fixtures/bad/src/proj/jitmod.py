"""Deliberate jit-purity / rng-discipline violations (never executed)."""
import jax
import numpy as np


@jax.jit
def impure_step(x, flag):
    if flag:  # VIOLATION: tracer-branch
        x = x + 1
    y = np.cumsum(x)  # VIOLATION: host-numpy
    z = np.random.rand()  # VIOLATION: numpy-rng
    s = x.sum().item()  # VIOLATION: materializer
    f = float(s)  # VIOLATION: host-coercion
    return x * f + y + z


def reuse_keys(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))  # VIOLATION: key-reuse
    return a + b
