"""Fixture metric catalogue (mirrors the real obs/metrics.py shape)."""

M_ROUNDS = "fl_rounds"
M_BYTES = "fl_bytes_up"
