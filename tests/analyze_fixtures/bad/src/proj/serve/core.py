"""Deliberate ckpt-coverage violation: mutable state the WAL misses."""


class RoundServer:
    def __init__(self, params, cfg, serve_cfg):
        self.params = params
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.version = 0
        self.lost_counter = 0

    def step(self, delta):
        self.params = delta
        self.version += 1
        self.lost_counter += 1  # VIOLATION: uncovered-attr
