"""Deliberate pallas-layout violations (never executed)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):  # VIOLATION: kernel-arity (call wires 2)
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        _kernel,
        grid=(4,),
        # VIOLATION: index-map-arity + lane-misaligned
        in_specs=[pl.BlockSpec((8, 100), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((7, 128),  # VIOLATION: sublane-misaligned
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((64, 128), jnp.float32),
    )(x)
