"""Deliberate metric-consistency / spec-consistency violations."""
from proj.obs.metrics import M_BYTES, M_ROUNDS


def setup(m):
    rogue = m.counter("fl_rogue_total", "x")  # VIOLATION: uncatalogued-metric
    g = m.gauge(M_ROUNDS, "rounds")
    c = m.counter("fl_rounds", "again")  # VIOLATION: kind-conflict
    b = m.counter(M_BYTES, "bytes")
    b.labels(client="0").inc()
    b.labels(phase="up").inc()  # VIOLATION: label-disagreement
    return rogue, g, c


def make(run):
    return run(codecs=("nosuch:9",),  # VIOLATION: bad-codec-spec
               participation="nosuch:1")  # VIOLATION: bad-participation-spec
