"""repro.serve — the FL round service.

Load-bearing checks:

1. KILL-AND-RESUME IS LOSSLESS: a server killed between two uploads and
   resumed from its write-ahead snapshot finishes a fixed request tape
   with BITWISE-identical params, byte ledgers, /metrics exposition and
   /v1/status versus a never-killed server fed the same tape — at every
   kill point.
2. Ledger eviction survives a restart: a dispatch whose recycle mask is
   evicted mid-flight is rejected identically (counters and all) whether
   or not the server was killed and resumed in between.
3. GOLDEN ENDPOINTS: with an injected zero clock, /v1/status and
   /metrics are byte-stable across independent runs and match the pinned
   schema.
4. The checkpoint substrate: atomic save (no torn snapshots trusted),
   restore errors that NAME every missing/mismatched key.
5. The HTTP wire end-to-end (the CI smoke via ``repro.serve.client``),
   error-to-status-code mapping, metrics state_dict round-trip, the
   measured link trace, and the launch/serve -> launch/generate rename.
"""
import json
import os
import urllib.request

import jax
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.obs import MetricsRegistry, Telemetry
from repro.serve import http as serve_http
from repro.serve.client import ServeClient, _build_workload, make_clients
from repro.serve.core import (ClientBusy, ClientUnavailable, RoundServer,
                              ServeError, UnknownDispatch, VersionMismatch)
from repro.serve.state import ServeConfig

N_CLIENTS = 4


def workload(n=N_CLIENTS, codecs="down:delta", buffer_size=3):
    return _build_workload(n, 0, buffer_size, codecs)


def request_tape(n_ops, n_clients=N_CLIENTS, seed=7):
    """Deterministic (kind, client, update-seed) request sequence with a
    dispatch always preceding its upload."""
    rng = np.random.default_rng(seed)
    ops, inflight = [], set()
    while len(ops) < n_ops:
        c = int(rng.integers(n_clients))
        if c in inflight:
            ops.append(("upload", c, int(rng.integers(1 << 30))))
            inflight.discard(c)
        else:
            ops.append(("dispatch", c, 0))
            inflight.add(c)
    return ops


def fixed_update(template, useed):
    r = np.random.default_rng(useed)
    return jax.tree.map(lambda x: np.asarray(
        r.standard_normal(np.shape(x)), np.float32) * 0.01, template)


def drive(server, ops):
    for kind, c, useed in ops:
        if kind == "dispatch":
            server.dispatch(c)
        else:
            server.upload(c, fixed_update(server.params, useed))


def leaves_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        and np.asarray(x).dtype == np.asarray(y).dtype
        for x, y in zip(la, lb))


# -- 1. crash recovery ------------------------------------------------------

@pytest.mark.parametrize("kill_at", [1, 7, 14, 23])
def test_kill_and_resume_bitwise(tmp_path, kill_at):
    _, params, _, _, cfg, _ = workload()
    ops = request_tape(24)

    ref = RoundServer(params, cfg, ServeConfig(buffer_size=3),
                      telemetry=Telemetry(), clock=lambda: 0.0)
    drive(ref, ops)

    sc = ServeConfig(buffer_size=3, ckpt_path=str(tmp_path / "wal"))
    killed = RoundServer(params, cfg, sc, telemetry=Telemetry(),
                         clock=lambda: 0.0)
    drive(killed, ops[:kill_at])
    del killed                              # the kill -9
    resumed = RoundServer.resume(params, cfg, sc, telemetry=Telemetry(),
                                 clock=lambda: 0.0)
    drive(resumed, ops[kill_at:])

    assert leaves_bitwise_equal(ref.params, resumed.params)
    assert leaves_bitwise_equal(ref.luar_state, resumed.luar_state)
    assert ref.version == resumed.version
    assert ref.status() == resumed.status()
    assert ref.metrics_text() == resumed.metrics_text()
    # byte ledgers: same versions, bitwise-same recorded prices/masks
    ma, mb = ref.mask_ledger.export_state(), resumed.mask_ledger.export_state()
    assert [v for v, _ in ma[0]] == [v for v, _ in mb[0]]
    assert all(np.array_equal(x[1], y[1]) for x, y in zip(ma[0], mb[0]))
    da, db = (ref.delta_ledger.export_state(),
              resumed.delta_ledger.export_state())
    assert [v for v, _ in da[0]] == [v for v, _ in db[0]]
    assert all(np.array_equal(x[1][0], y[1][0])
               for x, y in zip(da[0], db[0]))


def test_resume_restores_inflight_and_buffer(tmp_path):
    _, params, _, _, cfg, _ = workload()
    sc = ServeConfig(buffer_size=3, ckpt_path=str(tmp_path / "wal"))
    srv = RoundServer(params, cfg, sc, telemetry=Telemetry(),
                      clock=lambda: 0.0)
    srv.dispatch(0)
    srv.dispatch(1)
    srv.upload(1, fixed_update(srv.params, 5))   # buffered, no merge yet
    del srv
    res = RoundServer.resume(params, cfg, sc, telemetry=Telemetry(),
                             clock=lambda: 0.0)
    assert set(res.jobs) == {0} and len(res.buffer) == 1
    out = res.upload(0, fixed_update(res.params, 6))
    assert out["status"] == "accepted" and out["buffer_fill"] == 2


def test_resume_refuses_config_drift(tmp_path):
    _, params, _, _, cfg, _ = workload()
    sc = ServeConfig(buffer_size=3, ckpt_path=str(tmp_path / "wal"))
    RoundServer(params, cfg, sc, telemetry=Telemetry()).checkpoint()
    with pytest.raises(ValueError, match="differently configured"):
        RoundServer.resume(params, cfg,
                           ServeConfig(buffer_size=2,
                                       ckpt_path=sc.ckpt_path),
                           telemetry=Telemetry())


@pytest.mark.parametrize("drift", [dict(staleness_alpha=0.9),
                                   dict(ledger_capacity=8)])
def test_resume_refuses_merge_semantics_drift(tmp_path, drift):
    # regression for the repro.analyze ckpt-coverage finding: these two
    # fields used to be missing from the fingerprint, so a resume under
    # a different staleness discount (or a shrunken ledger ring) was
    # silently accepted and diverged instead of being refused
    _, params, _, _, cfg, _ = workload()
    sc = ServeConfig(buffer_size=3, ckpt_path=str(tmp_path / "wal"))
    RoundServer(params, cfg, sc, telemetry=Telemetry()).checkpoint()
    drifted = ServeConfig(buffer_size=3, ckpt_path=sc.ckpt_path, **drift)
    with pytest.raises(ValueError, match="differently configured"):
        RoundServer.resume(params, cfg, drifted, telemetry=Telemetry())


def test_resume_accepts_operational_knob_drift(tmp_path):
    # relocating the service (host/port) or re-pacing its WAL cadence
    # must NOT refuse a resume — only trajectory-changing fields are
    # fingerprinted
    _, params, _, _, cfg, _ = workload()
    sc = ServeConfig(buffer_size=3, ckpt_path=str(tmp_path / "wal"))
    RoundServer(params, cfg, sc, telemetry=Telemetry()).checkpoint()
    moved = ServeConfig(buffer_size=3, ckpt_path=sc.ckpt_path,
                        ckpt_every=5, host="0.0.0.0", port=8125)
    RoundServer.resume(params, cfg, moved, telemetry=Telemetry())


# -- 2. eviction across restart --------------------------------------------

def eviction_scenario(params, cfg, sc, kill_resume, tmp_path=None):
    srv = RoundServer(params, cfg, sc, telemetry=Telemetry(),
                      clock=lambda: 0.0)
    srv.dispatch(0)                    # mask recorded at version 0
    rounds = sc.ledger_capacity + 2    # enough merges to evict version 0

    def one_round(s):
        for c in (1, 2, 3):
            s.dispatch(c)
            s.upload(c, fixed_update(s.params, 100 + s.version * 10 + c))

    for _ in range(rounds // 2):
        one_round(srv)
    if kill_resume:
        del srv
        srv = RoundServer.resume(params, cfg, sc, telemetry=Telemetry(),
                                 clock=lambda: 0.0)
    for _ in range(rounds - rounds // 2):
        one_round(srv)
    out = srv.upload(0, fixed_update(srv.params, 999))
    return srv, out


def test_ledger_eviction_across_restart(tmp_path):
    _, params, _, _, cfg, _ = workload()
    mk = lambda name: ServeConfig(buffer_size=3, ledger_capacity=4,
                                  ckpt_path=str(tmp_path / name))
    ref, out_ref = eviction_scenario(params, cfg, mk("a"), kill_resume=False)
    res, out_res = eviction_scenario(params, cfg, mk("b"), kill_resume=True)
    assert out_ref["status"] == "rejected"
    assert out_ref["reason"] == "ledger_miss"
    assert out_res == out_ref
    assert ref.status() == res.status()
    assert ref.status()["ledger"]["evictions_mask"] > 0
    assert ref.metrics_text() == res.metrics_text()
    assert leaves_bitwise_equal(ref.params, res.params)


# -- 3. golden endpoints ----------------------------------------------------

def http_fixture_run():
    """3 clients x 2 rounds over the real wire with a zero clock."""
    loss_fn, params, data, parts, cfg, _ = workload(3)
    rs = RoundServer(params, cfg, ServeConfig(buffer_size=3),
                     telemetry=Telemetry(), clock=lambda: 0.0)
    httpd = serve_http.start(rs)
    try:
        clients = make_clients(3, httpd.url, loss_fn, params, data, parts,
                               cfg, seed=0)
        for _ in range(2):
            for cl in clients:
                assert cl.run_round()["status"] == "accepted"
        status = json.loads(urllib.request.urlopen(
            httpd.url + "/v1/status", timeout=30).read())
        resp = urllib.request.urlopen(httpd.url + "/metrics", timeout=30)
        metrics = resp.read().decode()
        ctype = resp.headers["Content-Type"]
    finally:
        serve_http.stop(httpd, checkpoint=False)
    return status, metrics, ctype


def test_golden_status_and_metrics_byte_stable():
    s1, m1, ctype = http_fixture_run()
    s2, m2, _ = http_fixture_run()
    assert s1 == s2                      # byte-stable under the zero clock
    assert m1 == m2
    assert ctype == "text/plain; version=0.0.4"

    # the pinned /v1/status schema: 3 clients x 2 rounds, buffer of 3
    assert s1["schema"] == 1
    assert s1["version"] == 2 and s1["rounds_done"] == 2
    assert s1["buffer_fill"] == 0 and s1["buffer_size"] == 3
    assert s1["inflight"] == 0 and s1["clients_seen"] == 3
    assert s1["accepted"] == 6 and s1["rejected"] == 0
    assert s1["dispatches"] == 6
    assert s1["downloads_full"] + s1["downloads_delta"] == 6
    assert s1["uploaded_mb"] > 0 and s1["downloaded_mb"] > 0
    assert s1["ledger"]["mask_entries"] >= 1
    assert s1["uptime_s"] == 0.0

    assert m1.startswith("# HELP")
    for line in ("# TYPE fl_server_version gauge",
                 "fl_server_version 2",
                 "fl_server_buffer_fill 0",
                 "fl_server_inflight_dispatches 0",
                 "# TYPE fl_staleness_rounds histogram",
                 "fl_rounds_total 2",
                 "fl_updates_accepted_total 6"):
        assert line in m1, f"missing exposition line: {line}"


# -- 4. checkpoint substrate ------------------------------------------------

def test_ckpt_atomic_save_leaves_no_tmp(tmp_path):
    path = str(tmp_path / "snap")
    ckpt.save_arrays(path, {"a": np.arange(4.0)}, {"note": 1})
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    arrays, meta = ckpt.load_arrays(path)
    assert np.array_equal(arrays["a"], np.arange(4.0))
    assert meta["note"] == 1 and meta["keys"] == ["a"]


def test_ckpt_torn_snapshot_detected(tmp_path):
    path = str(tmp_path / "snap")
    ckpt.save_arrays(path, {"a": np.arange(4.0), "b": np.zeros(2)})
    np.savez(path + ".npz", a=np.arange(4.0))      # lose "b" from the npz
    with pytest.raises(ValueError, match=r"torn snapshot.*\['b'\]"):
        ckpt.load_arrays(path)


def test_ckpt_restore_names_every_offending_key(tmp_path):
    path = str(tmp_path / "m")
    like = {"w": np.zeros((2, 3)), "b": np.zeros(3), "extra": np.zeros(1)}
    ckpt.save(path, {"w": np.zeros((2, 4)), "b": np.zeros(3)})
    with pytest.raises(ValueError) as ei:
        ckpt.restore(path, like)
    msg = str(ei.value)
    assert "extra" in msg and "w" in msg
    assert "(2, 4)" in msg and "(2, 3)" in msg
    # and the happy path round-trips
    good = {"w": np.full((2, 3), 7.0), "b": np.arange(3.0)}
    ckpt.save(path, good, step=5)
    back, meta = ckpt.restore(path, {"w": np.zeros((2, 3)),
                                     "b": np.zeros(3)})
    assert np.array_equal(back["w"], good["w"]) and meta["step"] == 5


# -- 5. wire, errors, satellites --------------------------------------------

def test_http_smoke_cli():
    from repro.serve.client import main
    assert main(["--clients", "3", "--rounds", "2", "--buffer", "3"]) == 0


def test_error_mapping_over_http():
    _, params, _, _, cfg, _ = workload(3)
    rs = RoundServer(params, cfg, ServeConfig(buffer_size=3),
                     telemetry=Telemetry())
    httpd = serve_http.start(rs)
    try:
        def post(path, body):
            req = urllib.request.Request(
                httpd.url + path, data=json.dumps(body).encode(),
                headers={"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=30) as r:
                    return r.status, json.loads(r.read())
            except urllib.error.HTTPError as e:
                return e.code, json.loads(e.read())

        code, doc = post("/v1/upload", {"client": 0, "update": ""})
        assert code == 400                                # malformed payload
        code, doc = post("/v1/dispatch", {"client": 99})
        assert code == 400 and "population" in doc["error"]
        code, first = post("/v1/dispatch", {"client": 0})
        assert code == 200 and first["version"] == 0 and first["first"]
        code, doc = post("/v1/dispatch", {"client": 0})
        assert code == 409 and doc["kind"] == "ClientBusy"
        from repro.serve import wire
        upd = wire.encode_tree(fixed_update(rs.params, 3))
        code, doc = post("/v1/upload", {"client": 1, "update": upd})
        assert code == 409 and doc["kind"] == "UnknownDispatch"
        code, doc = post("/v1/upload",
                         {"client": 0, "version": 41, "update": upd})
        assert code == 409 and doc["kind"] == "VersionMismatch"
        code, doc = post("/v1/upload",
                         {"client": 0, "version": 0, "update": upd})
        assert code == 200 and doc["status"] == "accepted"
    finally:
        serve_http.stop(httpd, checkpoint=False)


def test_core_error_types():
    _, params, _, _, cfg, _ = workload(3)
    rs = RoundServer(params, cfg, ServeConfig(buffer_size=3),
                     telemetry=Telemetry())
    with pytest.raises(ServeError):
        rs.dispatch(-1)
    rs.dispatch(0)
    with pytest.raises(ClientBusy):
        rs.dispatch(0)
    with pytest.raises(UnknownDispatch):
        rs.upload(2, fixed_update(rs.params, 1))
    with pytest.raises(VersionMismatch):
        rs.upload(0, fixed_update(rs.params, 1), version=3)
    assert issubclass(ClientUnavailable, ServeError)
    assert ClientUnavailable.status == 503


def test_sync_only_codec_refused():
    _, params, _, _, cfg, _ = workload(3)
    from dataclasses import replace
    cfg = replace(cfg, codecs=("lbgm",))   # needs a synchronous view
    with pytest.raises(NotImplementedError, match="lbgm"):
        RoundServer(params, cfg, ServeConfig(), telemetry=Telemetry())


def test_metrics_state_dict_roundtrip():
    from repro.obs import prom
    reg = MetricsRegistry()
    a = reg.counter("t_total", "c").labels(kind="a")
    for _ in range(3):
        a.inc()
    reg.counter("t_total", "c").labels(kind="b").inc()
    reg.gauge("g", "g").labels().set(2.5)
    h = reg.histogram("h", "h", buckets=(1, 2, 4)).labels()
    for v in (0.5, 3, 9, 1.5):
        h.observe(v)
    doc = reg.state_dict()
    doc = json.loads(json.dumps(doc))      # survives the JSON round trip
    fresh = MetricsRegistry()
    fresh.load_state_dict(doc)
    assert prom.exposition(fresh) == prom.exposition(reg)


def test_client_link_trace():
    from repro.launch.mesh import LINK_MIX, MEASURED_LINK_BW, \
        client_link_trace
    tr = client_link_trace(100)
    assert len(tr) == 100 and tr == client_link_trace(100)
    counts = {name: sum(1 for t in tr if t[0] == name)
              for name, _ in LINK_MIX}
    assert counts == {"wan": 80, "metro": 15, "dcn": 4, "ici": 1}
    assert all((up, down) == MEASURED_LINK_BW[name]
               for name, up, down in tr)
    assert [t[0] for t in client_link_trace(1)] == ["wan"]
    assert sum(1 for t in client_link_trace(7) if t[0] == "wan") >= 5
    with pytest.raises(ValueError):
        client_link_trace(0)


def test_launch_serve_shim_deprecated():
    import importlib
    import sys
    import warnings
    sys.modules.pop("repro.launch.serve", None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mod = importlib.import_module("repro.launch.serve")
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    from repro.launch import generate
    assert mod.serve is generate.serve and mod.main is generate.main


def test_wire_roundtrip_bitwise():
    from repro.serve import wire
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray([1.5, -2.25], np.float64)}}
    b64 = wire.encode_tree(tree)
    back = wire.decode_tree(b64, tree)
    assert leaves_bitwise_equal(tree, back)


def test_serve_client_pacing_sleeps(monkeypatch):
    loss_fn, params, data, parts, cfg, _ = workload(3)
    rs = RoundServer(params, cfg, ServeConfig(buffer_size=3),
                     telemetry=Telemetry())
    slept = []
    import repro.serve.client as client_mod
    monkeypatch.setattr(client_mod.time, "sleep",
                        lambda s: slept.append(s))
    cl = ServeClient(0, rs, loss_fn, params, data, parts[0], cfg,
                     pace=1.0, link=("wan", 1.0e7, 4.1e7), seed=0)
    out = cl.run_round()
    assert out["status"] == "accepted"
    assert len(slept) == 1 and slept[0] > 0
    # WAN uplink at 10 MB/s dominates: the dwell is the byte time
    assert slept[0] == pytest.approx(
        out["down_bytes"] / 4.1e7 + cl._up_bytes / 1.0e7)
