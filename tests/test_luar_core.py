"""Unit + property tests for the paper's core: Eq. (1)/(2), Alg. 1,
selection schemes, communication/memory accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LuarConfig, build_units, comm_init, comm_update,
                        comm_ratio, gumbel_topk_mask, luar_init, luar_round,
                        masked_upload_bytes, recycle_probs, s_metric,
                        select_recycle_set, server_memory_bytes,
                        unit_sq_norms)
from repro.models.cnn import cnn_init, mlp_init


@pytest.fixture(scope="module")
def cnn_params():
    return cnn_init(jax.random.PRNGKey(0))


def _const_update(params, val=0.01):
    return jax.tree.map(lambda a: val * jnp.ones_like(a), params)


# ---------------------------------------------------------------------------
# units
# ---------------------------------------------------------------------------


def test_module_units_match_paper_cnn(cnn_params):
    um = build_units(cnn_params, "module")
    assert um.names == ("conv1", "conv2", "fc1", "fc2")  # 4 layers, Table 11


def test_leaf_units(cnn_params):
    um = build_units(cnn_params, "leaf")
    assert len(um.names) == 8  # w+b per layer


def test_unit_bytes(cnn_params):
    um = build_units(cnn_params, "module")
    total = sum(um.unit_bytes)
    expect = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cnn_params))
    assert total == expect


def test_unit_sq_norms_matches_manual(cnn_params):
    um = build_units(cnn_params, "module")
    norms = unit_sq_norms(um, cnn_params)
    manual = sum(float(jnp.sum(v["w"] ** 2) + jnp.sum(v["b"] ** 2))
                 for v in [cnn_params["conv1"]])
    assert np.isclose(float(norms[0]), manual, rtol=1e-5)


# ---------------------------------------------------------------------------
# Eq. (1) / (2)
# ---------------------------------------------------------------------------


def test_s_metric_definition(cnn_params):
    um = build_units(cnn_params, "module")
    upd = _const_update(cnn_params, 0.1)
    s = s_metric(um, upd, cnn_params)
    d2 = unit_sq_norms(um, upd)
    x2 = unit_sq_norms(um, cnn_params)
    np.testing.assert_allclose(np.asarray(s),
                               np.sqrt(np.asarray(d2)) / np.sqrt(np.asarray(x2)),
                               rtol=1e-4)


@given(st.lists(st.floats(min_value=1e-6, max_value=1e3), min_size=2, max_size=64))
@settings(deadline=None, max_examples=50)
def test_recycle_probs_is_distribution(svals):
    p = recycle_probs(jnp.asarray(svals, jnp.float32))
    assert np.all(np.asarray(p) >= 0)
    assert np.isclose(float(jnp.sum(p)), 1.0, atol=1e-5)


def test_recycle_probs_inverse_ordering():
    s = jnp.asarray([0.1, 1.0, 10.0])
    p = recycle_probs(s)
    assert p[0] > p[1] > p[2]  # small s (stable layer) -> likelier recycled


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=32), st.integers(min_value=0, max_value=32),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=60)
def test_gumbel_topk_exactly_k(n, k, seed):
    k = min(k, n)
    logp = jnp.zeros((n,))
    mask = gumbel_topk_mask(jax.random.PRNGKey(seed), logp, k)
    assert int(jnp.sum(mask)) == k


def test_gumbel_topk_respects_weights():
    # a unit with overwhelming probability is (almost) always selected
    logp = jnp.log(jnp.asarray([0.97, 0.01, 0.01, 0.01]))
    hits = 0
    for i in range(50):
        mask = gumbel_topk_mask(jax.random.PRNGKey(i), logp, 1)
        hits += int(mask[0])
    assert hits >= 40


@pytest.mark.parametrize("scheme", ["luar", "random", "grad_norm", "top",
                                    "bottom", "deterministic"])
def test_selection_schemes_count(scheme):
    s = jnp.asarray([0.1, 0.5, 0.01, 2.0, 0.3])
    gsq = jnp.asarray([1.0, 2.0, 0.5, 3.0, 0.1])
    mask = select_recycle_set(jax.random.PRNGKey(0), scheme, 2, s=s, grad_sq=gsq)
    assert int(jnp.sum(mask)) == 2


def test_top_bottom_deterministic_positions():
    s = jnp.arange(1, 6, dtype=jnp.float32)
    g = jnp.ones((5,))
    top = select_recycle_set(jax.random.PRNGKey(0), "top", 2, s=s, grad_sq=g)
    bot = select_recycle_set(jax.random.PRNGKey(0), "bottom", 2, s=s, grad_sq=g)
    det = select_recycle_set(jax.random.PRNGKey(0), "deterministic", 2, s=s, grad_sq=g)
    assert list(np.asarray(top)) == [True, True, False, False, False]
    assert list(np.asarray(bot)) == [False, False, False, True, True]
    assert list(np.asarray(det)) == [True, True, False, False, False]  # smallest s


# ---------------------------------------------------------------------------
# Alg. 1 round semantics
# ---------------------------------------------------------------------------


def test_delta0_is_fedavg(cnn_params):
    cfg = LuarConfig(delta=0, granularity="module")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(1))
    fresh = _const_update(cnn_params)
    applied, state = luar_round(state, um, cfg, fresh, cnn_params)
    for a, f in zip(jax.tree.leaves(applied), jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(f))
    assert not bool(jnp.any(state.mask))


def test_round0_mask_empty_then_recycles(cnn_params):
    cfg = LuarConfig(delta=2, granularity="module")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(1))
    assert not bool(jnp.any(state.mask))          # R_0 = empty (Alg. 2)
    fresh = _const_update(cnn_params)
    applied1, state = luar_round(state, um, cfg, fresh, cnn_params)
    assert int(jnp.sum(state.mask)) == 2          # R_1 sampled
    fresh2 = _const_update(cnn_params, 0.5)
    applied2, state2 = luar_round(state, um, cfg, fresh2, cnn_params)
    # masked units must carry round-1's update; unmasked carry fresh2
    mask = np.asarray(state.mask)
    l1 = jax.tree.leaves(applied1)
    l2 = jax.tree.leaves(applied2)
    lf = jax.tree.leaves(fresh2)
    for u, a1, a2, f2 in zip(um.leaf_unit, l1, l2, lf):
        if mask[u]:
            np.testing.assert_array_equal(np.asarray(a2), np.asarray(a1))
        else:
            np.testing.assert_array_equal(np.asarray(a2), np.asarray(f2))


def test_drop_mode_zeroes(cnn_params):
    cfg = LuarConfig(delta=2, granularity="module", mode="drop")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(1))
    fresh = _const_update(cnn_params)
    _, state = luar_round(state, um, cfg, fresh, cnn_params)
    applied, _ = luar_round(state, um, cfg, fresh, cnn_params)
    mask = np.asarray(state.mask)
    for u, a in zip(um.leaf_unit, jax.tree.leaves(applied)):
        if mask[u]:
            assert float(jnp.max(jnp.abs(a))) == 0.0


def test_staleness_and_agg_count_bookkeeping(cnn_params):
    cfg = LuarConfig(delta=1, granularity="module")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(3))
    fresh = _const_update(cnn_params)
    T = 10
    for _ in range(T):
        _, state = luar_round(state, um, cfg, fresh, cnn_params)
    agg = np.asarray(state.agg_count)
    # every round, exactly n_units - delta units aggregate (round 0: all)
    assert agg.sum() == (len(um.names) - 1) * (T - 1) + len(um.names)
    assert int(state.round) == T


# ---------------------------------------------------------------------------
# comm / memory accounting
# ---------------------------------------------------------------------------


def test_comm_monotone_in_delta(cnn_params):
    um = build_units(cnn_params, "module")
    sizes = np.asarray(um.unit_bytes, np.float64)
    full = masked_upload_bytes(um, jnp.zeros(4, bool)) * 32
    assert full == sizes.sum() * 32
    mask = jnp.asarray([True, False, False, False])
    assert masked_upload_bytes(um, mask) * 32 == (sizes.sum() - sizes[0]) * 32


def test_comm_ratio_accumulates(cnn_params):
    um = build_units(cnn_params, "module")
    stats = comm_init()
    mask = jnp.asarray([True, True, False, False])
    for _ in range(4):
        stats = comm_update(stats, um, mask, 8)
    sizes = np.asarray(um.unit_bytes, np.float64)
    expect = sizes[2:].sum() / sizes.sum()
    assert np.isclose(comm_ratio(stats, um, 8), expect, rtol=1e-6)


def test_server_memory_model(cnn_params):
    """Table 1: a*(d-k)+k < a*d whenever k > 0."""
    um = build_units(cnn_params, "module")
    m = server_memory_bytes(um, delta_bytes=um.unit_bytes[2], n_active=32)
    assert m["fedluar"] < m["fedavg"]
    d = sum(um.unit_bytes)
    assert m["fedavg"] == 32 * d
    assert m["fedluar"] == 32 * (d - um.unit_bytes[2]) + um.unit_bytes[2]


# ---------------------------------------------------------------------------
# kappa < 1/16 diagnostic (Theorem 2's condition is checkable)
# ---------------------------------------------------------------------------


def test_kappa_estimate():
    """kappa = ||grad restricted to R||^2 / ||grad||^2 <= 1 and == fraction
    for uniform gradients."""
    params = mlp_init(jax.random.PRNGKey(0))
    um = build_units(params, "module")
    g = jax.tree.map(jnp.ones_like, params)
    gsq = unit_sq_norms(um, g)
    mask = jnp.asarray([True, False, False])
    kappa = float(jnp.sum(jnp.where(mask, gsq, 0.0)) / jnp.sum(gsq))
    assert 0.0 < kappa < 1.0


def test_max_staleness_bound(cnn_params):
    """Beyond-paper: with max_staleness=K, no unit is ever recycled more
    than K consecutive rounds (worst-case Lemma-1 k bound)."""
    cfg = LuarConfig(delta=3, granularity="module", scheme="deterministic",
                     max_staleness=2)
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(5))
    fresh = _const_update(cnn_params)
    max_seen = 0
    for _ in range(20):
        _, state = luar_round(state, um, cfg, fresh, cnn_params)
        max_seen = max(max_seen, int(jnp.max(state.staleness)))
    assert max_seen <= 2


def test_max_staleness_off_allows_unbounded(cnn_params):
    cfg = LuarConfig(delta=3, granularity="module", scheme="deterministic")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(5))
    fresh = _const_update(cnn_params)
    for _ in range(10):
        _, state = luar_round(state, um, cfg, fresh, cnn_params)
    assert int(jnp.max(state.staleness)) > 2  # deterministic keeps recycling


# ---------------------------------------------------------------------------
# high-level API + fused kernel path
# ---------------------------------------------------------------------------


def test_fedluar_api_matches_functional(cnn_params):
    from repro.core import FedLUAR
    api = FedLUAR(cnn_params, delta=2, granularity="module", seed=1,
                  n_active=8)
    cfg = LuarConfig(delta=2, granularity="module")
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(1))
    fresh = _const_update(cnn_params)
    for _ in range(4):
        a1 = api.aggregate(fresh, cnn_params)
        a2, state = luar_round(state, um, cfg, fresh, cnn_params)
        for x, y in zip(jax.tree.leaves(a1), jax.tree.leaves(a2)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    d = api.diagnostics()
    assert d["round"] == 4 and 0 < d["comm_ratio"] <= 1.0
    assert len(api.recycled_unit_names) == 2


def test_fedluar_kernel_path_matches(cnn_params):
    """The fused Pallas server op (interpret mode) reproduces the jnp
    aggregation bit-for-bit on the applied update and matches s."""
    from repro.core import FedLUAR
    fresh = _const_update(cnn_params, 0.05)
    a = FedLUAR(cnn_params, delta=2, granularity="module", seed=3)
    b = FedLUAR(cnn_params, delta=2, granularity="module", seed=3,
                use_kernel=True)
    for _ in range(3):
        ua = a.aggregate(fresh, cnn_params)
        ub = b.aggregate(fresh, cnn_params)
        for x, y in zip(jax.tree.leaves(ua), jax.tree.leaves(ub)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)
        # tile-wise SMEM accumulation vs tree-sum: tiny order difference
        np.testing.assert_allclose(np.asarray(a.state.s), np.asarray(b.state.s),
                                   rtol=1e-3)
        np.testing.assert_array_equal(np.asarray(a.state.mask),
                                      np.asarray(b.state.mask))


# ---------------------------------------------------------------------------
# depth granularity (per-layer units on scanned stacks)
# ---------------------------------------------------------------------------


def test_depth_granularity_unit_count():
    import jax
    from repro.configs import get_config
    from repro.models.registry import build
    cfg = get_config("qwen3-14b", reduced=True)          # 2 scanned layers
    params = build(cfg).init(jax.random.PRNGKey(0))
    um_leaf = build_units(params, "leaf")
    um_depth = build_units(params, "depth")
    n_stacked = sum(1 for u in um_leaf.leaf_unit
                    if um_leaf.names[u].startswith("blocks"))
    assert len(um_depth.names) == len(um_leaf.names) + n_stacked * (cfg.n_layers - 1)
    assert f"blocks.attn.wq[0]" in um_depth.names
    assert sum(um_depth.unit_bytes) == sum(um_leaf.unit_bytes)


def test_depth_granularity_recycles_single_layer():
    """Recycling one depth-unit leaves the other layers' slices fresh."""
    import jax
    from repro.configs import get_config
    from repro.models.registry import build
    cfg = get_config("qwen3-14b", reduced=True)
    params = build(cfg).init(jax.random.PRNGKey(0))
    um = build_units(params, "depth")
    lcfg = LuarConfig(delta=5, granularity="depth")
    state, um2 = luar_init(params, lcfg, jax.random.PRNGKey(2))
    fresh1 = _const_update(params, 0.1)
    a1, state = luar_round(state, um2, lcfg, fresh1, params)
    fresh2 = _const_update(params, 0.7)
    a2, state2 = luar_round(state, um2, lcfg, fresh2, params)
    mask = np.asarray(state.mask)
    assert mask.sum() == 5
    l1, l2, lf = (jax.tree.leaves(t) for t in (a1, a2, fresh2))
    for u, x1, x2, f2 in zip(um2.leaf_unit, l1, l2, lf):
        if isinstance(u, tuple):
            start, L = u
            for i in range(L):
                want = np.asarray(x1)[i] if mask[start + i] else np.asarray(f2)[i]
                np.testing.assert_array_equal(np.asarray(x2)[i], want)
        else:
            want = np.asarray(x1) if mask[u] else np.asarray(f2)
            np.testing.assert_array_equal(np.asarray(x2), want)


def test_depth_norms_match_slicewise():
    import jax
    from repro.configs import get_config
    from repro.models.registry import build
    cfg = get_config("mamba2-780m", reduced=True)
    params = build(cfg).init(jax.random.PRNGKey(0))
    um = build_units(params, "depth")
    norms = np.asarray(unit_sq_norms(um, params))
    # pick one stacked unit and verify against a manual slice norm
    idx = um.names.index("blocks.in_proj[1]")
    manual = float(jnp.sum(jnp.square(params["blocks"]["in_proj"][1].astype(jnp.float32))))
    assert np.isclose(norms[idx], manual, rtol=1e-5)


# ---------------------------------------------------------------------------
# exhaustive scheme x granularity x mode sweep (cheap invariants)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("granularity", ["leaf", "module", "depth"])
@pytest.mark.parametrize("scheme", ["luar", "random", "deterministic"])
@pytest.mark.parametrize("mode", ["recycle", "drop"])
def test_round_invariants_all_combos(cnn_params, granularity, scheme, mode):
    """For every combo: mask has exactly delta bits, applied matches the
    pytree structure, comm accounting stays within [0, full]."""
    cfg = LuarConfig(delta=2, scheme=scheme, mode=mode, granularity=granularity)
    state, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(7))
    fresh = _const_update(cnn_params)
    for _ in range(3):
        applied, state = luar_round(state, um, cfg, fresh, cnn_params)
    assert int(jnp.sum(state.mask)) == 2
    assert jax.tree.structure(applied) == jax.tree.structure(cnn_params)
    full = masked_upload_bytes(um, jnp.zeros(len(um.names), bool))
    up = masked_upload_bytes(um, state.mask)
    assert 0.0 <= up <= full
    assert bool(jnp.all(jnp.isfinite(state.s)))


@given(st.integers(2, 40), st.integers(0, 40))
@settings(deadline=None, max_examples=30)
def test_upload_bytes_linearity(n, k):
    """Property: upload bytes = total - sum of masked unit sizes."""
    k = min(k, n)
    sizes = tuple(int(x) for x in np.random.default_rng(n).integers(1, 1000, n))
    um = UnitMapStub(sizes)
    mask = jnp.zeros((n,), bool).at[:k].set(True)
    got = masked_upload_bytes(um, mask) * 3
    want = (sum(sizes) - sum(sizes[:k])) * 3
    assert got == want


class UnitMapStub:
    def __init__(self, sizes):
        self.unit_bytes = sizes


# ---------------------------------------------------------------------------
# Eq. (1) denominator guard (zero-norm units must not poison selection)
# ---------------------------------------------------------------------------


def test_s_metric_zero_norm_unit_is_finite_neutral():
    """The pinned convention: a unit whose update AND params are all-zero
    (zero-init bias, fully-pruned layer) scores s == 1.0 exactly — the
    shared eps makes 0/0 a neutral 'no signal', not inf/NaN."""
    params = {"a": {"w": jnp.ones((4, 4))}, "z": {"b": jnp.zeros((8,))}}
    um = build_units(params, "module")
    upd = jax.tree.map(jnp.zeros_like, params)
    s = s_metric(um, upd, params)
    assert bool(jnp.all(jnp.isfinite(s)))
    zi = um.names.index("z")
    assert float(s[zi]) == 1.0
    p = recycle_probs(s)
    assert bool(jnp.all(jnp.isfinite(p)))
    assert np.isclose(float(jnp.sum(p)), 1.0, atol=1e-6)


def test_s_metric_nan_and_inf_updates_stay_finite():
    """A NaN or overflowed update in ONE unit must not turn every unit's
    Eq. (2) probability NaN through the normalizer."""
    from repro.core.metric import _S_MAX
    params = {"a": {"w": jnp.ones((4,))}, "b": {"w": jnp.ones((4,))},
              "c": {"w": jnp.ones((4,))}}
    um = build_units(params, "module")
    upd = {"a": {"w": jnp.full((4,), jnp.nan)},
           "b": {"w": jnp.full((4,), 1e30)},     # norm overflows f32 -> inf
           "c": {"w": jnp.full((4,), 0.5)}}
    s = s_metric(um, upd, params)
    assert bool(jnp.all(jnp.isfinite(s)))
    assert float(s[um.names.index("a")]) == 1.0          # NaN -> neutral
    assert float(s[um.names.index("b")]) == float(np.float32(_S_MAX))  # capped
    p = recycle_probs(s)
    assert bool(jnp.all(jnp.isfinite(p)))
    # the diverged unit is effectively never recycled; the NaN unit takes
    # only its neutral (s=1) share, and the healthy unit the rest
    assert float(p[um.names.index("b")]) < 1e-6
    assert np.isclose(float(p[um.names.index("a")]), 1 / 3, atol=1e-5)
    assert np.isclose(float(p[um.names.index("c")]), 2 / 3, atol=1e-5)


def test_selection_under_zero_init_layer():
    """Regression: rounds with a zero-init layer keep sampling valid
    delta-sized recycle sets (probabilities never NaN)."""
    params = {"conv": {"w": jnp.ones((5, 5))},
              "zero": {"w": jnp.zeros((7,))},    # zero-init layer
              "fc": {"w": jnp.ones((3, 3))}}
    cfg = LuarConfig(delta=1, granularity="module")
    state, um = luar_init(params, cfg, jax.random.PRNGKey(11))
    fresh = jax.tree.map(jnp.zeros_like, params)   # zero update too: 0/0
    for _ in range(5):
        _, state = luar_round(state, um, cfg, fresh, params)
        assert bool(jnp.all(jnp.isfinite(state.s)))
        assert int(jnp.sum(state.mask)) == 1


def test_s_metric_guard_is_identity_on_finite_values(cnn_params):
    """Bitwise: the non-finite guard must not perturb any healthy value
    (this is what keeps fingerprint-pinned trajectories intact)."""
    um = build_units(cnn_params, "module")
    upd = _const_update(cnn_params, 0.03)
    d2 = unit_sq_norms(um, upd)
    x2 = unit_sq_norms(um, cnn_params)
    raw = jnp.sqrt(d2 + 1e-12) / jnp.sqrt(x2 + 1e-12)
    np.testing.assert_array_equal(np.asarray(s_metric(um, upd, cnn_params)),
                                  np.asarray(raw))


# ---------------------------------------------------------------------------
# fused_agg: the batched-kernel round vs the per-leaf reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("granularity", ["leaf", "module"])
@pytest.mark.parametrize("mode", ["recycle", "drop"])
def test_fused_luar_round_matches_reference(cnn_params, granularity, mode):
    """cfg.fused_agg=True reproduces the reference round: applied update
    within kernel tolerance, s within accumulation-order tolerance, and
    the SAME sampled recycle sets over several rounds."""
    cfg = LuarConfig(delta=2, granularity=granularity, mode=mode)
    fcfg = cfg._replace(fused_agg=True)
    state_r, um = luar_init(cnn_params, cfg, jax.random.PRNGKey(5))
    state_f, _ = luar_init(cnn_params, fcfg, jax.random.PRNGKey(5))
    fresh = _const_update(cnn_params, 0.05)
    for _ in range(3):
        ar, state_r = luar_round(state_r, um, cfg, fresh, cnn_params)
        af, state_f = luar_round(state_f, um, fcfg, fresh, cnn_params)
        for x, y in zip(jax.tree.leaves(ar), jax.tree.leaves(af)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)
        np.testing.assert_allclose(np.asarray(state_r.s),
                                   np.asarray(state_f.s), rtol=1e-3)
        np.testing.assert_array_equal(np.asarray(state_r.mask),
                                      np.asarray(state_f.mask))


def test_fused_luar_round_depth_granularity():
    """The batched kernel handles stacked (start, L) depth units the
    per-leaf ops.luar_agg path never could."""
    params = {"blocks": {"w": jnp.arange(24.0).reshape(3, 2, 4) / 24.0,
                         "b": jnp.ones((3, 4)) * 0.1},
              "head": {"w": jnp.ones((4, 2))}}
    cfg = LuarConfig(delta=2, granularity="depth")
    fcfg = cfg._replace(fused_agg=True)
    state_r, um = luar_init(params, cfg, jax.random.PRNGKey(9))
    state_f, _ = luar_init(params, fcfg, jax.random.PRNGKey(9))
    fresh = _const_update(params, 0.2)
    for _ in range(3):
        ar, state_r = luar_round(state_r, um, cfg, fresh, params)
        af, state_f = luar_round(state_f, um, fcfg, fresh, params)
        for x, y in zip(jax.tree.leaves(ar), jax.tree.leaves(af)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)
        np.testing.assert_array_equal(np.asarray(state_r.mask),
                                      np.asarray(state_f.mask))
