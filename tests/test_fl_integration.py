"""End-to-end FL integration: Alg. 2 on synthetic non-IID data.
Validates the paper's qualitative claims at test scale: LUAR keeps
accuracy at a fraction of FedAvg's communication; recycling beats
dropping; advanced server optimizers compose with LUAR."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.fl.server import ServerConfig
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(3000, n_classes=10, d=32, seed=0)
    xt, yt = gaussian_mixture(800, n_classes=10, d=32, seed=1)
    parts = dirichlet_partition(y, 24, alpha=0.1, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xt), -1) == yt))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _run(task, rounds=25, **kw):
    client = kw.pop("client", ClientConfig(lr=0.05))
    cfg = FLConfig(n_clients=24, n_active=8, tau=5, batch_size=16,
                   rounds=rounds, client=client, eval_every=rounds, **kw)
    return run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
                  cfg, task["eval_fn"])


def test_fedavg_converges(task):
    res = _run(task)
    assert res.history[-1]["acc"] > 0.9
    assert np.isclose(res.comm_ratio, 1.0)


def test_luar_keeps_accuracy_cuts_comm(task):
    res = _run(task, luar=LuarConfig(delta=2, granularity="leaf"))
    assert res.history[-1]["acc"] > 0.9
    assert res.comm_ratio < 0.85


def test_recycle_beats_drop(task):
    """Table 5 directionally: same comm, recycling >= dropping."""
    rec = _run(task, rounds=30, luar=LuarConfig(delta=3, granularity="leaf"))
    drp = _run(task, rounds=30, luar=LuarConfig(delta=3, granularity="leaf",
                                                mode="drop"))
    assert rec.history[-1]["acc"] >= drp.history[-1]["acc"] - 0.02


def test_luar_with_fedopt(task):
    # server-Adam renormalises the recycled update each round, so FedOpt
    # wants a smaller server lr under recycling; the staleness bound keeps
    # any single unit from compounding (DESIGN.md §Beyond-paper)
    res = _run(task, luar=LuarConfig(delta=2, granularity="leaf",
                                     max_staleness=4),
               server=ServerConfig(kind="fedopt", lr=0.2))
    assert res.history[-1]["acc"] > 0.85


def test_luar_with_fedacg(task):
    res = _run(task, luar=LuarConfig(delta=2, granularity="leaf"),
               server=ServerConfig(kind="fedacg", acg_lambda=0.5))
    assert res.history[-1]["acc"] > 0.85


def test_luar_with_fedprox(task):
    res = _run(task, luar=LuarConfig(delta=2, granularity="leaf"),
               client=ClientConfig(lr=0.05, prox_mu=0.001))
    assert res.history[-1]["acc"] > 0.85


def test_luar_with_fedpaq(task):
    """LUAR composes with quantization (Table 3: FedPAQ+LUAR)."""
    res = _run(task, luar=LuarConfig(delta=2, granularity="leaf"), fedpaq_bits=8)
    assert res.history[-1]["acc"] > 0.85
    assert res.comm_ratio < 0.25   # 8/32 quantization x recycling


def test_agg_counts_sum(task):
    res = _run(task, rounds=10, luar=LuarConfig(delta=2, granularity="leaf"))
    # 6 leaf units; round 0 aggregates all, rounds 1..9 aggregate 4 each
    assert res.agg_count.sum() == 6 + 9 * 4
