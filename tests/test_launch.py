"""Launch-layer tests: sharding rules (property-based), HLO analyzer
(against a known toy program), step construction."""
from collections import namedtuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.core.recycle import LuarConfig
from repro.launch import hlo
from repro.launch.sharding import param_spec, layout
from repro.launch.steps import make_fedluar_train_step, train_state_shapes
from repro.models.registry import build

FakeDevices = namedtuple("FakeDevices", ["shape"])


class FakeMesh:
    def __init__(self, shape, axes):
        self.devices = FakeDevices(shape)
        self.axis_names = axes


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
CFG = get_config("qwen3-14b")


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4))
@settings(deadline=None, max_examples=100)
def test_param_spec_never_shards_nondivisible(dims):
    """Property: every sharded dim divides its axis-size product."""
    for mesh in (MESH1, MESH2):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for strategy in ("fsdp_sp", "naive_tp"):
            spec = param_spec("blocks.attn.wq", tuple(dims), mesh, CFG, strategy)
            for dim, s in zip(dims, spec):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0 and dim >= prod


def test_param_spec_1d_replicated():
    assert param_spec("final_norm", (5120,), MESH1, CFG) == P()


def test_param_spec_expert_parallel():
    spec = param_spec("blocks.moe.w_gate", (26, 64, 2048, 1408), MESH1,
                      get_config("deepseek-v2-lite-16b"))
    assert spec[1] == "model"          # 64 experts over 16-way EP


def test_param_spec_mixtral_tp_fallback():
    spec = param_spec("blocks.moe.w_gate", (32, 8, 4096, 14336), MESH1,
                      get_config("mixtral-8x7b"))
    assert spec[1] is None             # 8 experts cannot shard 16 ways


def test_naive_tp_shards_last_dim():
    spec = param_spec("blocks.attn.wk", (40, 5120, 1024), MESH1, CFG, "naive_tp")
    assert spec[-1] == "model"         # the head_dim-splitting trap


def test_layout_pure_dp_when_batch_divides():
    baxes, seq = layout(CFG, SHAPES["train_4k"], MESH1)   # B=256 == 16*16
    assert "model" in baxes and seq is None


def test_layout_sp_when_batch_small():
    baxes, seq = layout(CFG, SHAPES["prefill_32k"], MESH1)  # B=32
    assert baxes == ("data",) and seq == "model"


def test_layout_ssm_never_seq_shards():
    cfg = get_config("mamba2-780m")
    _, seq = layout(cfg, SHAPES["prefill_32k"], MESH1)
    assert seq is None


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------


def test_hlo_analyzer_multiplies_loop_trip_counts():
    """A scan of L matmuls must report ~L x the flops of one matmul."""
    L, n = 12, 64

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    lowered = jax.jit(jax.grad(f)).lower(
        jax.ShapeDtypeStruct((L, n, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32))
    text = lowered.compile().as_text()
    a = hlo.analyze(text)
    one_matmul = 2 * n * n * n
    # fwd + bwd(2 matmuls) per layer = 3 matmuls/layer minimum
    assert a["flops"] >= 3 * L * one_matmul * 0.9
    assert a["flops"] <= 6 * L * one_matmul  # not wildly over


def test_hlo_shape_parsing():
    shapes = hlo._shape_list_bytes("f32[16,256]{1,0} bf16[8]")
    assert hlo._bytes_of(shapes[0]) == 16 * 256 * 4
    assert hlo._bytes_of(shapes[1]) == 8 * 2


def test_hlo_roofline_bottleneck():
    r = hlo.roofline({"flops": 1e15, "hbm_bytes": 1e9, "collective_bytes": 1e9})
    assert r["bottleneck"] == "compute_s"
    r = hlo.roofline({"flops": 1e9, "hbm_bytes": 1e9, "collective_bytes": 1e12})
    assert r["bottleneck"] == "collective_s"


# ---------------------------------------------------------------------------
# FedLUAR train step (single device semantics)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_step():
    cfg = get_config("qwen3-14b", reduced=True)
    model = build(cfg)
    state_shapes, um = train_state_shapes(model)
    return cfg, model, um


def test_train_state_shapes_no_allocation(tiny_step):
    cfg, model, um = tiny_step
    state_shapes, _ = train_state_shapes(model)
    for leaf in jax.tree.leaves(state_shapes):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert len(um.names) > 5


def test_fedluar_step_dynamic_runs(tiny_step):
    cfg, model, um = tiny_step
    from repro.launch.steps import TrainState
    from repro.core.recycle import luar_init
    params = model.init(jax.random.PRNGKey(0))
    luar_state, _ = luar_init(params, LuarConfig(delta=3), jax.random.PRNGKey(1))
    momentum = jax.tree.map(jnp.zeros_like, params)
    state = TrainState(params, momentum, luar_state)
    step = make_fedluar_train_step(model, LuarConfig(delta=3), um, lr=1e-2)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    new_state, loss = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(loss))
    assert int(jnp.sum(new_state.luar.mask)) == 3


def test_fedluar_step_static_freezes_masked_units(tiny_step):
    cfg, model, um = tiny_step
    from repro.launch.steps import TrainState
    from repro.core.recycle import luar_init
    params = model.init(jax.random.PRNGKey(0))
    luar_state, _ = luar_init(params, LuarConfig(delta=0), jax.random.PRNGKey(1))
    momentum = jax.tree.map(jnp.zeros_like, params)
    state = TrainState(params, momentum, luar_state)
    mask = tuple(i < 2 for i in range(len(um.names)))   # first two units recycled
    step = make_fedluar_train_step(model, LuarConfig(delta=2), um,
                                   lr=1e-2, static_mask=mask)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    new_state, loss = jax.jit(step)(state, batch)
    # recycled units: prev_update was zeros -> params unchanged
    leaves_old = jax.tree.leaves(params)
    leaves_new = jax.tree.leaves(new_state.params)
    changed = [not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves_old, leaves_new)]
    for u, ch in zip(um.leaf_unit, changed):
        if mask[u]:
            assert not ch, f"masked unit {um.names[u]} moved"


def test_generate_prompts_use_split_key_not_init_key(monkeypatch):
    """Regression (found by repro.analyze rng-discipline): ``serve`` used
    to draw the prompt batch from the SAME key that initialised the
    model, correlating data with weights.  Pin the fix: the key handed
    to ``randint`` is the split-off half, never the raw seed key."""
    from repro.launch import generate

    seen = []
    real_randint = jax.random.randint

    def spy(key, *a, **k):
        seen.append(np.asarray(key).copy())
        return real_randint(key, *a, **k)

    monkeypatch.setattr(jax.random, "randint", spy)
    out, _ = generate.serve("qwen3-14b", batch=2, prompt_len=8,
                            steps=2, seed=0)

    raw = np.asarray(jax.random.PRNGKey(0))
    _, prompt_key = jax.random.split(jax.random.PRNGKey(0))
    assert any(np.array_equal(k, np.asarray(prompt_key)) for k in seen)
    assert not any(np.array_equal(k, raw) for k in seen)
    assert out.shape == (2, 2)


def test_static_mask_removes_grad_work(tiny_step):
    """Beyond-paper claim: baking R_t into the executable DCEs the masked
    units' weight-gradient matmuls -> fewer HLO flops than dynamic."""
    cfg, model, um = tiny_step
    state_shapes, _ = train_state_shapes(model)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}

    def flops_of(static_mask):
        step = make_fedluar_train_step(model, LuarConfig(delta=4), um,
                                       static_mask=static_mask)
        lowered = jax.jit(step).lower(state_shapes, batch)
        return hlo.analyze(lowered.compile().as_text())["flops"]

    n = len(um.names)
    heavy = sorted(range(n), key=lambda i: -um.unit_bytes[i])[: n // 2]
    mask = tuple(i in heavy for i in range(n))
    f_dyn = flops_of(None)
    f_static = flops_of(mask)
    assert f_static < f_dyn * 0.97, (f_static, f_dyn)
