"""Substrate tests: baselines, partitioner, optimizers, checkpointing,
data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import ckpt
from repro.core import build_units
from repro.data.synthetic import (gaussian_mixture, lm_batch,
                                  synthetic_images, synthetic_tokens)
from repro.fl import baselines
from repro.fl.partition import dirichlet_partition, partition_stats
from repro.models.cnn import cnn_init, cnn_apply, mlp_init, softmax_xent


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def test_fedpaq_quantization_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    tree = {"a": x}
    for bits in (2, 4, 8):
        q = baselines.fedpaq_quantize(tree, jax.random.PRNGKey(1), bits)["a"]
        levels = 2 ** bits - 1
        step = 2 * float(jnp.max(jnp.abs(x))) / levels
        assert float(jnp.max(jnp.abs(q - x))) <= step + 1e-5
    assert baselines.fedpaq_comm_ratio(8) == 0.25


def test_fedpaq_stochastic_unbiased():
    x = {"a": jnp.full((2000,), 0.3)}
    qs = [baselines.fedpaq_quantize(x, jax.random.PRNGKey(i), 2)["a"].mean()
          for i in range(20)]
    assert abs(float(np.mean(qs)) - 0.3) < 0.02


def test_lbgm_reuses_collinear_updates():
    params = mlp_init(jax.random.PRNGKey(0))
    um = build_units(params, "module")
    state = baselines.lbgm_init(params, um)
    g = jax.tree.map(jnp.ones_like, params)
    # round 1: anchors empty -> everything sent in full
    applied, state, sent = baselines.lbgm_round(state, um, g)
    assert bool(jnp.all(sent))
    # round 2: identical direction, half magnitude -> nothing sent in full
    g2 = jax.tree.map(lambda a: 0.5 * a, g)
    applied2, state2, sent2 = baselines.lbgm_round(state, um, g2)
    assert not bool(jnp.any(sent2))
    for a, e in zip(jax.tree.leaves(applied2), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e), atol=1e-5)


def test_magnitude_prune_fraction():
    x = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
    pruned = baselines.magnitude_prune(x, 0.1)["a"]
    nz = int(jnp.sum(pruned != 0))
    assert 90 <= nz <= 110


def test_dropout_avg_expectation():
    x = {"a": jnp.ones((5000,))}
    d = baselines.dropout_avg(x, jax.random.PRNGKey(0), fdr=0.5)["a"]
    assert abs(float(d.mean()) - 1.0) < 0.05   # inverse-scaled


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------


def test_dirichlet_partition_covers_all():
    _, y = gaussian_mixture(2000, n_classes=10, d=8, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.5, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 2000
    assert len(np.unique(allidx)) == 2000


def test_dirichlet_skew_increases_with_small_alpha():
    _, y = gaussian_mixture(4000, n_classes=10, d=8, seed=0)
    s_iid = partition_stats(dirichlet_partition(y, 16, 100.0, seed=1), y)
    s_noniid = partition_stats(dirichlet_partition(y, 16, 0.1, seed=1), y)
    assert s_noniid["mean_label_entropy"] < s_iid["mean_label_entropy"]


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_sgd_momentum_closed_form():
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([1.0])}
    st_ = optim.sgd_init(p)
    p1, st_ = optim.sgd_update(p, g, st_, lr=0.1, momentum=0.9)
    p2, st_ = optim.sgd_update(p1, g, st_, lr=0.1, momentum=0.9)
    # m1 = 1; p1 = 1 - .1 ; m2 = 1.9; p2 = p1 - .19
    assert np.isclose(float(p1["w"][0]), 0.9)
    assert np.isclose(float(p2["w"][0]), 0.71)


def test_adam_step_direction():
    p = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([2.0])}
    st_ = optim.adam_init(p)
    p1, st_ = optim.adam_update(p, g, st_, lr=0.01)
    assert float(p1["w"][0]) < 0  # moves against gradient
    assert np.isclose(float(p1["w"][0]), -0.01, rtol=1e-3)  # ~lr for step 1


@given(st.integers(0, 400))
@settings(deadline=None, max_examples=20)
def test_step_decay(r):
    lr = optim.step_decay(0.2, jnp.asarray(r), (100, 150))
    expect = 0.2 * (0.1 if r >= 100 else 1.0) * (0.1 if r >= 150 else 1.0)
    assert np.isclose(float(lr), expect, rtol=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    params = cnn_init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck")
    ckpt.save(path, params, step=7, extra={"note": "test"})
    like = jax.tree.map(jnp.zeros_like, params)
    restored, meta = ckpt.restore(path, like)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data + CNN forward
# ---------------------------------------------------------------------------


def test_synthetic_images_learnable_shapes():
    x, y = synthetic_images(64, n_classes=62)
    assert x.shape == (64, 28, 28, 1)
    params = cnn_init(jax.random.PRNGKey(0))
    logits = cnn_apply(params, jnp.asarray(x))
    assert logits.shape == (64, 62)
    loss = softmax_xent(logits, jnp.asarray(y))
    assert np.isfinite(float(loss))


def test_synthetic_tokens_classes_distinguishable():
    d = synthetic_tokens(200, seq_len=32, vocab=256, n_classes=4, seed=0)
    toks, labels = d["tokens"], d["labels"]
    band = 256 // 4
    # tokens should fall in the label's band well above chance
    frac = np.mean((toks // band) == labels[:, None])
    assert frac > 0.5
    lm = lm_batch(toks)
    assert lm["tokens"].shape == (200, 31)
    np.testing.assert_array_equal(lm["labels"], toks[:, 1:])


def test_gaussian_mixture_train_test_share_task():
    xtr, ytr = gaussian_mixture(500, n_classes=5, d=16, seed=0)
    xte, yte = gaussian_mixture(500, n_classes=5, d=16, seed=9)
    # nearest-class-mean classifier trained on train labels works on test
    means = np.stack([xtr[ytr == c].mean(0) for c in range(5)])
    pred = np.argmin(((xte[:, None] - means[None]) ** 2).sum(-1), axis=1)
    assert (pred == yte).mean() > 0.9
