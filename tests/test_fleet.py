"""repro.fleet — the vectorized fleet engine vs the event-driven sim.

The load-bearing claims, in order of strength:

  * EXACT small-N equivalence: under a uniform scenario + uniform policy
    + no codecs, ``run_fleet`` reproduces ``run_sim``'s fedbuff
    dispatch/upload/merge counts, byte ledgers, comm ratios AND virtual
    finish time exactly (time-homogeneous waves redispatch every freed
    slot at the instant the sim would have).
  * accuracy matches within a documented tolerance only — the engines
    draw client batches in different orders, so the learning
    trajectories are statistically (not bitwise) the same run.
  * the vectorized cost-model / participation / profile counterparts
    match their host originals BITWISE (elementwise-identical f64).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_scenario
from repro.core import LuarConfig
from repro.core.comm import (ClientResources, ResourceArrays,
                             compute_time, compute_time_vec, download_time,
                             download_time_vec, resources_to_arrays,
                             round_trip_time, round_trip_time_vec,
                             upload_time, upload_time_vec)
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig
from repro.fleet import INELIGIBLE, make_wave_scorer, run_fleet, wave_top_k
from repro.fleet.state import FleetState
from repro.models.cnn import mlp_apply, mlp_init, softmax_xent
from repro.participate import (AvailDiurnal, EnergyBudget, make_vector_policy)
from repro.sim import SimConfig, run_sim, sample_resources
from repro.sim.profiles import sample_resource_arrays

ACC_TOL = 0.15          # |acc_fleet - acc_sim|: same statistics, not
                        # the same batch order (measured ~0.10 worst)


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    return FLConfig(n_clients=16, n_active=6, tau=3, batch_size=8, **kw)


def _both(task, cfg, sim):
    a = run_sim(task["loss_fn"], task["params"], task["data"], task["parts"],
                cfg, sim, task["eval_fn"])
    b = run_fleet(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, sim, task["eval_fn"])
    return a, b


EXACT_FIELDS = ("n_dispatched", "n_received", "n_uplinks_spent",
                "rounds_done", "n_dropped", "ledger_misses",
                "n_full_downloads", "n_inflight_end", "n_stranded_end",
                "downloaded", "comm_ratio", "down_ratio", "sim_time",
                "wasted_upload_bytes", "wasted_download_bytes")


def _assert_exact_match(s, f):
    for field in EXACT_FIELDS:
        assert getattr(s, field) == getattr(f, field), \
            f"{field}: sim={getattr(s, field)} fleet={getattr(f, field)}"
    # WHICH clients each engine picked differs (different cohort RNG);
    # the totals are the pinned ledgers
    assert int(np.sum(f.participation_count)) == \
        int(np.sum(s.participation_count)) == s.n_dispatched
    assert int(np.sum(f.dropout_count)) == int(np.sum(s.dropout_count))


# ---------------------------------------------------------------------------
# small-N equivalence vs the sim engine
# ---------------------------------------------------------------------------


def test_fleet_matches_sim_fedbuff_exact(task):
    """Uniform scenario, delta=0, K=4: every count, byte ledger, ratio
    and the virtual finish time are EXACTLY the sim's."""
    cfg = _cfg()
    sim = SimConfig(mode="fedbuff", buffer_size=4, concurrency=6)
    s, f = _both(task, cfg, sim)
    _assert_exact_match(s, f)
    assert s.rounds_done == cfg.rounds
    assert abs(s.history[-1]["acc"] - f.history[-1]["acc"]) <= ACC_TOL
    # the non-learning history columns are the ledgers', hence exact
    for hs, hf in zip(s.history, f.history):
        for k in ("round", "t_sim", "up_mb", "comm_ratio", "down_ratio"):
            assert hs[k] == hf[k], (k, hs, hf)


def test_fleet_matches_sim_fedasync_exact(task):
    """buffer_size=1 (FedAsync): merge per arrival, eta discount on."""
    cfg = _cfg()
    sim = SimConfig(mode="fedbuff", buffer_size=1, concurrency=3)
    s, f = _both(task, cfg, sim)
    _assert_exact_match(s, f)
    assert abs(s.history[-1]["acc"] - f.history[-1]["acc"]) <= ACC_TOL


def test_fleet_luar_recycling_comm_ratio(task):
    """delta=2 recycling: the learning trajectories (and so the recycle
    masks) differ between engines, so byte ledgers agree only loosely —
    but both engines must show recycling actually cutting uplink."""
    cfg = _cfg(luar=LuarConfig(delta=2))
    sim = SimConfig(mode="fedbuff", buffer_size=4, concurrency=6)
    s, f = _both(task, cfg, sim)
    assert s.n_dispatched == f.n_dispatched
    assert s.n_received == f.n_received
    assert 0.0 < f.comm_ratio < 1.0 and 0.0 < s.comm_ratio < 1.0
    assert abs(f.comm_ratio - s.comm_ratio) < 0.25


def test_fleet_truncated_run_accounting_exact(task):
    """max_sim_time cutoff: stranded-buffer and in-flight waste charges
    match the sim's exactly (uniform + delta=0 keeps ledgers aligned)."""
    cfg = _cfg()
    sim = SimConfig(mode="fedbuff", buffer_size=4, concurrency=6,
                    max_sim_time=0.15)
    s, f = _both(task, cfg, sim)
    _assert_exact_match(s, f)
    assert f.sim_time <= 0.15


def test_fleet_shared_parts_proxy_mode(task):
    """parts as ONE shared index array (the fleet-benchmark layout)."""
    cfg = _cfg(rounds=3)
    sim = SimConfig(mode="fedbuff", buffer_size=4, concurrency=6)
    pool = np.arange(len(task["data"]["x"]))
    res = run_fleet(task["loss_fn"], task["params"], task["data"], pool,
                    cfg, sim, task["eval_fn"])
    assert res.rounds_done == 3
    assert res.n_received >= 3 * 4
    assert res.resources is None


def test_fleet_diurnal_policy_runs(task):
    """Diurnal scenario + diurnal availability: eligibility breathes
    with the virtual clock and the run still completes its rounds."""
    cfg = _cfg(rounds=4, participation="avail:diurnal:0.5")
    sim = SimConfig(mode="fedbuff", scenario="diurnal", buffer_size=4,
                    concurrency=6)
    res = run_fleet(task["loss_fn"], task["params"], task["data"],
                    task["parts"], cfg, sim, task["eval_fn"])
    assert res.rounds_done == 4
    assert res.participation_count.sum() == res.n_dispatched


# ---------------------------------------------------------------------------
# validation gates (documented non-goals raise, never degrade)
# ---------------------------------------------------------------------------


def test_fleet_rejects_sync_mode(task):
    with pytest.raises(ValueError, match="fedbuff wave loop"):
        run_fleet(task["loss_fn"], task["params"], task["data"],
                  task["parts"], _cfg(), SimConfig(mode="sync"))


def test_fleet_rejects_unversioned_merge(task):
    with pytest.raises(NotImplementedError, match="mask_ledger"):
        run_fleet(task["loss_fn"], task["params"], task["data"],
                  task["parts"], _cfg(),
                  SimConfig(mode="fedbuff", mask_ledger=False))


def test_fleet_rejects_downlink_codecs(task):
    with pytest.raises(NotImplementedError, match="downlink"):
        run_fleet(task["loss_fn"], task["params"], task["data"],
                  task["parts"], _cfg(codecs=("down:fedpaq:4",)),
                  SimConfig(mode="fedbuff"))


def test_fleet_rejects_stateful_uplink_codecs(task):
    with pytest.raises(NotImplementedError, match="stateful"):
        run_fleet(task["loss_fn"], task["params"], task["data"],
                  task["parts"], _cfg(codecs=("ef", "fedpaq:4")),
                  SimConfig(mode="fedbuff"))


def test_fleet_rejects_weighted_policies():
    with pytest.raises(NotImplementedError, match="host-side only"):
        make_vector_policy("powd:8", 64, 0)
    with pytest.raises(ValueError, match="unknown participation"):
        make_vector_policy("nosuch:1", 64, 0)


def test_fleet_stateless_uplink_codec_prices_wire(task):
    """A stateless uplink codec (fedpaq 4-bit) IS supported and shows up
    in the comm ratio."""
    cfg = _cfg(rounds=3, codecs=("fedpaq:4",))
    sim = SimConfig(mode="fedbuff", buffer_size=4, concurrency=6)
    res = run_fleet(task["loss_fn"], task["params"], task["data"],
                    task["parts"], cfg, sim)
    assert res.rounds_done == 3
    assert res.comm_ratio == pytest.approx(0.125, abs=0.01)


# ---------------------------------------------------------------------------
# vectorized counterparts match the host originals bitwise
# ---------------------------------------------------------------------------


def test_cost_model_vec_matches_scalar_bitwise():
    """The *_vec cost model is elementwise the scalar helpers' f64."""
    rng = np.random.default_rng(0)
    params = {"a": {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))},
              "c": {"w": jnp.zeros((32, 10))}}
    um = build_units(params, "module")
    N = 33
    res = ResourceArrays(rng.uniform(0.01, 0.2, N),
                         rng.uniform(1e5, 1e7, N),
                         rng.uniform(1e5, 1e7, N),
                         rng.uniform(0.0, 0.2, N))
    masks = rng.random((N, len(um.names))) > 0.5
    d_vec = download_time_vec(um, res)
    c_vec = compute_time_vec(5, res)
    u_vec = upload_time_vec(um, masks, res)
    rt_vec = round_trip_time_vec(um, masks, res, 5)
    for i in range(N):
        r = ClientResources(res.step_time[i], res.up_bw[i],
                            res.down_bw[i], res.dropout[i])
        assert d_vec[i] == download_time(um, r)
        assert c_vec[i] == compute_time(5, r)
        assert u_vec[i] == upload_time(um, masks[i], r)
        assert rt_vec[i] == round_trip_time(um, masks[i], r, 5)


def test_resource_arrays_match_host_rows():
    """sample_resource_arrays IS sample_resources, struct-of-arrays."""
    for name in ("uniform", "lognormal", "bimodal", "diurnal", "measured"):
        arr = sample_resource_arrays(get_scenario(name), 37, seed=5)
        host = resources_to_arrays(sample_resources(get_scenario(name), 37,
                                                    seed=5))
        for a, b in zip(arr, host):
            np.testing.assert_array_equal(a, b)


def test_vector_diurnal_matches_host_availability():
    host = AvailDiurnal(0.4, 120.0)
    host.bind(50)
    vec = make_vector_policy("avail:diurnal:0.4:120", 50, 0)
    ids = np.arange(50, dtype=np.int64)
    for t in (0.0, 13.7, 60.0, 99.9, 240.0):
        np.testing.assert_array_equal(
            np.flatnonzero(vec.eligible(t, 600.0)),
            host.available(ids, t, 600.0))


def test_vector_energy_matches_host_battery_trajectory():
    """Same dispatch sequence -> bitwise-identical battery arrays."""
    host = EnergyBudget(5.0, 0.5, 1.0)
    host.bind(8)
    vec = make_vector_policy("energy:5:0.5:1.0", 8, 0)
    rng = np.random.default_rng(2)
    t = 0.0
    for _ in range(20):
        t += float(rng.uniform(0.1, 2.0))
        ids = rng.choice(8, size=3, replace=False)
        costs = rng.uniform(0.5, 4.0, 3)
        ev = vec.eligible(t, 600.0)
        host._accrue(t)
        np.testing.assert_array_equal(ev, host.battery > 0.0)
        vec.observe_dispatch(ids, t, costs)
        for c, s in zip(ids, costs):
            host.observe_dispatch(int(c), t, float(s))
        np.testing.assert_array_equal(vec.battery, host.battery)


# ---------------------------------------------------------------------------
# wave kernels + population state
# ---------------------------------------------------------------------------


def test_wave_scorer_respects_eligibility():
    from repro.launch.mesh import make_host_mesh
    scorer = make_wave_scorer(make_host_mesh())
    elig = np.zeros(64, bool)
    elig[[3, 17, 40, 41]] = True
    scores = np.asarray(scorer(jax.random.PRNGKey(0), jnp.asarray(elig)))
    assert (scores[~elig] == INELIGIBLE).all()
    assert (scores[elig] > INELIGIBLE / 2).all()
    vals, idx = wave_top_k(jnp.asarray(scores), 4)
    assert set(np.asarray(idx).tolist()) == {3, 17, 40, 41}


def test_wave_scorer_is_key_deterministic_and_uniformish():
    from repro.launch.mesh import make_host_mesh
    scorer = make_wave_scorer(make_host_mesh())
    elig = jnp.ones(256, bool)
    a = np.asarray(scorer(jax.random.PRNGKey(7), elig))
    b = np.asarray(scorer(jax.random.PRNGKey(7), elig))
    np.testing.assert_array_equal(a, b)
    # Gumbel-max top-k over equal scores is uniform w/o replacement:
    # across keys, every client should land in SOME cohort
    hit = np.zeros(256, bool)
    for s in range(60):
        sc = scorer(jax.random.PRNGKey(100 + s), elig)
        _, idx = wave_top_k(sc, 32)
        hit[np.asarray(idx)] = True
    assert hit.all()


def test_fleet_state_soa_invariants():
    st = FleetState.init(10)
    assert st.n_inflight == 0
    assert math.isinf(st.arrival_time[0])
    assert st.arrival_time.dtype == np.float64
    st.in_flight[[2, 5]] = True
    st.arrival_time[[2, 5]] = 1.5
    assert st.n_inflight == 2
    st.free(np.asarray([2]))
    assert st.n_inflight == 1 and math.isinf(st.arrival_time[2])
