"""Update-codec pipeline (repro.compress): spec grammar + registry, the
legacy-flag deprecation shim (bitwise equivalence of trajectories AND
per-unit payload pricing), codec algebra properties (pricing monotone in
the recycle mask, decode-encode fixed points, EF residual telescoping),
the new topk/ef stages end-to-end, and the diurnal bandwidth scenario.
"""
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.compress import (CODECS, legacy_codec_specs, parse_codec,
                            parse_codecs)
from repro.configs.base import SIM_SCENARIOS, get_scenario
from repro.core import LuarConfig
from repro.core.units import build_units
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import (FLConfig, client_payload_bytes_per_unit,
                             resolve_codec_specs, run_fl)
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.sim import SimConfig, run_sim, sample_resources
from repro.sim.profiles import bandwidth_multiplier, scale_bandwidth


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    def eval_fn(p):
        return {"acc": float(jnp.mean(jnp.argmax(mlp_apply(p, xj), -1) == yj))}

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts, eval_fn=eval_fn)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 5)
    kw.setdefault("eval_every", 5)
    return FLConfig(n_clients=16, n_active=6, tau=3, batch_size=8, **kw)


def _run_fl(task, cfg):
    return run_fl(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, task["eval_fn"])


def _run_sim(task, cfg, sim):
    return run_sim(task["loss_fn"], task["params"], task["data"],
                   task["parts"], cfg, sim, task["eval_fn"])


def _trees_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# a tiny 3-unit template for unit-level codec algebra
_TEMPLATE = {"w1": jnp.zeros((4, 3), jnp.float32),
             "b1": jnp.zeros((6,), jnp.float32),
             "w2": jnp.zeros((2, 2, 2), jnp.float32)}
_UM = build_units(_TEMPLATE, "leaf")
_SIZES = np.asarray(_UM.unit_bytes, np.float64)
_NU = len(_UM.names)


def _tree(rng):
    return jax.tree.map(
        lambda l: jnp.asarray(rng.standard_normal(l.shape), jnp.float32),
        _TEMPLATE)


def _bound(specs):
    pipe = parse_codecs(specs)
    state = pipe.init_state(_TEMPLATE, _UM)
    return pipe, state


# ---------------------------------------------------------------------------
# registry + spec grammar
# ---------------------------------------------------------------------------


def test_registry_has_all_stages():
    assert {"fedpaq", "prune", "dropout", "lbgm", "topk", "ef"} <= set(CODECS)


@pytest.mark.parametrize("spec", ["fedpaq:4", "prune:0.25", "dropout:0.5",
                                  "lbgm:0.9", "topk:0.1", "ef"])
def test_spec_round_trips(spec):
    assert parse_codec(spec).spec() == spec


def test_parse_codecs_plus_separated_string():
    pipe = parse_codecs("fedpaq:4+topk:0.1")
    assert pipe.specs() == ("fedpaq:4", "topk:0.1")


def test_parse_rejects_unknown_and_bad_args():
    with pytest.raises(ValueError, match="unknown codec"):
        parse_codec("gzip:9")
    with pytest.raises(ValueError, match="not a number"):
        parse_codec("fedpaq:four")
    with pytest.raises(ValueError):
        parse_codec("fedpaq:0")        # out-of-range bits
    with pytest.raises(ValueError):
        parse_codec("topk:0")          # empty upload


def test_ef_is_hoisted_to_front():
    """Error feedback compensates the stages downstream of it, so the
    pipeline normalizes it to the front regardless of list position."""
    pipe = parse_codecs(("fedpaq:4", "topk:0.1", "ef"))
    assert pipe.specs() == ("ef", "fedpaq:4", "topk:0.1")


def test_legacy_specs_preserve_stack_order():
    assert legacy_codec_specs(8, 0.25, 0.5, 0.9) == (
        "fedpaq:8", "prune:0.25", "dropout:0.5", "lbgm:0.9")
    assert legacy_codec_specs() == ()


def test_resolve_rejects_mixed_flags_and_codecs():
    with pytest.raises(ValueError, match="mixes codecs"):
        resolve_codec_specs(_cfg(codecs=("topk:0.1",), fedpaq_bits=8))


def test_legacy_flags_warn_deprecation():
    with pytest.warns(DeprecationWarning):
        assert resolve_codec_specs(_cfg(fedpaq_bits=8)) == ("fedpaq:8",)


# ---------------------------------------------------------------------------
# encode/decode algebra
# ---------------------------------------------------------------------------


def test_empty_pipeline_is_identity():
    pipe, state = _bound(())
    x = _tree(np.random.default_rng(0))
    y, state, aux = pipe.encode(state, x, jax.random.PRNGKey(0))
    assert _trees_equal(x, y) and aux == ()
    mask = np.array([True, False, False])
    np.testing.assert_array_equal(pipe.price_per_unit(_SIZES, mask),
                                  np.where(mask, 0.0, _SIZES))


def test_prune_roundtrip_is_fixed_point():
    """decode(encode(.)) is idempotent for sparsifiers: re-encoding an
    already-pruned tree with the same keep fraction changes nothing."""
    pipe, state = _bound(("prune:0.5",))
    x = _tree(np.random.default_rng(1))
    once, state, _ = pipe.encode(state, x, jax.random.PRNGKey(0))
    once = pipe.decode(state, once)
    twice, state, _ = pipe.encode(state, once, jax.random.PRNGKey(1))
    twice = pipe.decode(state, twice)
    assert _trees_equal(once, twice)


def test_topk_roundtrip_is_fixed_point():
    pipe, state = _bound(("topk:0.2",))
    x = _tree(np.random.default_rng(2))
    once, state, _ = pipe.encode(state, x, jax.random.PRNGKey(0))
    twice, state, aux = pipe.encode(state, pipe.decode(state, once),
                                    jax.random.PRNGKey(1))
    assert _trees_equal(once, pipe.decode(state, twice))
    assert int(np.asarray(aux[0]).sum()) >= 1


def test_fedpaq_fixes_grid_values():
    """Stochastic quantization is exact on values already on its grid
    (p = 0 -> the bernoulli never rounds), a decode-encode fixed point."""
    bits = 3
    levels = 2 ** bits - 1
    rng = np.random.default_rng(3)
    scale = 1.7

    def gridify(l):
        q = rng.integers(0, levels + 1, l.shape)
        return jnp.asarray((q / levels * 2.0 - 1.0) * scale, jnp.float32)

    x = jax.tree.map(gridify, _TEMPLATE)
    # ensure the per-tensor max is exactly `scale` so the grid matches
    x = jax.tree.map(lambda l: l.at[(0,) * l.ndim].set(scale), x)
    pipe, state = _bound((f"fedpaq:{bits}",))
    y, state, _ = pipe.encode(state, x, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(pipe.decode(state, y))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_topk_is_global_across_units():
    """Global selection: when one tensor dominates, the other units ship
    (almost) nothing — per-tensor prune cannot express this."""
    x = jax.tree.map(jnp.zeros_like, _TEMPLATE)
    x = dict(x)
    x["w1"] = jnp.asarray(np.arange(1, 13).reshape(4, 3), jnp.float32)
    pipe, state = _bound(("topk:0.25",))
    y, state, aux = pipe.encode(state, x, jax.random.PRNGKey(0))
    counts = np.asarray(aux[0])
    n_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(_TEMPLATE))
    k = max(1, round(0.25 * n_total))
    assert counts[_UM.names.index("w1")] == k      # all survivors in w1
    # zero-tensor ties at the threshold cannot occur here: everything else
    # is strictly below, so total survivors == k exactly
    assert counts.sum() == k


def test_topk_never_counts_exact_zeros_as_survivors():
    """When the k-th magnitude is 0 the >= threshold is vacuously true on
    zero entries — but a sparse encoding never serializes zeros, so they
    must not appear in the survivor counts (or the byte ledger)."""
    x = jax.tree.map(jnp.zeros_like, _TEMPLATE)
    x = dict(x)
    x["b1"] = jnp.asarray([3.0, -2.0, 1.0, 0.0, 0.0, 0.0], jnp.float32)
    pipe, state = _bound(("topk:0.5",))          # k = 11 of 22 >= 3 nonzeros
    _, state, aux = pipe.encode(state, x, jax.random.PRNGKey(0))
    assert int(np.asarray(aux[0]).sum()) == 3


def test_lbgm_scalar_price_capped_at_upstream():
    """A suppressed unit ships one 4-byte coefficient UNLESS upstream
    compression already made the dense unit cheaper than the scalar."""
    pipe = parse_codecs(("lbgm:0.9",))
    sizes = np.asarray([2.0, 100.0])             # first unit cheaper than 4B
    mask = np.zeros(2, bool)
    sent = np.asarray([False, False])
    got = pipe.price_per_unit(sizes, mask, ((sent),))
    np.testing.assert_array_equal(got, [2.0, 4.0])
    assert np.all(got <= sizes)                  # never above dense


def test_flconfig_codecs_accepts_plus_joined_string():
    assert resolve_codec_specs(_cfg(codecs="fedpaq:4+topk:0.1+ef")) == (
        "fedpaq:4", "topk:0.1", "ef")


def test_topk_pricing_uses_value_plus_index_bytes():
    pipe = parse_codecs(("topk:0.1",))
    mask = np.zeros(_NU, bool)
    counts = np.asarray([5, 0, 2], np.float64)
    got = pipe.price_per_unit(_SIZES, mask, (counts,))
    n_entries = _SIZES / 4.0
    want = np.minimum(_SIZES * (counts / n_entries) + counts * 4.0, _SIZES)
    np.testing.assert_allclose(got, want)
    # nominal (aux-free) pricing: expectation at the keep fraction
    nominal = pipe.price_per_unit(_SIZES, mask)
    want_nom = np.minimum(_SIZES * 0.1 + 0.1 * n_entries * 4.0, _SIZES)
    np.testing.assert_allclose(nominal, want_nom)


def test_ef_zero_residual_is_identity_and_commit_captures_error():
    pipe, state = _bound(("ef", "prune:0.3"))
    x = _tree(np.random.default_rng(4))
    y, state, _ = pipe.encode(state, x, jax.random.PRNGKey(0))
    # e_1 = (x + 0) - transmitted
    want = jax.tree.map(lambda a, b: a - b, x, y)
    assert _trees_equal(state[0], want)
    # a lossless downstream leaves the residual at zero
    pipe2, state2 = _bound(("ef",))
    y2, state2, _ = pipe2.encode(state2, x, jax.random.PRNGKey(0))
    assert _trees_equal(x, y2)
    assert all(float(jnp.abs(l).max()) == 0.0 for l in jax.tree.leaves(state2[0]))


@pytest.mark.slow
@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=15)
def test_ef_residual_telescopes(rounds, seed):
    """sum_t transmitted_t == sum_t update_t - e_T (e_0 = 0): error
    feedback turns compression error into a bounded lag, never a bias."""
    rng = np.random.default_rng(seed)
    pipe, state = _bound(("ef", "topk:0.2"))
    total_in = jax.tree.map(jnp.zeros_like, _TEMPLATE)
    total_out = jax.tree.map(jnp.zeros_like, _TEMPLATE)
    for t in range(rounds):
        u = _tree(rng)
        w, state, _ = pipe.encode(state, u, jax.random.PRNGKey(t))
        total_in = jax.tree.map(lambda a, b: a + b, total_in, u)
        total_out = jax.tree.map(lambda a, b: a + b, total_out, w)
    residual = state[0]
    for i, o, e in zip(jax.tree.leaves(total_in), jax.tree.leaves(total_out),
                       jax.tree.leaves(residual)):
        np.testing.assert_allclose(np.asarray(o) + np.asarray(e),
                                   np.asarray(i), rtol=1e-4, atol=1e-5)


def test_unbound_um_stage_raises_actionably():
    pipe = parse_codecs(("topk:0.1",))
    with pytest.raises(RuntimeError, match="init_state"):
        pipe.encode((None,), _TEMPLATE, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# pricing properties
# ---------------------------------------------------------------------------

_PRICEABLE = [(), ("fedpaq:4",), ("prune:0.25",), ("dropout:0.5",),
              ("topk:0.1",), ("fedpaq:4", "topk:0.1", "ef"),
              ("fedpaq:8", "prune:0.5", "dropout:0.25")]


@pytest.mark.slow
@given(st.integers(min_value=0, max_value=len(_PRICEABLE) - 1),
       st.integers(min_value=2, max_value=12),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(deadline=None, max_examples=40)
def test_pricing_monotone_in_mask(pipe_idx, n, seed):
    """Growing the recycle mask never increases any unit's price, masked
    units always price zero, and prices stay within [0, dense]."""
    rng = np.random.default_rng(seed)
    pipe = parse_codecs(_PRICEABLE[pipe_idx])
    sizes = rng.integers(4, 4096, n).astype(np.float64) * 4.0
    small = rng.random(n) < 0.4
    big = small | (rng.random(n) < 0.4)           # small  ⊆  big
    p_small = pipe.price_per_unit(sizes, small)
    p_big = pipe.price_per_unit(sizes, big)
    assert np.all(p_big <= p_small + 1e-12)
    assert np.all(p_small[small] == 0.0) and np.all(p_big[big] == 0.0)
    assert np.all(p_small >= 0.0) and np.all(p_small <= sizes + 1e-9)


def test_legacy_and_codec_pricing_identical():
    mask = np.asarray([False, True, False])
    sizes = np.asarray([100.0, 200.0, 400.0])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = client_payload_bytes_per_unit(
            sizes, mask, _cfg(fedpaq_bits=8, prune_keep=0.25, dropout_rate=0.5))
    explicit = client_payload_bytes_per_unit(
        sizes, mask, _cfg(codecs=("fedpaq:8", "prune:0.25", "dropout:0.5")))
    np.testing.assert_array_equal(legacy, explicit)
    np.testing.assert_allclose(
        explicit, np.where(mask, 0.0, sizes) * (8 / 32) * 0.5 * 0.5)


# ---------------------------------------------------------------------------
# the deprecation shim: bitwise run_fl equivalence
# ---------------------------------------------------------------------------

_SHIM_PAIRS = [
    (dict(fedpaq_bits=8), ("fedpaq:8",)),
    (dict(prune_keep=0.25), ("prune:0.25",)),
    (dict(dropout_rate=0.5), ("dropout:0.5",)),
    (dict(lbgm_threshold=0.5), ("lbgm:0.5",)),
    (dict(fedpaq_bits=4, prune_keep=0.5, dropout_rate=0.25,
          lbgm_threshold=0.5),
     ("fedpaq:4", "prune:0.5", "dropout:0.25", "lbgm:0.5")),
]


@pytest.mark.slow
@pytest.mark.parametrize("flags,specs", _SHIM_PAIRS,
                         ids=["fedpaq", "prune", "dropout", "lbgm", "stack"])
def test_shim_matches_explicit_pipeline_bitwise(task, flags, specs):
    """Every legacy-flag config and its explicit codec equivalent produce
    the same run_fl trajectory bit-for-bit AND the same payload bytes."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = _run_fl(task, _cfg(luar=LuarConfig(delta=2), **flags))
    explicit = _run_fl(task, _cfg(luar=LuarConfig(delta=2), codecs=specs))
    assert _trees_equal(legacy.params, explicit.params)
    assert legacy.comm_ratio == explicit.comm_ratio
    assert [h["acc"] for h in legacy.history] == \
           [h["acc"] for h in explicit.history]


def test_lbgm_codec_matches_legacy_in_sync_sim(task):
    """The LBGM special case deleted from the round engine survives as a
    codec stage: the sync simulator trajectory is unchanged."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = _run_sim(task, _cfg(lbgm_threshold=0.5),
                          SimConfig(scenario="uniform"))
    explicit = _run_sim(task, _cfg(codecs=("lbgm:0.5",)),
                        SimConfig(scenario="uniform"))
    assert _trees_equal(legacy.params, explicit.params)
    assert legacy.comm_ratio == explicit.comm_ratio
    assert 0.0 < explicit.comm_ratio < 1.0        # scalars actually priced


# ---------------------------------------------------------------------------
# the new stages end-to-end (acceptance: fedbuff + full stack, zero waste)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fedbuff_full_stack_zero_waste(task):
    """("fedpaq:4", "topk:0.1", "ef") under the fedbuff engine: per-client
    EF state threads through the async path, staleness occurs, and the
    upload ledger still balances to exactly zero waste."""
    cfg = _cfg(luar=LuarConfig(delta=2), codecs=("fedpaq:4", "topk:0.1", "ef"),
               rounds=6)
    res = _run_sim(task, cfg, SimConfig(scenario="bimodal", mode="fedbuff",
                                        buffer_size=4, concurrency=8))
    assert res.rounds_done == cfg.rounds
    assert res.ledger_misses == 0
    assert res.staleness_observed.max() > 0       # real version skew
    np.testing.assert_array_equal(res.wasted_per_unit,
                                  np.zeros_like(res.wasted_per_unit))
    assert res.wasted_upload_bytes == 0.0
    assert 0.0 < res.comm_ratio < 0.2             # the stack actually priced


def test_fedbuff_lbgm_codec_spec_raises(task):
    with pytest.raises(NotImplementedError, match="mode='sync'"):
        _run_sim(task, _cfg(codecs=("lbgm:0.5",)),
                 SimConfig(scenario="uniform", mode="fedbuff"))


def test_run_fl_with_new_stack_converges(task):
    cfg = _cfg(luar=LuarConfig(delta=2), codecs=("fedpaq:4", "topk:0.25", "ef"),
               rounds=20, eval_every=20)
    res = _run_fl(task, cfg)
    assert res.history[-1]["acc"] > 0.6
    assert res.comm_ratio < 0.25


# ---------------------------------------------------------------------------
# launch-path integration: codec state rides in TrainState
# ---------------------------------------------------------------------------


class _TinyModel:
    """Just enough Model surface for the fedluar train step."""

    def init(self, key):
        return {"w": jnp.asarray(np.linspace(1.0, 2.0, 8), jnp.float32),
                "b": jnp.asarray(np.linspace(-1.0, 1.0, 4), jnp.float32)}

    def train_loss(self, p, batch):
        return (jnp.sum(jnp.square(p["w"] - batch["x"]))
                + jnp.sum(jnp.square(p["b"])))


def test_fedluar_train_step_threads_codec_state():
    from repro.launch.steps import (TrainState, make_fedluar_train_step,
                                    train_state_shapes)
    model = _TinyModel()
    codec = parse_codecs(("ef", "topk:0.5"))
    shapes, um = train_state_shapes(model, codec=codec)
    assert shapes.codec is not None               # eval_shape'd codec state

    params = model.init(jax.random.PRNGKey(0))
    zeros = jax.tree.map(jnp.zeros_like, params)
    from repro.core import luar_init
    luar_state, _ = luar_init(params, LuarConfig(delta=1), jax.random.PRNGKey(1))
    state = TrainState(params, zeros, luar_state,
                       codec.init_state(params, um))
    step = jax.jit(make_fedluar_train_step(model, LuarConfig(delta=1), um,
                                           lr=0.1, codec=codec))
    batch = {"x": jnp.zeros(8, jnp.float32)}
    l0 = None
    for _ in range(3):
        state, loss = step(state, batch)
        l0 = l0 if l0 is not None else float(loss)
    assert float(loss) < l0                       # still optimizes
    # the EF residual accumulated what top-k dropped: nonzero state
    residual = state.codec[0]
    assert any(float(jnp.abs(l).max()) > 0 for l in jax.tree.leaves(residual))
    # static path refuses codecs (it would defeat the DCE'd collective)
    with pytest.raises(ValueError, match="dynamic path"):
        make_fedluar_train_step(model, LuarConfig(delta=1), um,
                                static_mask=[True, False], codec=codec)


# ---------------------------------------------------------------------------
# diurnal bandwidth scenario
# ---------------------------------------------------------------------------


def test_diurnal_multiplier_oscillates_and_validates():
    sc = get_scenario("diurnal")
    ts = np.linspace(0.0, sc.bw_period, 200, endpoint=False)
    ms = np.array([bandwidth_multiplier(sc, t) for t in ts])
    assert ms.max() > 1.0 + 0.9 * sc.bw_amplitude
    assert ms.min() < 1.0 - 0.9 * sc.bw_amplitude
    assert abs(ms.mean() - 1.0) < 1e-6            # zero-mean cycle
    assert ms.min() > 0.0                         # bandwidth never dies
    # one full period later: the same multiplier
    assert bandwidth_multiplier(sc, 0.3 * sc.bw_period) == pytest.approx(
        bandwidth_multiplier(sc, 1.3 * sc.bw_period))
    # non-diurnal kinds are flat
    assert bandwidth_multiplier("bimodal", 123.0) == 1.0
    with pytest.raises(ValueError, match="bw_amplitude"):
        bandwidth_multiplier(sc.replace(bw_amplitude=1.5), 0.0)


def test_scale_bandwidth_touches_links_only():
    r = sample_resources("diurnal", 2)[0]
    r2 = scale_bandwidth(r, 0.5)
    assert r2.up_bw == 0.5 * r.up_bw and r2.down_bw == 0.5 * r.down_bw
    assert r2.step_time == r.step_time and r2.dropout == r.dropout
    assert scale_bandwidth(r, 1.0) is r


@pytest.mark.slow
def test_diurnal_cycle_changes_round_times(task):
    """The cycle is visible end-to-end: the same config runs slower when
    dispatches land in the bandwidth trough (phase = -pi/2) than at the
    peak (phase = +pi/2), and the flat-amplitude control matches uniform
    timing exactly."""
    base = get_scenario("diurnal").replace(bw_period=1e6)   # ~constant phase
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=4)
    peak = _run_sim(task, cfg, SimConfig(scenario=base.replace(
        bw_phase=math.pi / 2)))
    trough = _run_sim(task, cfg, SimConfig(scenario=base.replace(
        bw_phase=-math.pi / 2)))
    assert trough.sim_time > peak.sim_time
    flat = _run_sim(task, cfg, SimConfig(scenario=base.replace(
        bw_amplitude=0.0)))
    uniform = _run_sim(task, cfg, SimConfig(scenario=get_scenario(
        "uniform").replace(step_time=base.step_time, up_bw=base.up_bw,
                           down_bw=base.down_bw)))
    assert flat.sim_time == pytest.approx(uniform.sim_time)
    assert _trees_equal(trough.params, peak.params)   # timing-only knob


def test_diurnal_registered_and_uniform_population():
    assert "diurnal" in SIM_SCENARIOS
    res = sample_resources("diurnal", 8, seed=0)
    assert len(set(res)) == 1                     # time varies, clients don't
