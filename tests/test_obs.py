"""repro.obs — unified telemetry (metrics, traces, profiling, perf gate).

Load-bearing checks:

1. GOLDEN TRACE: a 2-round ``run_fl`` with an injected zero clock emits
   EXACTLY the pinned JSONL bytes — schema version, event kinds, field
   key order.  Any change to the stream is a schema change and must bump
   ``TRACE_SCHEMA`` + this golden together.
2. TELEMETRY IS FREE: with a trace sink and profiler attached, ``run_fl``
   and BOTH sim engines reproduce the PR-5 fingerprint trajectories
   bit-for-bit, and every counter-derived result field equals the plain
   run's exactly.
3. The perf-trajectory harness: BENCH snapshot schema, the regression
   comparator's pass/regress/coverage verdicts, soft mode, and the
   committed repo-root ``BENCH_*.json`` baselines validating.
"""
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import run as bench_run
from benchmarks.check_regression import compare, load_snapshot
from benchmarks.check_regression import main as check_main
from benchmarks.common import BENCH_SCHEMA, bench_record
from benchmarks.kernels_bench import _time
from repro.core import LuarConfig
from repro.data.synthetic import gaussian_mixture
from repro.fl.client import ClientConfig
from repro.fl.partition import dirichlet_partition
from repro.fl.rounds import FLConfig, run_fl
from repro.models.cnn import mlp_init, mlp_apply, softmax_xent
from repro.obs import (AGGREGATE, DISPATCH, EVENT_KINDS, M_COMM_RATIO,
                       M_DOWNLOAD_BYTES, M_ROUNDS, M_STALENESS, M_UPLINKS,
                       M_UPLOAD_BYTES, MetricsRegistry, Profiler,
                       Telemetry, TRACE_SCHEMA, TraceSink,
                       format_metrics, read_trace, run_summary)
from repro.obs import prom
from repro.sim import SimConfig, run_sim

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def task():
    x, y = gaussian_mixture(1200, n_classes=10, d=32, seed=0)
    parts = dirichlet_partition(y, 16, alpha=0.3, seed=0)
    params = mlp_init(jax.random.PRNGKey(0), n_features=32, n_classes=10)

    def loss_fn(p, b):
        return softmax_xent(mlp_apply(p, b["x"]), b["y"])

    return dict(loss_fn=loss_fn, params=params, data={"x": x, "y": y},
                parts=parts)


def _cfg(**kw):
    kw.setdefault("client", ClientConfig(lr=0.05))
    kw.setdefault("rounds", 8)
    kw.setdefault("eval_every", 4)
    return FLConfig(n_clients=16, n_active=6, tau=3, batch_size=8, **kw)


def _fp(params) -> str:
    buf = np.concatenate([np.asarray(l, np.float64).ravel()
                          for l in jax.tree.leaves(params)])
    return hashlib.sha256(buf.tobytes()).hexdigest()[:16]


# same-platform fingerprints as tests/test_participation.py — telemetry
# must not move them
_GOLD_RUN_FL = "13d3711a8b5d456c"
_GOLD_FEDBUFF = "d7da0364cb957567"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help").labels()
    c.add(2.5)
    c.inc()
    assert c.value == 3.5
    with pytest.raises(ValueError, match="counter add"):
        c.add(-1.0)
    g = reg.gauge("t_gauge").labels()
    g.set(7)
    assert g.value == 7.0
    h = reg.histogram("t_hist", buckets=(1.0, 10.0)).labels()
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 3 and h.counts == [1, 1, 1]
    assert h.sum == 55.5
    assert h.quantile(0.5) == 5.0
    assert h.mean() == pytest.approx(18.5)


def test_registry_labels_and_kind_conflict():
    reg = MetricsRegistry()
    fam = reg.counter("evictions_total")
    fam.labels(ledger="mask").inc()
    fam.labels(ledger="mask").inc()
    fam.labels(ledger="delta").inc()
    assert reg.value("evictions_total", ledger="mask") == 2.0
    assert reg.value("evictions_total", ledger="delta") == 1.0
    assert reg.value("evictions_total", ledger="nope") == 0.0
    assert reg.value("never_registered", default=-1.0) == -1.0
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("evictions_total")
    # scalar convenience forwards to the no-label child
    reg.counter("plain_total").add(4.0)
    assert reg.value("plain_total") == 4.0


def test_format_metrics_renders_every_series():
    reg = MetricsRegistry()
    reg.counter("a_total").add(1.0)
    reg.histogram("h").observe(0.25)
    text = format_metrics(reg)
    assert "a_total 1" in text
    assert "h count=1" in text


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------


def test_prom_exposition_counters_and_gauges():
    reg = MetricsRegistry()
    reg.counter("fl_upload_bytes_total", "client bytes").add(1234.0)
    reg.gauge("fl_comm_ratio").set(0.25)
    fam = reg.counter("fl_evictions_total")
    fam.labels(ledger="mask").inc()
    body = prom.exposition(reg)
    assert "# HELP fl_upload_bytes_total client bytes" in body
    assert "# TYPE fl_upload_bytes_total counter" in body
    assert "\nfl_upload_bytes_total 1234\n" in body
    assert "fl_comm_ratio 0.25" in body
    assert 'fl_evictions_total{ledger="mask"} 1' in body
    assert body.endswith("\n")


def test_prom_exposition_histogram_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    body = prom.exposition(reg)
    assert 'lat_seconds_bucket{le="1"} 1' in body
    assert 'lat_seconds_bucket{le="2"} 2' in body
    assert 'lat_seconds_bucket{le="+Inf"} 3' in body
    assert "lat_seconds_sum 11" in body
    assert "lat_seconds_count 3" in body


def test_prom_escapes_label_values():
    reg = MetricsRegistry()
    reg.gauge("g").labels(path='a"b\\c').set(1.0)
    assert 'g{path="a\\"b\\\\c"} 1' in prom.exposition(reg)


# ---------------------------------------------------------------------------
# trace sink
# ---------------------------------------------------------------------------


def test_trace_rejects_unknown_kind():
    sink = TraceSink(clock=lambda: 0.0)
    with pytest.raises(ValueError, match="unknown trace event kind"):
        sink.emit("REBOOT", 0.0)
    assert sink.n_emitted == 0


def test_trace_key_order_and_file_roundtrip(tmp_path):
    p = str(tmp_path / "t.jsonl")
    with TraceSink(p, clock=lambda: 1.5) as sink:
        sink.emit(DISPATCH, 3.0, client=4, version=2, down_bytes=10.0)
    [rec] = read_trace(p)
    assert list(rec) == ["v", "event", "t_sim", "t_wall", "client",
                         "version", "down_bytes"]
    assert rec == {"v": TRACE_SCHEMA, "event": "DISPATCH", "t_sim": 3.0,
                   "t_wall": 1.5, "client": 4, "version": 2,
                   "down_bytes": 10.0}


def test_read_trace_rejects_other_schema(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"v": 999, "event": "RUN_START"}\n')
    with pytest.raises(ValueError, match="schema"):
        read_trace(str(p))


def test_trace_jsonifies_numpy():
    sink = TraceSink(clock=lambda: 0.0)
    sink.emit(AGGREGATE, 0.0, n=np.int64(3),
              recycled=np.array([1, 2]), alpha=np.float64(0.5))
    [line] = sink.lines()
    assert '"n": 3' in line and '"recycled": [1, 2]' in line
    assert '"alpha": 0.5' in line


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------


def test_profiler_compile_steady_split():
    reg = MetricsRegistry()
    prof = Profiler(reg)
    for _ in range(3):
        with prof.span("round_step", jitted=True):
            pass
    with prof.span("pricing"):
        pass
    phases = {(s, ph): n for s, ph, n, *_ in prof.table()}
    assert phases[("round_step", "compile")] == 1
    assert phases[("round_step", "steady")] == 2
    assert phases[("pricing", "steady")] == 1
    assert "round_step" in prof.render()


def test_telemetry_span_noop_without_profiler():
    tele = Telemetry()
    with tele.span("anything", jitted=True):
        pass                           # must not raise nor record
    assert tele.metrics.get("obs_span_seconds") is None


# ---------------------------------------------------------------------------
# golden trace: 2-round run_fl, byte-pinned
# ---------------------------------------------------------------------------

_GOLD_TRACE = [
    '{"v": 1, "event": "RUN_START", "t_sim": 0.0, "t_wall": 0.0, "engine": "run_fl", "n_clients": 16, "rounds": 2, "n_units": 6, "units": ["fc1.b", "fc1.w", "fc2.b", "fc2.w", "fc3.b", "fc3.w"]}',  # noqa: E501
    '{"v": 1, "event": "DISPATCH", "t_sim": 0.0, "t_wall": 0.0, "round": 0, "version": 0, "cohort": [3, 7, 6, 4, 0, 9], "down_bytes": 166128.0, "first_contacts": 0}',  # noqa: E501
    '{"v": 1, "event": "UPLOAD", "t_sim": 0.0, "t_wall": 0.0, "round": 0, "n": 6, "bytes_per_client": 27688.0, "lag": 0, "status": "accepted"}',  # noqa: E501
    '{"v": 1, "event": "AGGREGATE", "t_sim": 0.0, "t_wall": 0.0, "round": 0, "version": 1, "n": 6, "recycled": []}',  # noqa: E501
    '{"v": 1, "event": "DISPATCH", "t_sim": 1.0, "t_wall": 0.0, "round": 1, "version": 1, "cohort": [10, 13, 0, 7, 12, 6], "down_bytes": 166128.0, "first_contacts": 0}',  # noqa: E501
    '{"v": 1, "event": "UPLOAD", "t_sim": 1.0, "t_wall": 0.0, "round": 1, "n": 6, "bytes_per_client": 3112.0, "lag": 0, "status": "accepted"}',  # noqa: E501
    '{"v": 1, "event": "AGGREGATE", "t_sim": 1.0, "t_wall": 0.0, "round": 1, "version": 2, "n": 6, "recycled": [1, 3]}',  # noqa: E501
    '{"v": 1, "event": "RUN_END", "t_sim": 2.0, "t_wall": 0.0, "uploaded": 184800.0, "downloaded": 332256.0, "comm_ratio": 0.5561976307425599, "down_ratio": 1.0, "n_uplinks": 12}',  # noqa: E501
]


def test_golden_run_fl_trace(task):
    """Schema-versioned golden: exact JSONL bytes of a 2-round run with
    an injected zero clock.  A diff here is a trace schema change —
    bump TRACE_SCHEMA and this golden deliberately, never silently."""
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=2)
    tele = Telemetry(trace=TraceSink(clock=lambda: 0.0))
    run_fl(task["loss_fn"], task["params"], task["data"], task["parts"],
           cfg, None, telemetry=tele)
    assert tele.trace.lines() == _GOLD_TRACE
    assert all(json.loads(ln)["event"] in EVENT_KINDS for ln in _GOLD_TRACE)


# ---------------------------------------------------------------------------
# telemetry leaves every trajectory bit-for-bit
# ---------------------------------------------------------------------------


def test_run_fl_bitwise_with_telemetry(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    plain = run_fl(task["loss_fn"], task["params"], task["data"],
                   task["parts"], cfg, None)
    tele = Telemetry(trace=TraceSink(clock=lambda: 0.0))
    tele.profiler = Profiler(tele.metrics)
    res = run_fl(task["loss_fn"], task["params"], task["data"],
                 task["parts"], cfg, None, telemetry=tele)
    assert _fp(res.params) == _GOLD_RUN_FL == _fp(plain.params)
    # counter-derived fields are EXACTLY the plain run's
    assert res.comm_ratio == plain.comm_ratio
    assert res.uploaded == plain.uploaded
    assert res.downloaded == plain.downloaded
    assert res.n_uplinks_spent == plain.n_uplinks_spent
    assert res.fairness == plain.fairness
    # and the registry agrees with the result dataclass
    m = tele.metrics
    assert m.value(M_UPLOAD_BYTES) == res.uploaded
    assert m.value(M_DOWNLOAD_BYTES) == res.downloaded
    assert m.value(M_COMM_RATIO) == res.comm_ratio
    assert int(m.value(M_UPLINKS)) == res.n_uplinks_spent


def test_sync_sim_bitwise_with_telemetry(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    sim = dict(scenario="bimodal", deadline=60.0, sys_seed=3)
    plain = run_sim(task["loss_fn"], task["params"], task["data"],
                    task["parts"], cfg, SimConfig(**sim), None)
    tele = Telemetry.create(profile=True)
    tele.trace = TraceSink(clock=lambda: 0.0)
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, SimConfig(**sim), None,
                  telemetry=tele)
    assert _fp(res.params) == _GOLD_RUN_FL == _fp(plain.params)
    assert res.sim_time == plain.sim_time
    assert res.comm_ratio == plain.comm_ratio
    assert res.wasted_upload_bytes == plain.wasted_upload_bytes
    assert (res.n_uplinks_spent, res.n_dispatched) == \
        (plain.n_uplinks_spent, plain.n_dispatched)
    assert tele.trace.n_emitted > 0
    assert int(tele.metrics.value(M_ROUNDS)) == res.rounds_done


def test_fedbuff_bitwise_with_telemetry(task):
    cfg = _cfg(luar=LuarConfig(delta=2))
    sim = dict(scenario="bimodal", mode="fedbuff", buffer_size=4,
               concurrency=8, sys_seed=3)
    plain = run_sim(task["loss_fn"], task["params"], task["data"],
                    task["parts"], cfg, SimConfig(**sim), None)
    tele = Telemetry.create(profile=True)
    tele.trace = TraceSink(clock=lambda: 0.0)
    res = run_sim(task["loss_fn"], task["params"], task["data"],
                  task["parts"], cfg, SimConfig(**sim), None,
                  telemetry=tele)
    assert _fp(res.params) == _GOLD_FEDBUFF == _fp(plain.params)
    assert res.sim_time == plain.sim_time
    assert res.comm_ratio == plain.comm_ratio
    assert res.staleness_q == plain.staleness_q
    assert np.array_equal(res.staleness_observed, plain.staleness_observed)
    assert (res.n_received, res.n_dispatched, res.ledger_misses) == \
        (plain.n_received, plain.n_dispatched, plain.ledger_misses)
    # the staleness histogram's raw samples ARE the observation list
    h = tele.metrics.get(M_STALENESS).labels()
    assert h.count == len(res.staleness_observed)
    events = {e["event"] for e in tele.trace.events}
    assert {"RUN_START", "DISPATCH", "UPLOAD", "AGGREGATE",
            "RUN_END"} <= events


def test_run_summary_matches_result(task):
    cfg = _cfg(luar=LuarConfig(delta=2), rounds=2)
    tele = Telemetry()
    res = run_fl(task["loss_fn"], task["params"], task["data"],
                 task["parts"], cfg, None, telemetry=tele)
    s = run_summary(tele.metrics, wall_s=1.0)
    assert s["comm_ratio"] == round(res.comm_ratio, 4)
    assert s["uploaded_mb"] == round(res.uploaded / 1e6, 3)
    assert s["n_uplinks_spent"] == res.n_uplinks_spent
    assert s["downloaded_mb"] == round(res.downloaded / 1e6, 3)
    assert list(s)[-1] == "wall_s"


# ---------------------------------------------------------------------------
# perf-trajectory harness (BENCH_*.json + regression gate)
# ---------------------------------------------------------------------------


def _rows():
    return [("bench/a", 100e-6, {"units": 4}), ("bench/b", 5e-6, {})]


def test_bench_record_schema_and_footer(tmp_path):
    path = bench_record("kern", _rows(), wall_s=1.25, quick=True,
                        out_dir=str(tmp_path))
    assert path.endswith("BENCH_kern.json")
    doc = load_snapshot(path)           # validates or raises
    assert doc["schema"] == BENCH_SCHEMA and doc["quick"] is True
    assert [r["name"] for r in doc["rows"]] == ["bench/a", "bench/b"]
    assert doc["rows"][0]["us_per_call"] == 100.0
    f = doc["footer"]
    assert f["total_wall_s"] == 1.25
    assert isinstance(f["git_sha"], str) and f["git_sha"]
    assert f["jax_version"] == jax.__version__


def test_load_snapshot_rejects_malformed(tmp_path):
    p = tmp_path / "BENCH_bad.json"
    p.write_text("{not json")
    with pytest.raises(ValueError, match="unreadable"):
        load_snapshot(str(p))
    p.write_text(json.dumps({"schema": 99, "rows": [], "footer": {}}))
    with pytest.raises(ValueError, match="schema"):
        load_snapshot(str(p))
    p.write_text(json.dumps({"schema": 1, "rows": [], "footer": {}}))
    with pytest.raises(ValueError, match="no rows"):
        load_snapshot(str(p))
    p.write_text(json.dumps({
        "schema": 1, "rows": [{"name": "x", "us_per_call": None}],
        "footer": {}}))
    with pytest.raises(ValueError, match="us_per_call"):
        load_snapshot(str(p))
    p.write_text(json.dumps({
        "schema": 1, "rows": [{"name": "x", "us_per_call": 1.0}],
        "footer": {"total_wall_s": 1.0}}))
    with pytest.raises(ValueError, match="footer missing"):
        load_snapshot(str(p))


def test_compare_verdicts(tmp_path):
    base = bench_record("b", _rows(), 1.0, True, str(tmp_path / "base"))
    fresh_ok = bench_record(
        "b", [("bench/a", 250e-6, {}), ("bench/b", 5e-6, {}),
              ("bench/new", 1e-6, {})], 1.0, True, str(tmp_path / "ok"))
    fresh_bad = bench_record(
        "b", [("bench/a", 500e-6, {})], 1.0, True, str(tmp_path / "bad"))
    b, ok, bad = (load_snapshot(p) for p in (base, fresh_ok, fresh_bad))
    assert compare(b, ok, tolerance=3.0) == []      # 2.5x + new row: fine
    problems = compare(b, bad, tolerance=3.0)
    assert any("5.00x" in p for p in problems)      # bench/a blew up
    assert any("missing from fresh" in p for p in problems)  # bench/b gone


def test_check_regression_cli_modes(tmp_path, capsys):
    base = bench_record("m", _rows(), 1.0, True, str(tmp_path))
    worse = bench_record(
        "m", [(n, s * 10, d) for n, s, d in _rows()], 1.0, True,
        str(tmp_path / "w"))
    assert check_main(["--baseline", base, "--fresh", base]) == 0
    assert check_main(["--baseline", base, "--fresh", worse]) == 1
    out = capsys.readouterr().out
    assert "perf regression" in out and "::" not in out  # text mode: plain
    assert check_main(["--baseline", base, "--fresh", worse,
                       "--soft", "--format", "github"]) == 0
    out = capsys.readouterr().out
    assert "::warning::perf regression" in out
    assert check_main(["--baseline", base, "--fresh", worse,
                       "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error::perf regression" in out
    assert check_main(["--baseline", base, "--fresh", worse,
                       "--tolerance", "20"]) == 0


def test_bench_footer_dirty_flag_and_warning(tmp_path, capsys):
    """``bench_record`` stamps the working-tree state; the regression
    gate warns (never fails) when a baseline's footer says dirty=True,
    and stays silent on pre-flag snapshots that lack the key."""
    from benchmarks.check_regression import dirty_warning

    base = bench_record("d", _rows(), 1.0, True, str(tmp_path))
    doc = load_snapshot(base)
    assert isinstance(doc["footer"]["dirty"], bool)
    # back-compat: schema-1 snapshots recorded before the flag existed
    legacy = {**doc, "footer": {k: v for k, v in doc["footer"].items()
                                if k != "dirty"}}
    assert dirty_warning(legacy, base) == ""
    load_snapshot_path = tmp_path / "BENCH_dirty.json"
    dirty_doc = {**doc, "footer": {**doc["footer"], "dirty": True}}
    load_snapshot_path.write_text(json.dumps(dirty_doc))
    assert "DIRTY working tree" in dirty_warning(dirty_doc,
                                                 str(load_snapshot_path))
    # compare mode: dirty BASELINE annotates but the verdict is still
    # driven by the numbers alone
    assert check_main(["--baseline", str(load_snapshot_path),
                       "--fresh", base, "--format", "github"]) == 0
    err = capsys.readouterr().err
    assert "::warning::comparing against a dirty baseline" in err


def test_committed_bench_baselines_validate():
    """The acceptance gate: BENCH_kernels.json and BENCH_tta.json exist
    at the repo root and pass the no-arg validator."""
    for suite in ("kernels", "tta"):
        path = os.path.join(REPO_ROOT, f"BENCH_{suite}.json")
        assert os.path.exists(path), f"missing committed {path}"
        load_snapshot(path)
    assert check_main(["--root", REPO_ROOT]) == 0


def test_run_only_rejects_unknown_module():
    with pytest.raises(ValueError, match="valid keys"):
        bench_run.resolve_only("kernels,tta,definitely_not_a_table")
    assert bench_run.resolve_only("kernels, tta") == ["kernels", "tta"]


def test_kernels_time_blocks_per_rep():
    t_min, t_mean = _time(lambda: jnp.sum(jnp.ones((64, 64))), reps=3)
    assert 0 < t_min <= t_mean


@pytest.mark.slow
def test_run_record_writes_snapshot(tmp_path, capsys):
    bench_run.main(["--only", "kernels", "--record",
                    "--out-dir", str(tmp_path)])
    doc = load_snapshot(str(tmp_path / "BENCH_kernels.json"))
    assert doc["rows"][0]["name"] == "bench/luar_round_cnn"
    assert "mean_us" in doc["rows"][0]["derived"]
    assert "name,us_per_call,derived" in capsys.readouterr().out


def test_launch_train_trace_and_summary(tmp_path, capsys):
    """--trace-out writes a readable v1 trace and the summary line is the
    registry render (same keys the old hand-rolled block printed)."""
    from repro.launch.train import main as train_main
    trace_path = str(tmp_path / "tr.jsonl")
    train_main(["--workload", "mlp", "--rounds", "2", "--clients", "8",
                "--active", "4", "--eval-every", "4", "--seed", "0",
                "--trace-out", trace_path, "--profile"])
    events = read_trace(trace_path)
    assert events[0]["event"] == "RUN_START"
    assert events[-1]["event"] == "RUN_END"
    out = capsys.readouterr().out
    summary = next(json.loads(ln) for ln in out.splitlines()
                   if ln.startswith("{") and "comm_ratio" in ln
                   and "wall_s" in ln)
    assert list(summary)[:5] == ["comm_ratio", "uploaded_mb",
                                 "n_uplinks_spent", "down_ratio",
                                 "downloaded_mb"]
    assert "round_step" in out          # the --profile table
